# Developer entry points. `make tier1` mirrors .github/workflows/ci.yml.

CARGO_DIR := rust

# Pinned nightly for the sanitizer legs (kept in sync with
# NIGHTLY_TOOLCHAIN in .github/workflows/ci.yml).
NIGHTLY ?= nightly-2025-05-20

.PHONY: tier1 fmt lint lint-arblint build test test-sharded test-quant test-rff test-v2 test-kernel-blocked test-remote test-chaos tsan miri bench-smoke doc check-pjrt artifacts

tier1: fmt lint lint-arblint build test test-sharded test-quant test-rff test-v2

# Mirror the extra CI jobs: rustdoc with warnings denied, and the
# pjrt feature path against the vendored stub.
doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

check-pjrt:
	cd $(CARGO_DIR) && cargo check --features pjrt --all-targets

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# Repo-native static analysis (docs/ANALYSIS.md): SAFETY comments,
# env-var doc table, wire/format doc sync, alloc caps, no-panic plane.
lint-arblint:
	cd $(CARGO_DIR) && cargo run --bin arblint

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

# Mirror the CI tier1-sharded job: the whole suite through a 4-shard
# serving plane (unpinned coordinators read APPROXRBF_TEST_SHARDS).
test-sharded:
	cd $(CARGO_DIR) && APPROXRBF_TEST_SHARDS=4 cargo test -q

# Mirror the CI tier1-quant job: every unpinned publish produces an
# int8-quantized bundle, so the whole suite serves kind-5 payloads.
test-quant:
	cd $(CARGO_DIR) && APPROXRBF_TEST_QUANT=int8 cargo test -q

# Mirror the CI tier1-rff job: every unpinned publish lands on the
# random-feature substrate, so the whole suite serves kind-6 bundles.
test-rff:
	cd $(CARGO_DIR) && APPROXRBF_TEST_SUBSTRATE=rff cargo test -q

# Mirror the CI tier1-v2 job: every unpinned publish writes a format-v2
# (64-byte-aligned) bundle, so the whole suite hot-swaps and serves
# zero-copy from memory-mapped payloads.
test-v2:
	cd $(CARGO_DIR) && APPROXRBF_TEST_FORMAT=v2 cargo test -q

# Mirror the CI tier1-quant job's second step: the sharded plane served
# through the pinned 'blocked' quantized kernel arm (int8 decisions are
# bit-identical across arms; this guards the dispatch plumbing).
test-kernel-blocked:
	cd $(CARGO_DIR) && APPROXRBF_TEST_QUANT=int8 \
		APPROXRBF_QUANT_KERNEL=blocked cargo test -q --test shard_test

# Mirror the CI tier1-remote job: router + two spawned serve-shard
# processes over loopback (bit-identity, republish-over-the-wire,
# kill-one-shard fail-fast). Serial: the suite binds real sockets and
# spawns child processes, so parallel tests would just fight over CPU.
test-remote:
	cd $(CARGO_DIR) && APPROXRBF_TEST_REMOTE=1 \
		cargo test -q --test remote_e2e -- --test-threads=1

# Mirror the CI tier1-chaos job (one seed of its matrix): the serving
# plane behind deterministic fault proxies — delays, corruption, cuts,
# black holes, flap partitions, supervisor restarts. Override the seed
# with CHAOS_SEED=<u64> to replay a CI failure (docs/TESTING.md).
CHAOS_SEED ?= 1
test-chaos:
	cd $(CARGO_DIR) && APPROXRBF_TEST_CHAOS=1 \
		APPROXRBF_CHAOS_SEED=$(CHAOS_SEED) \
		cargo test -q --test chaos_e2e -- --test-threads=1

# Mirror the CI tsan job: ThreadSanitizer over the genuinely concurrent
# suites (sharded coordinator, then remote TCP plane). -Zbuild-std
# instruments std itself, without which TSan reports false races inside
# the runtime; requires `rustup component add rust-src` on $(NIGHTLY).
tsan:
	cd $(CARGO_DIR) && RUSTFLAGS="-Zsanitizer=thread" \
		APPROXRBF_TEST_SHARDS=4 cargo +$(NIGHTLY) test \
		--test shard_test -Zbuild-std \
		--target x86_64-unknown-linux-gnu
	cd $(CARGO_DIR) && RUSTFLAGS="-Zsanitizer=thread" \
		APPROXRBF_TEST_REMOTE=1 cargo +$(NIGHTLY) test \
		--test remote_e2e -Zbuild-std \
		--target x86_64-unknown-linux-gnu -- --test-threads=1

# Mirror the CI miri job: interpret the pure modules where UB would
# hide. The case cap keeps interpreted property tests fast; Miri
# isolates the environment, so each var must be explicitly forwarded.
# util::proptest is excluded: its meta-test asserts the uncapped count.
miri:
	cd $(CARGO_DIR) && \
		MIRIFLAGS="-Zmiri-env-forward=APPROXRBF_PROP_CASES -Zmiri-env-forward=APPROXRBF_QUANT_KERNEL -Zmiri-env-forward=APPROXRBF_RFF_KERNEL" \
		APPROXRBF_PROP_CASES=2 APPROXRBF_QUANT_KERNEL=scalar \
		APPROXRBF_RFF_KERNEL=scalar cargo +$(NIGHTLY) miri test --lib \
		util::crc32 util::rng registry::quant registry::mapfile \
		linalg::rffmap linalg::quantblas net::wire

# Mirror the CI bench-smoke job: short deterministic serving_bench and
# registry_bench sweeps; BENCH_quant.json's kernel_arms rows must show
# int8 blocked/simd >= scalar, and BENCH_registry.json's large int8 leg
# must show the v2 mmap swap beating the v1 heap decode (the CI job
# gates on both).
bench-smoke:
	cd $(CARGO_DIR) && APPROXRBF_BENCH_SMOKE=1 \
		cargo bench --bench serving_bench
	cd $(CARGO_DIR) && APPROXRBF_BENCH_SMOKE=1 \
		cargo bench --bench registry_bench

# AOT-lower the L1/L2 kernels to HLO text for the PJRT runtime
# (requires JAX; consumed by builds with `--features pjrt`).
artifacts:
	python3 python/compile/aot.py --out artifacts

