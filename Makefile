# Developer entry points. `make tier1` mirrors .github/workflows/ci.yml.

CARGO_DIR := rust

.PHONY: tier1 fmt lint build test doc check-pjrt artifacts

tier1: fmt lint build test

# Mirror the extra CI jobs: rustdoc with warnings denied, and the
# pjrt feature path against the vendored stub.
doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

check-pjrt:
	cd $(CARGO_DIR) && cargo check --features pjrt --all-targets

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

# AOT-lower the L1/L2 kernels to HLO text for the PJRT runtime
# (requires JAX; consumed by builds with `--features pjrt`).
artifacts:
	python3 python/compile/aot.py --out artifacts

