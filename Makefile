# Developer entry points. `make tier1` mirrors .github/workflows/ci.yml.

CARGO_DIR := rust

.PHONY: tier1 fmt lint build test artifacts

tier1: fmt lint build test

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

# AOT-lower the L1/L2 kernels to HLO text for the PJRT runtime
# (requires JAX; consumed by builds with `--features pjrt`).
artifacts:
	python3 python/compile/aot.py --out artifacts

