"""L2 checks: impl parity (pallas vs jnp), lowering shapes, HLO emission."""

import os
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model, aot
from compile.kernels import ref


def case(B=256, d=32, n=1024, seed=3, scale=0.3, gamma=0.05, b=-0.1):
    rng = np.random.default_rng(seed)
    Z = jnp.array((rng.normal(size=(B, d)) * scale).astype(np.float32))
    X = jnp.array((rng.normal(size=(n, d)) * scale).astype(np.float32))
    coef = jnp.array(rng.normal(size=(n,)).astype(np.float32))
    return Z, X, coef, gamma, b


@pytest.mark.parametrize("kind", ["approx", "exact", "build"])
def test_impl_parity(kind):
    """pallas and jnp L2 impls agree to f32 rounding."""
    Z, X, coef, gamma, b = case()
    if kind == "approx":
        c, v, M = ref.build_ref(X, coef, gamma)
        s = jnp.array([float(c[0]), gamma, b], dtype=jnp.float32)
        a = model.predict_approx_fn("pallas")(Z, M, v, s)
        j = model.predict_approx_fn("jnp")(Z, M, v, s)
    elif kind == "exact":
        s = jnp.array([gamma, b], dtype=jnp.float32)
        a = model.predict_exact_fn("pallas")(Z, X, coef, s)
        j = model.predict_exact_fn("jnp")(Z, X, coef, s)
    else:
        g = jnp.array([gamma], dtype=jnp.float32)
        a = model.build_fn("pallas")(X, coef, g)
        j = model.build_fn("jnp")(X, coef, g)
    for x, y in zip(a, j):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_lowerings_have_expected_io(impl):
    lowered = model.lower_predict_approx(32, 256, impl)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # 4 params (Z, M, v, scalars); result is a 2-tuple.
    assert text.count("parameter(") >= 4


def test_emit_writes_manifest_line():
    with tempfile.TemporaryDirectory() as td:
        manifest = []
        aot.emit(td, manifest, "approx", "jnp", 32, 0, 256,
                 model.lower_predict_approx(32, 256, "jnp"), 2)
        assert len(manifest) == 1
        line = manifest[0]
        for key in ("kind=approx", "impl=jnp", "d=32", "batch=256",
                    "outputs=2", "file=approx_jnp_d32_b256.hlo.txt"):
            assert key in line
        path = os.path.join(td, "approx_jnp_d32_b256.hlo.txt")
        assert os.path.getsize(path) > 100


def test_hlo_text_is_v0_5_1_compatible():
    """No raw serialized proto: HLO text with ENTRY + parameters parses on
    the old text parser (ids reassigned). We can't run xla_extension 0.5.1
    from python, so assert the structural invariants the text parser
    needs: module header and a single ENTRY computation."""
    lowered = model.lower_predict_exact(32, 1024, 256, "jnp")
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert text.count("ENTRY") == 1
