"""Core L1 correctness signal: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes-ranges; assert_allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import approx_predict, build_approx, rbf_exact
from compile.kernels import ref

RTOL, ATOL = 1e-4, 1e-5


def make_case(seed, B, d, n, scale, gamma):
    rng = np.random.default_rng(seed)
    Z = (rng.normal(size=(B, d)) * scale).astype(np.float32)
    X = (rng.normal(size=(n, d)) * scale).astype(np.float32)
    coef = rng.normal(size=(n,)).astype(np.float32)
    b = float(rng.normal())
    return jnp.array(Z), jnp.array(X), jnp.array(coef), gamma, b


# Dims chosen to exercise tile-boundary behaviour: exact multiples of the
# default tiles (128 batch / 256 SV blocks) and single-tile cases.
SHAPES = st.sampled_from([
    (128, 8, 256), (256, 16, 256), (128, 32, 512), (256, 5, 1024),
    (128, 64, 256), (256, 128, 512),
])


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shapes=SHAPES,
       scale=st.floats(0.05, 1.0), gamma=st.floats(1e-4, 0.5))
def test_rbf_exact_matches_ref(seed, shapes, scale, gamma):
    B, d, n = shapes
    Z, X, coef, gamma, b = make_case(seed, B, d, n, scale, gamma)
    got = rbf_exact(Z, X, coef, jnp.array([gamma, b], dtype=jnp.float32))
    want = ref.rbf_exact_ref(Z, X, coef, gamma, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shapes=SHAPES,
       scale=st.floats(0.05, 1.0), gamma=st.floats(1e-4, 0.5))
def test_builder_matches_ref(seed, shapes, scale, gamma):
    _, d, n = shapes
    _, X, coef, gamma, _ = make_case(seed, 1, d, n, scale, gamma)
    c, v, M = build_approx(X, coef, jnp.array([gamma], dtype=jnp.float32))
    cr, vr, Mr = ref.build_ref(X, coef, gamma)
    np.testing.assert_allclose(c, cr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(v, vr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(M, Mr, rtol=RTOL, atol=ATOL)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shapes=SHAPES,
       scale=st.floats(0.05, 1.0), gamma=st.floats(1e-4, 0.5))
def test_approx_predict_matches_ref(seed, shapes, scale, gamma):
    B, d, n = shapes
    Z, X, coef, gamma, b = make_case(seed, B, d, n, scale, gamma)
    cr, vr, Mr = ref.build_ref(X, coef, gamma)
    s = jnp.array([float(cr[0]), gamma, b], dtype=jnp.float32)
    dec, zn = approx_predict(Z, Mr, vr, s)
    dref, znref = ref.approx_predict_ref(Z, Mr, vr, cr[0], gamma, b)
    np.testing.assert_allclose(dec, dref, rtol=RTOL, atol=1e-4)
    np.testing.assert_allclose(zn, znref, rtol=RTOL, atol=ATOL)


def test_padded_svs_are_noops():
    """Padding contract: zero-coef SVs must not change any output."""
    Z, X, coef, gamma, b = make_case(7, 128, 16, 256, 0.3, 0.05)
    Xp = jnp.concatenate([X, jnp.ones((256, 16), jnp.float32) * 9.0])
    cp = jnp.concatenate([coef, jnp.zeros((256,), jnp.float32)])
    got = rbf_exact(Z, Xp, cp, jnp.array([gamma, b], dtype=jnp.float32))
    want = ref.rbf_exact_ref(Z, X, coef, gamma, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-4)
    c1, v1, M1 = build_approx(Xp, cp, jnp.array([gamma], jnp.float32))
    c0, v0, M0 = ref.build_ref(X, coef, gamma)
    np.testing.assert_allclose(c1, c0, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(v1, v0, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(M1, M0, rtol=RTOL, atol=ATOL)


def test_padded_batch_rows_are_isolated():
    """Zero-padded batch rows produce rows that don't affect real rows."""
    Z, X, coef, gamma, b = make_case(8, 128, 16, 256, 0.3, 0.05)
    Zp = jnp.concatenate([Z, jnp.zeros((128, 16), jnp.float32)])
    cr, vr, Mr = ref.build_ref(X, coef, gamma)
    s = jnp.array([float(cr[0]), gamma, b], dtype=jnp.float32)
    dec_p, _ = approx_predict(Zp, Mr, vr, s)
    dec, _ = approx_predict(Z, Mr, vr, s)
    np.testing.assert_allclose(dec_p[:128], dec, rtol=RTOL, atol=ATOL)


def test_approximation_error_bound_eq_a2():
    """Appendix A / Eq. (A.2): rel err < 3.05% for |x| < 0.5."""
    x = jnp.linspace(-0.5, 0.5, 10001)
    err = ref.maclaurin2_rel_error_ref(x)
    assert float(jnp.max(err)) < 0.0305


def test_approx_tracks_exact_within_bound():
    """End-to-end: when Eq. (3.11) holds, fhat is term-wise within ~3%.

    Build a case that respects the bound and check decision values agree
    to a few percent of the decision scale.
    """
    rng = np.random.default_rng(9)
    B, d, n = 128, 16, 512
    X = rng.normal(size=(n, d)).astype(np.float32)
    X /= np.linalg.norm(X, axis=1, keepdims=True)       # ||x_i|| = 1
    Z = rng.normal(size=(B, d)).astype(np.float32)
    Z /= np.linalg.norm(Z, axis=1, keepdims=True)       # ||z|| = 1
    coef = rng.normal(size=(n,)).astype(np.float32)
    gamma = 0.2                                          # < 1/4 = gamma_max
    b = 0.1
    Z, X, coef = jnp.array(Z), jnp.array(X), jnp.array(coef)
    cr, vr, Mr = ref.build_ref(X, coef, gamma)
    s = jnp.array([float(cr[0]), gamma, b], dtype=jnp.float32)
    dec, _ = approx_predict(Z, Mr, vr, s)
    exact = ref.rbf_exact_ref(Z, X, coef, gamma, b)
    scale = float(jnp.max(jnp.abs(exact - b))) + 1e-9
    rel = float(jnp.max(jnp.abs(dec - exact))) / scale
    assert rel < 0.05, rel
