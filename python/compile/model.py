"""L2: JAX compute-graph definitions wrapping the L1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text for the Rust runtime.
Each exists in two implementations:

  impl="pallas" — calls the Pallas kernels (interpret=True). These lower
      to scan/while-heavy HLO: correct everywhere, and the faithful
      expression of the paper's tiling structure, but slow on CPU PJRT.
  impl="jnp"    — the same math as pure jnp ops. XLA fuses these into a
      handful of loops; this is the implementation the performance
      artifacts use (DESIGN.md section 10 documents this honestly).

Both implementations are asserted equal in python/tests/ and again from
Rust (runtime parity tests), so swapping impls never changes numerics
beyond f32 rounding.

Signature conventions (fixed shapes; the Rust caller pads — see
kernels/ref.py for the padding contract):
  predict_approx(Z(B,d), M(d,d), v(d,), s(3,)=[c,gamma,b]) -> (dec(B,), zn(B,))
  predict_exact (Z(B,d), X(n,d), coef(n,), s(2,)=[gamma,b]) -> (dec(B,),)
  build         (X(n,d), coef(n,), g(1,)=[gamma])          -> (c(1,), v(d,), M(d,d))
"""

import jax
import jax.numpy as jnp

from .kernels import approx_predict, build_approx, rbf_exact
from .kernels import ref


def predict_approx_fn(impl="jnp"):
    """Approximated decision function (the paper's O(d^2) hot path)."""
    if impl == "pallas":
        def fn(Z, M, v, s):
            dec, zn = approx_predict(Z, M, v, s)
            return (dec, zn)
    else:
        def fn(Z, M, v, s):
            dec, zn = ref.approx_predict_ref(Z, M, v, s[0], s[1], s[2])
            return (dec, zn)
    return fn


def predict_exact_fn(impl="jnp"):
    """Exact RBF decision function (the paper's O(n_SV d) baseline)."""
    if impl == "pallas":
        def fn(Z, X, coef, s):
            return (rbf_exact(Z, X, coef, s),)
    else:
        def fn(Z, X, coef, s):
            return (ref.rbf_exact_ref(Z, X, coef, s[0], s[1]),)
    return fn


def build_fn(impl="jnp"):
    """Model approximation: SVs -> (c, v, M) (the paper's t_approx stage)."""
    if impl == "pallas":
        def fn(X, coef, g):
            return build_approx(X, coef, g)
    else:
        def fn(X, coef, g):
            return ref.build_ref(X, coef, g[0])
    return fn


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_predict_approx(d, batch, impl="jnp"):
    fn = predict_approx_fn(impl)
    return jax.jit(fn).lower(
        spec((batch, d)), spec((d, d)), spec((d,)), spec((3,))
    )


def lower_predict_exact(d, nsv, batch, impl="jnp"):
    fn = predict_exact_fn(impl)
    return jax.jit(fn).lower(
        spec((batch, d)), spec((nsv, d)), spec((nsv,)), spec((2,))
    )


def lower_build(d, nsv, impl="jnp"):
    fn = build_fn(impl)
    return jax.jit(fn).lower(spec((nsv, d)), spec((nsv,)), spec((1,)))
