"""AOT compile path: lower L2 functions to HLO *text* for the Rust runtime.

Run once via `make artifacts`; Python never runs on the request path.

Interchange format is HLO text, NOT `lowered.compile()` / serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).
The HLO *text* parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md "Gotchas").

Outputs (artifacts/):
  <kind>_<impl>_d<d>[_n<nsv>][_b<batch>].hlo.txt   one per shape bucket
  manifest.txt   one line per artifact:
      kind=approx impl=jnp d=128 nsv=0 batch=256 outputs=2 file=...
The Rust runtime (rust/src/runtime/) reads the manifest, picks the
smallest bucket that fits a request, and pads inputs per the padding
contract in kernels/ref.py.
"""

import argparse
import os
import sys
import time

from jax._src.lib import xla_client as xc

from . import model

# Shape buckets. d buckets cover the five dataset profiles in
# data/synth.rs (22->32, 100/123->128, 780->1024, 2000->2048); nsv buckets
# cover trained model sizes after padding with zero-coef SVs.
APPROX_DS = [32, 64, 128, 256, 512, 1024, 2048]
EXACT_SHAPES = [  # (d, nsv)
    (32, 1024), (32, 4096), (32, 8192),
    (64, 1024), (64, 4096),
    (128, 1024), (128, 4096), (128, 8192),
    (256, 1024), (256, 4096),
    (512, 1024), (512, 4096),
    (1024, 1024), (1024, 4096),
    (2048, 1024), (2048, 4096),
]
BUILD_SHAPES = EXACT_SHAPES
BATCH = 256
BULK_BATCH = 2048
# Pallas (interpret) variants: structural/correctness artifacts; jnp
# variants are the performance artifacts (DESIGN.md section 10).
PALLAS_APPROX_DS = [32, 128]
PALLAS_EXACT_SHAPES = [(32, 1024), (128, 1024)]
PALLAS_BUILD_SHAPES = [(32, 1024), (128, 1024)]


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir, manifest, kind, impl, d, nsv, batch, lowered, outputs):
    name = f"{kind}_{impl}_d{d}"
    if nsv:
        name += f"_n{nsv}"
    if batch:
        name += f"_b{batch}"
    fname = name + ".hlo.txt"
    t0 = time.time()
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    manifest.append(
        f"kind={kind} impl={impl} d={d} nsv={nsv} batch={batch} "
        f"outputs={outputs} file={fname}"
    )
    print(f"  {fname:44s} {len(text)/1024:9.1f} KiB  {time.time()-t0:5.2f}s",
          flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument("--skip-pallas", action="store_true",
                    help="emit only the jnp performance artifacts")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    t0 = time.time()

    print("== approx predict (jnp) ==", flush=True)
    for d in APPROX_DS:
        emit(out_dir, manifest, "approx", "jnp", d, 0, BATCH,
             model.lower_predict_approx(d, BATCH, "jnp"), 2)
        # Bulk bucket: amortizes per-execute overhead for offline
        # prediction (EXPERIMENTS.md §Perf L3-P3).
        emit(out_dir, manifest, "approx", "jnp", d, 0, BULK_BATCH,
             model.lower_predict_approx(d, BULK_BATCH, "jnp"), 2)
    print("== exact predict (jnp) ==", flush=True)
    for d, n in EXACT_SHAPES:
        emit(out_dir, manifest, "exact", "jnp", d, n, BATCH,
             model.lower_predict_exact(d, n, BATCH, "jnp"), 1)
    print("== build (jnp) ==", flush=True)
    for d, n in BUILD_SHAPES:
        emit(out_dir, manifest, "build", "jnp", d, n, 0,
             model.lower_build(d, n, "jnp"), 3)

    if not args.skip_pallas:
        print("== approx predict (pallas, interpret) ==", flush=True)
        for d in PALLAS_APPROX_DS:
            emit(out_dir, manifest, "approx", "pallas", d, 0, BATCH,
                 model.lower_predict_approx(d, BATCH, "pallas"), 2)
        print("== exact predict (pallas, interpret) ==", flush=True)
        for d, n in PALLAS_EXACT_SHAPES:
            emit(out_dir, manifest, "exact", "pallas", d, n, BATCH,
                 model.lower_predict_exact(d, n, BATCH, "pallas"), 1)
        print("== build (pallas, interpret) ==", flush=True)
        for d, n in PALLAS_BUILD_SHAPES:
            emit(out_dir, manifest, "build", "pallas", d, n, 0,
                 model.lower_build(d, n, "pallas"), 3)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest.txt "
          f"in {time.time()-t0:.1f}s -> {out_dir}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
