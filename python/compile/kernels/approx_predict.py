"""L1 Pallas kernel: batched approximated RBF-SVM decision function.

Computes, for a tile of test instances Z (B_t x d):

    fhat(z) = exp(-gamma ||z||^2) * (c + v.z + z^T M z) + b        (Eq. 3.8)

and the squared norms ||z||^2 (free by-product consumed by the run-time
validity check of Eq. 3.11 — the Rust router compares them against
1/(16 gamma^2 ||x_M||^2)).

TPU mapping (DESIGN.md section 7): the grid iterates over batch tiles; M
stays resident in VMEM (d <= 1024; for d = 2048 the XLA path is used and M
is panel-tiled by the compiler). z^T M z is evaluated as an MXU matmul
(Z M) followed by a VPU row-reduction against Z — NOT a per-element loop —
so the kernel is matmul-shaped exactly like the paper's BLAS formulation.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that the Rust runtime
(xla crate, PJRT CPU) executes. Real-TPU characteristics are estimated
analytically in DESIGN.md section 8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _approx_kernel(z_ref, m_ref, v_ref, s_ref, dec_ref, zn_ref):
    """One batch tile. s_ref packs the scalars [c, gamma, b] as (3,)."""
    z = z_ref[...].astype(jnp.float32)                    # (bt, d)
    m = m_ref[...].astype(jnp.float32)                    # (d, d)
    v = v_ref[...].astype(jnp.float32)                    # (d,)
    c = s_ref[0]
    gamma = s_ref[1]
    b = s_ref[2]

    zn = jnp.sum(z * z, axis=1)                           # (bt,)  VPU
    zm = jnp.dot(z, m, preferred_element_type=jnp.float32)  # (bt, d) MXU
    quad = jnp.sum(zm * z, axis=1)                        # (bt,)  VPU
    lin = jnp.dot(z, v, preferred_element_type=jnp.float32)  # (bt,)
    dec_ref[...] = jnp.exp(-gamma * zn) * (c + lin + quad) + b
    zn_ref[...] = zn


@functools.partial(jax.jit, static_argnames=("block_b",))
def approx_predict(Z, M, v, scalars, *, block_b=128):
    """Approximated decision values for a batch.

    Args:
      Z: (B, d) f32 test instances; B must be a multiple of block_b
         (the Rust caller pads the final batch tile with zero rows).
      M: (d, d) f32 Hessian-derived matrix X^T D X.
      v: (d,)   f32 gradient-derived vector X^T w.
      scalars: (3,) f32 = [c, gamma, b].
      block_b: batch tile size (grid = B // block_b).

    Returns:
      (decision (B,), znorm2 (B,)) both f32.
    """
    B, d = Z.shape
    bt = min(block_b, B)
    assert B % bt == 0, f"batch {B} not a multiple of tile {bt}"
    grid = (B // bt,)
    return pl.pallas_call(
        _approx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=True,
    )(Z, M, v, scalars)
