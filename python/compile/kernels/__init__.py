"""Pallas kernels (L1) + pure-jnp oracles for the approxrbf compute stack."""

from .approx_predict import approx_predict
from .builder import build_approx
from .rbf_exact import rbf_exact

__all__ = ["approx_predict", "build_approx", "rbf_exact"]
