"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. pytest (python/tests/) asserts allclose between the two
over hypothesis-generated shapes/values; the Rust integration tests assert
the PJRT-executed artifacts against the same math re-implemented in Rust.

Conventions (match the paper, Section 3, with X stored row-major):
  Z    : (B, d)  test instances, one per row
  X    : (n, d)  support vectors, one per row  (paper's X is d x n_SV)
  coef : (n,)    alpha_i * y_i
  gamma, b : scalars (passed as (1,) f32 so one AOT artifact serves all)

Decision function (Eq. 3.2/3.3):
  f(z)    = sum_i coef_i * exp(-gamma * ||x_i - z||^2) + b
Approximation (Eq. 3.7/3.8):
  fhat(z) = exp(-gamma*||z||^2) * (c + v.z + z^T M z) + b
with
  e_i  = exp(-gamma*||x_i||^2)
  c    = sum_i coef_i * e_i
  v    = X^T w,              w_i = 2 gamma   * coef_i * e_i
  M    = X^T diag(D) X,      D_i = 2 gamma^2 * coef_i * e_i
"""

import jax.numpy as jnp


def rbf_exact_ref(Z, X, coef, gamma, b):
    """Exact RBF decision values, O(B * n * d). Returns (B,)."""
    # ||x_i - z||^2 = ||z||^2 + ||x_i||^2 - 2 z.x_i, computed batched.
    zn = jnp.sum(Z * Z, axis=1, keepdims=True)          # (B, 1)
    xn = jnp.sum(X * X, axis=1, keepdims=True).T        # (1, n)
    cross = Z @ X.T                                     # (B, n)
    d2 = zn + xn - 2.0 * cross
    K = jnp.exp(-gamma * d2)                            # (B, n)
    return K @ coef + b


def build_ref(X, coef, gamma):
    """Approximate-model parameters (c, v, M) from SVs. Eq. (3.8).

    Returns (c: (1,), v: (d,), M: (d, d)).
    """
    xn = jnp.sum(X * X, axis=1)                         # (n,)
    e = jnp.exp(-gamma * xn)                            # (n,)
    ce = coef * e                                       # (n,)
    c = jnp.sum(ce)[None]                               # (1,)
    w = 2.0 * gamma * ce                                # (n,)
    D = 2.0 * gamma * gamma * ce                        # (n,)
    v = X.T @ w                                         # (d,)
    M = (X * D[:, None]).T @ X                          # (d, d)
    return c, v, M


def approx_predict_ref(Z, M, v, c, gamma, b):
    """Approximated decision values, O(B * d^2). Eq. (3.8).

    Returns (decision: (B,), znorm2: (B,)). The squared norms are a free
    by-product used by the run-time bound check (Eq. 3.11).
    """
    zn = jnp.sum(Z * Z, axis=1)                         # (B,)
    zm = Z @ M                                          # (B, d)
    quad = jnp.sum(zm * Z, axis=1)                      # (B,)
    lin = Z @ v                                         # (B,)
    dec = jnp.exp(-gamma * zn) * (c + lin + quad) + b
    return dec, zn


def maclaurin2_ref(x):
    """Second-order Maclaurin approximation of exp(x) (Appendix A)."""
    return 1.0 + x + 0.5 * x * x


def maclaurin2_rel_error_ref(x):
    """|e^x - (1 + x + x^2/2)| / e^x — the curve of Figure 1."""
    return jnp.abs(jnp.exp(x) - maclaurin2_ref(x)) / jnp.exp(x)
