"""L1 Pallas kernel: exact RBF-SVM decision function (the paper's baseline).

    f(z) = sum_i coef_i exp(-gamma ||x_i - z||^2) + b              (Eq. 3.3)

Complexity O(B * n_SV * d). The grid is (batch tiles, SV tiles); the SV
axis is the innermost (sequential) grid dimension and partial sums are
accumulated directly into the output block — the classic Pallas
matmul-accumulation pattern. Each (n_t x d) panel of X is loaded once per
batch tile, which is the HBM->VMEM schedule the paper expressed with its
"loop over SVs" (DESIGN.md section 7).

The squared distance is computed via the same factorization the paper
uses: ||x - z||^2 = ||z||^2 + ||x||^2 - 2 z.x, so the inner loop is one
MXU matmul (Z X^T) plus rank-1 norm corrections.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(z_ref, x_ref, coef_ref, s_ref, dec_ref):
    """Accumulate one (batch tile, SV tile) pair. s_ref = [gamma, b]."""
    s = pl.program_id(1)
    gamma = s_ref[0]
    b = s_ref[1]
    z = z_ref[...].astype(jnp.float32)                     # (bt, d)
    x = x_ref[...].astype(jnp.float32)                     # (st, d)
    coef = coef_ref[...].astype(jnp.float32)               # (st,)

    zn = jnp.sum(z * z, axis=1, keepdims=True)             # (bt, 1)
    xn = jnp.sum(x * x, axis=1)[None, :]                   # (1, st)
    cross = jnp.dot(z, x.T, preferred_element_type=jnp.float32)  # (bt, st)
    k = jnp.exp(-gamma * (zn + xn - 2.0 * cross))          # (bt, st)
    partial = jnp.dot(k, coef, preferred_element_type=jnp.float32)  # (bt,)

    @pl.when(s == 0)
    def _init():
        dec_ref[...] = jnp.full_like(dec_ref, b)

    dec_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_b", "block_s"))
def rbf_exact(Z, X, coef, scalars, *, block_b=128, block_s=256):
    """Exact decision values for a batch.

    Args:
      Z: (B, d) f32 test instances (B multiple of block_b; zero-padded).
      X: (n, d) f32 support vectors (n multiple of block_s; padded SVs
         MUST carry coef = 0 so their kernel terms vanish).
      coef: (n,) f32 alpha_i * y_i.
      scalars: (2,) f32 = [gamma, b].

    Returns: decision (B,) f32.
    """
    B, d = Z.shape
    n, d2 = X.shape
    assert d == d2
    bt = min(block_b, B)
    st = min(block_s, n)
    assert B % bt == 0 and n % st == 0
    grid = (B // bt, n // st)
    return pl.pallas_call(
        _rbf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, s: (i, 0)),
            pl.BlockSpec((st, d), lambda i, s: (s, 0)),
            pl.BlockSpec((st,), lambda i, s: (s,)),
            pl.BlockSpec((2,), lambda i, s: (0,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, s: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=True,
    )(Z, X, coef, scalars)
