"""L1 Pallas kernel: build the approximate model (c, v, M) from SVs.

This is the paper's "approximation speed" stage (Table 2, t_approx):

    e_i = exp(-gamma ||x_i||^2)
    c   = sum_i coef_i e_i
    v   = X^T w,            w_i = 2 gamma   coef_i e_i      (gradient)
    M   = X^T diag(D) X,    D_i = 2 gamma^2 coef_i e_i      (Hessian/2)

dominated by the rank-n_SV symmetric update M = X^T D X — exactly the
X D X^T of Eq. (3.8) with our row-major X. The grid iterates over SV
panels (the only axis that grows) and accumulates all three outputs in
place; d x d stays resident, mirroring a K-blocked SYRK.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _builder_kernel(x_ref, coef_ref, g_ref, c_ref, v_ref, m_ref):
    s = pl.program_id(0)
    gamma = g_ref[0]
    x = x_ref[...].astype(jnp.float32)                     # (st, d)
    coef = coef_ref[...].astype(jnp.float32)               # (st,)

    xn = jnp.sum(x * x, axis=1)                            # (st,)
    ce = coef * jnp.exp(-gamma * xn)                       # (st,)
    w = 2.0 * gamma * ce
    dd = 2.0 * gamma * gamma * ce

    @pl.when(s == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        v_ref[...] = jnp.zeros_like(v_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    c_ref[...] += jnp.sum(ce)[None]
    v_ref[...] += jnp.dot(x.T, w, preferred_element_type=jnp.float32)
    m_ref[...] += jnp.dot(
        x.T * dd[None, :], x, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_s",))
def build_approx(X, coef, gamma, *, block_s=256):
    """Approximate-model parameters from support vectors.

    Args:
      X: (n, d) f32 support vectors (padded SVs must carry coef = 0).
      coef: (n,) f32 alpha_i * y_i.
      gamma: (1,) f32 RBF parameter.

    Returns: (c (1,), v (d,), M (d, d)) all f32.
    """
    n, d = X.shape
    st = min(block_s, n)
    assert n % st == 0
    grid = (n // st,)
    return pl.pallas_call(
        _builder_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((st, d), lambda s: (s, 0)),
            pl.BlockSpec((st,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda s: (0,)),
            pl.BlockSpec((d,), lambda s: (0,)),
            pl.BlockSpec((d, d), lambda s: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        ],
        interpret=True,
    )(X, coef, gamma)
