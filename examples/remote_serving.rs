//! Network serving tier on loopback: two in-process [`ShardServer`]s
//! behind a [`Router`], demonstrating that the remote plane is a
//! transparent, bit-identical stand-in for the in-process one.
//!
//! This example:
//!   1. picks two tenant ids that rendezvous-hash to *different*
//!      shards (`Router::place_for` — the same placement function the
//!      in-process `ShardSet` uses), trains both and publishes them
//!      into a shared registry (one int8-quantized);
//!   2. binds two single-lane shard servers on ephemeral loopback
//!      ports and connects a `Router` over them — then serves the same
//!      rows through a local coordinator *and* the remote plane and
//!      asserts decision/route/generation bit-identity per row;
//!   3. republishes one tenant mid-stream and propagates it with
//!      `Router::refresh()` (an `ARBW` Refresh frame per shard, acks
//!      counted) — the next remote batch serves generation 2;
//!   4. shuts one shard server down and shows fail-fast isolation:
//!      the dead shard's tenant gets typed errors immediately (no
//!      hangs), the surviving shard's tenant keeps serving.
//!
//! Everything runs in this one process over 127.0.0.1; the production
//! deployment is the same code with `approxrbf serve-shard` processes
//! on real hosts. Run: `cargo run --release --example remote_serving`
//!
//! [`ShardServer`]: approxrbf::net::ShardServer
//! [`Router`]: approxrbf::net::Router

use std::sync::Arc;
use std::time::Duration;

use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::builder::build_approx_model;
use approxrbf::coordinator::{
    Coordinator, PredictErrorKind, RoutePolicy,
};
use approxrbf::data::{Dataset, SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::net::{Router, RouterConfig, ShardServer, ShardServerConfig};
use approxrbf::registry::{
    ModelStore, PayloadKind, PublishOptions,
};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};

const SHARDS: usize = 2;

fn train_tenant(
    seed: u64,
) -> approxrbf::Result<(SvmModel, approxrbf::approx::ApproxModel, Dataset)> {
    let (raw_train, raw_test) =
        SynthProfile::ControlLike.generate(seed, 400, 160);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    let am = build_approx_model(&model, MathBackend::Blocked)?;
    Ok((model, am, test))
}

fn main() -> approxrbf::Result<()> {
    // ---------- tenants on different shards, by construction ----------
    // Placement is a pure function of (model id, shard count) — the
    // router and the in-process ShardSet share it — so we can pick ids
    // that land on different shards before anything is running.
    let (mut on_shard0, mut on_shard1) = (None, None);
    for i in 0u32.. {
        let name = format!("tenant-{i}");
        match Router::place_for(&name, SHARDS) {
            0 if on_shard0.is_none() => on_shard0 = Some(name),
            1 if on_shard1.is_none() => on_shard1 = Some(name),
            _ => {}
        }
        if on_shard0.is_some() && on_shard1.is_some() {
            break;
        }
    }
    let victim = on_shard0.unwrap(); // served by shard 0 (killed later)
    let survivor = on_shard1.unwrap(); // served by shard 1

    let dir = std::env::temp_dir().join("approxrbf_remote_example");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir)?);
    let (m0, a0, test0) = train_tenant(11)?;
    store.publish_with(
        &victim,
        &m0,
        &a0,
        PublishOptions {
            quantize: Some(PayloadKind::Int8),
            ..Default::default()
        },
    )?;
    let (m1, a1, test1) = train_tenant(22)?;
    store.publish(&survivor, &m1, &a1)?;
    println!(
        "[publish] '{victim}' (int8) -> shard 0, '{survivor}' (f32) -> \
         shard 1 ({} B registry at {})",
        store.peek(&victim)?.size_bytes + store.peek(&survivor)?.size_bytes,
        dir.display()
    );

    // ---------- two shard servers + a router, all on loopback ----------
    let bind_shard = |shard_id: u32| -> approxrbf::Result<ShardServer> {
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .warm_start(true)
            .start_registry(store.clone())?;
        ShardServer::bind(
            "127.0.0.1:0",
            coord,
            store.clone(),
            ShardServerConfig { shard_id, ..Default::default() },
        )
    };
    let server0 = bind_shard(0)?;
    let server1 = bind_shard(1)?;
    let addrs = vec![
        server0.local_addr().to_string(),
        server1.local_addr().to_string(),
    ];
    let router = Router::connect(&addrs, RouterConfig::default())?;
    println!("[net] router over {} / {}", addrs[0], addrs[1]);

    // A local single-lane plane over the same store is the oracle.
    let local = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .warm_start(true)
        .start_registry(store.clone())?;
    let local_client = local.client();
    let remote_client = router.client();

    // ---------- bit-identity: local plane vs remote plane ----------
    let mut compared = 0usize;
    for (id, test) in [(&victim, &test0), (&survivor, &test1)] {
        let want = local_client.predict_all_for(id, &test.x)?;
        let got = remote_client.predict_all_for(id, &test.x)?;
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.decision, g.decision, "decision drift on {id}");
            assert_eq!(w.route, g.route, "route drift on {id}");
            assert_eq!(w.generation, g.generation);
        }
        compared += want.len();
    }
    println!(
        "[parity] {compared} rows served twice: remote decisions, routes \
         and generations are bit-identical to the local plane"
    );

    // ---------- republish over the wire ----------
    let (m2, a2, _) = train_tenant(1022)?;
    let generation = store.publish(&survivor, &m2, &a2)?;
    let acked = router.refresh()?;
    local.refresh();
    println!(
        "[swap] republished '{survivor}' as generation {generation}; \
         Refresh acked by {acked}/{SHARDS} shards"
    );
    let post = remote_client.predict_all_for(&survivor, &test1.x)?;
    assert!(post.iter().all(|r| r.generation == generation));
    println!(
        "[swap] next remote batch ({} rows) served entirely by \
         generation {generation}",
        post.len()
    );

    // ---------- fail-fast isolation ----------
    println!("[kill] shutting down shard 0 ('{victim}'s owner)…");
    server0.shutdown()?;
    std::thread::sleep(Duration::from_millis(300)); // let the link die
    let z = test0.x.row(0).to_vec();
    let failure = match remote_client.submit_to(&victim, z) {
        // The router saw the link die first: refused at submit.
        Err(e) => e,
        // The frame got out before the teardown: the pending request
        // is completed with a typed error, never left hanging.
        Ok(_) => match remote_client.recv(Duration::from_secs(5)) {
            Some(Err(e)) => e,
            Some(Ok(r)) => panic!("dead shard served {r:?}"),
            None => panic!("request to dead shard hung"),
        },
    };
    assert!(matches!(
        failure.kind,
        PredictErrorKind::Exec { .. } | PredictErrorKind::Shutdown
    ));
    println!("[kill] '{victim}' fails fast with a typed error: {failure}");
    let alive = remote_client.predict_all_for(&survivor, &test1.x)?;
    println!(
        "[kill] '{survivor}' is unaffected: {} rows served by the \
         surviving shard",
        alive.len()
    );

    router.shutdown();
    server1.shutdown()?;
    local.shutdown()?;
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "\nThe RemoteClient used above has the same surface as the \
         in-process Client — the serving code is identical either way."
    );
    Ok(())
}
