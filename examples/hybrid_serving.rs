//! END-TO-END driver: proves all three layers compose on a real
//! workload, Python never on the request path.
//!
//!   L1/L2  Pallas/JAX kernels, AOT-lowered to HLO text (`make
//!          artifacts`, build time only)
//!   RT     rust `runtime::Engine` loads + compiles the artifacts via
//!          PJRT and executes them from the hot loop
//!   L3     the coordinator batches, bound-routes (Eq. 3.11) and serves
//!
//! Workload: train an RBF SVM on the ijcnn1-like profile, approximate
//! it (Eq. 3.8), then serve 20 000 batched requests — 10% of which are
//! adversarially pushed outside the validity bound — through the
//! hybrid router on the XLA executor. Reports throughput, latency
//! percentiles, route mix and served accuracy vs the exact model.
//! Falls back to the native executor (with a notice) if `artifacts/`
//! is missing. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example hybrid_serving`

use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::coordinator::{Coordinator, ExecSpec, Route, RoutePolicy};
use approxrbf::data::{SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::svm::predict::ExactPredictor;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;
use approxrbf::util::Rng;

const REQUESTS: usize = 20_000;
const OOB_FRACTION: f64 = 0.10;

/// XLA executor spec, when compiled in (`--features pjrt`) and the AOT
/// artifacts exist.
fn xla_exec() -> Option<ExecSpec> {
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        return Some(ExecSpec::Xla { artifacts_dir: "artifacts".into() });
    }
    None
}

fn main() -> approxrbf::Result<()> {
    // ---------- build phase (offline; python already ran via make) ----------
    let (raw_train, raw_test) =
        SynthProfile::ControlLike.generate(2024, 4000, 4000);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * 0.8;
    println!(
        "[build] training on {} instances (d={}), gamma={gamma:.4}…",
        train.len(),
        train.dim()
    );
    let t0 = Instant::now();
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    println!(
        "[build] {} SVs in {:.1}s; approximating (Eq. 3.8)…",
        stats.n_sv,
        t0.elapsed().as_secs_f64()
    );
    let am = build_approx_model(&model, MathBackend::Blocked)?;

    let exec = match xla_exec() {
        Some(exec) => {
            println!(
                "[build] artifacts found: serving on the XLA/PJRT executor"
            );
            exec
        }
        None => {
            println!(
                "[build] NOTE: no XLA executor (missing artifacts/ or built \
                 without `--features pjrt`); using the native executor"
            );
            ExecSpec::Native(MathBackend::Blocked)
        }
    };

    // ---------- traffic: 10% adversarially out-of-bound ----------
    let mut rng = Rng::new(7);
    let mut traffic = Vec::with_capacity(REQUESTS);
    let mut truth = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let r = i % test.len();
        let mut features = test.x.row(r).to_vec();
        if rng.chance(OOB_FRACTION) {
            let s = rng.range(2.5, 5.0) as f32;
            for v in &mut features {
                *v *= s; // ‖z‖² now ≫ budget: guarantee would be void
            }
        }
        traffic.push(features);
        truth.push(test.y[r]);
    }

    // Ground truth from the exact model (the reference the paper diffs
    // against); also used to score served accuracy.
    let exact_pred = ExactPredictor::new(&model, MathBackend::Blocked)?;

    // ---------- serve ----------
    let coord = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .exec(exec)
        .max_batch(256)
        .max_wait(Duration::from_micros(500))
        .start(model.clone(), am.clone())?;
    let client = coord.client();
    println!("[serve] submitting {REQUESTS} requests…");
    // Closed-loop client with a bounded in-flight window so reported
    // latency reflects service time, not a 20k-deep client queue. The
    // window refills in half-window bursts: on a single core, per-
    // response refills would thrash the batcher with wakeups.
    const INFLIGHT: usize = 1024;
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut responses = Vec::with_capacity(REQUESTS);
    while responses.len() < REQUESTS {
        let inflight = submitted - responses.len();
        if submitted < REQUESTS && inflight <= INFLIGHT / 2 {
            let burst =
                (INFLIGHT - inflight).min(REQUESTS - submitted);
            for _ in 0..burst {
                client.submit(traffic[submitted].clone())?;
                submitted += 1;
            }
        }
        // Completions are typed: a request the executor cannot serve
        // surfaces as Err(PredictError) here instead of a timeout.
        if let Some(c) = client.recv(Duration::from_millis(200)) {
            responses.push(c?);
        }
        while let Some(c) = client.recv(Duration::from_micros(0)) {
            responses.push(c?);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---------- report ----------
    responses.sort_by_key(|r| r.id);
    let mut label_hits = 0usize;
    let mut diff_vs_exact = 0usize;
    for (i, resp) in responses.iter().enumerate() {
        if (resp.label > 0.0) == (truth[i] > 0.0) {
            label_hits += 1;
        }
        let exact = exact_pred
            .decision_batch(&approxrbf::linalg::Mat::from_rows(&[
                &traffic[i][..],
            ])?)?[0];
        if (exact >= 0.0) != (resp.decision >= 0.0) {
            diff_vs_exact += 1;
        }
    }
    let m = coord.metrics();
    let lat: Vec<f64> =
        responses.iter().map(|r| r.latency.as_secs_f64()).collect();
    let s = approxrbf::util::Summary::from(&lat);
    println!("\n== E2E results (hybrid policy) ==");
    println!(
        "throughput : {:.0} req/s ({REQUESTS} requests in {wall:.2}s)",
        REQUESTS as f64 / wall
    );
    println!(
        "latency    : mean {:.0} µs   p50 {:.0} µs   p95 {:.0} µs   p99 {:.0} µs",
        s.mean * 1e6,
        s.p50 * 1e6,
        s.p95 * 1e6,
        s.p99 * 1e6
    );
    println!(
        "routes     : approx {} / exact {}  (out-of-bound detected: {})",
        m.served_approx, m.served_exact, m.out_of_bound
    );
    println!(
        "accuracy   : served {:.2}%   label diff vs exact model: {:.3}%",
        100.0 * label_hits as f64 / REQUESTS as f64,
        100.0 * diff_vs_exact as f64 / REQUESTS as f64
    );
    let approx_frac = m.served_approx as f64
        / (m.served_approx + m.served_exact) as f64;
    println!(
        "\n{:.0}% of traffic took the O(d²) fast path; the {:.0}% that \
         violated Eq. (3.11) was escorted to the exact model, so every \
         served prediction kept the 3.05% term-wise guarantee.",
        approx_frac * 100.0,
        (1.0 - approx_frac) * 100.0
    );
    // Invariant check (also asserted in tests): no approx-routed
    // response may be out of bound.
    assert!(responses
        .iter()
        .all(|r| r.route != Route::Approx || r.in_bound));
    coord.shutdown()?;
    Ok(())
}
