//! Object-detection-style workload (the paper's §5 motivating
//! application, after Cao et al. [4]): a detector scans frames with a
//! sliding window, extracting a low-dimensional descriptor per window
//! and classifying each — thousands of classifications per frame, in
//! real time. Exactly the regime where O(d²) beats O(n_SV·d).
//!
//! This example synthesizes a stream of "frames" (batches of window
//! descriptors with a plant-able fraction of positives), serves them
//! through the coordinator under each routing policy, and reports
//! per-frame latency and detection quality.
//!
//! Run: `cargo run --release --example object_detection`

use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::coordinator::{Coordinator, RoutePolicy};
use approxrbf::data::synth;
use approxrbf::linalg::{Mat, MathBackend};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;
use approxrbf::util::Rng;

const DESCRIPTOR_DIM: usize = 36; // HOG-like block descriptor
const WINDOWS_PER_FRAME: usize = 1024;
const FRAMES: usize = 30;

fn main() -> approxrbf::Result<()> {
    // ---- train a pedestrian-vs-background classifier ----
    let train = synth::two_gaussians(7, 4000, DESCRIPTOR_DIM, 1.6);
    let gamma = gamma_max_for_data(&train) * 0.8;
    println!("training detector (d={DESCRIPTOR_DIM}, gamma={gamma:.4})…");
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    println!("  {} SVs from {} windows", stats.n_sv, train.len());
    let am = build_approx_model(&model, MathBackend::Blocked)?;

    // ---- stream frames through the coordinator ----
    for policy in [RoutePolicy::AlwaysExact, RoutePolicy::Hybrid] {
        let coord = Coordinator::builder()
            .policy(policy)
            .max_batch(WINDOWS_PER_FRAME)
            .max_wait(Duration::from_micros(500))
            .start(model.clone(), am.clone())?;
        let client = coord.client();
        let mut rng = Rng::new(99);
        let mut frame_times = Vec::new();
        let mut detections = 0usize;
        for _frame in 0..FRAMES {
            // Synthesize one frame's windows: mostly background noise,
            // a few positive windows drawn near the positive class.
            let mut frame = Mat::zeros(WINDOWS_PER_FRAME, DESCRIPTOR_DIM);
            for w in 0..WINDOWS_PER_FRAME {
                let positive = rng.chance(0.02);
                let base = if positive { &train } else { &train };
                // Sample a real window of the right class as the seed.
                let mut idx = rng.below(base.len());
                while (base.y[idx] > 0.0) != positive {
                    idx = rng.below(base.len());
                }
                let src = base.x.row(idx);
                let dst = frame.row_mut(w);
                for j in 0..DESCRIPTOR_DIM {
                    dst[j] = src[j] + (rng.normal() * 0.05) as f32;
                }
            }
            let t0 = Instant::now();
            let responses = client.predict_all(&frame)?;
            frame_times.push(t0.elapsed().as_secs_f64());
            detections +=
                responses.iter().filter(|r| r.label > 0.0).count();
        }
        let s = approxrbf::util::Summary::from(&frame_times);
        let m = coord.metrics();
        println!(
            "\npolicy={:<7}  frame latency: mean {:.2} ms  p95 {:.2} ms  \
             ({:.0} windows/s)",
            policy.name(),
            s.mean * 1e3,
            s.p95 * 1e3,
            (WINDOWS_PER_FRAME * FRAMES) as f64
                / frame_times.iter().sum::<f64>()
        );
        println!(
            "  routes approx/exact: {}/{}  detections: {detections}",
            m.served_approx, m.served_exact
        );
        coord.shutdown()?;
    }
    println!(
        "\nThe hybrid policy reaches approx-model throughput while \
         retaining the paper's per-term error guarantee (Eq. 3.11)."
    );
    Ok(())
}
