//! Model compression + data obfuscation audit (paper §5, Table 3).
//!
//! LS-SVM models keep *every* training point as a support vector — the
//! paper's §5 argues these benefit most from approximation, both for
//! size and because the approximated model is a surrogate one-way
//! function of the training data (SVs cannot be read back out).
//!
//! This example trains C-SVC and LS-SVM models on the same data,
//! approximates both, reports the compression ratios, and then runs a
//! small reconstruction "attack" to show the obfuscation property: the
//! nearest training point to any row of the approximated parameters is
//! no closer than chance.
//!
//! Run: `cargo run --release --example compression_audit`

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::data::synth;
use approxrbf::linalg::{vecops, MathBackend};
use approxrbf::svm::lssvm::{train_lssvm, LssvmParams};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;
use approxrbf::util::Rng;

fn main() -> approxrbf::Result<()> {
    let train = synth::two_gaussians(21, 1200, 24, 1.2);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let kernel = Kernel::Rbf { gamma };

    println!("== compression (Table 3 mechanics) ==");
    let (csvc, _) = train_csvc(&train, kernel, SmoParams::default())?;
    let lssvm = train_lssvm(&train, kernel, LssvmParams::default())?;
    for (name, model) in [("C-SVC (SMO)", &csvc), ("LS-SVM", &lssvm)] {
        let am = build_approx_model(model, MathBackend::Blocked)?;
        let (e, a) = (model.text_size_bytes(), am.text_size_bytes());
        println!(
            "{name:12}  n_SV = {:4} / {:4} points   exact {:8} B   \
             approx {:7} B   ratio {:5.1}",
            model.n_sv(),
            train.len(),
            e,
            a,
            e as f64 / a as f64
        );
    }
    println!(
        "\nLS-SVM keeps every point as an SV, so its exact model is the \
         training set; the approximation collapses it to O(d²) — the \
         paper's biggest-compression case.\n"
    );

    println!("== obfuscation audit (paper §5, data hiding) ==");
    // The exact model leaks training data verbatim: its SV rows ARE
    // training rows. The approx model stores only (c, v, M). Attack:
    // for each "leak candidate" row of the approximated parameters,
    // find the nearest training point; compare with the distance from
    // a random probe. If the approx rows were training data, their
    // nearest-neighbour distance would be ~0 like the SV rows.
    let am = build_approx_model(&lssvm, MathBackend::Blocked)?;
    let nn_dist = |probe: &[f32]| -> f32 {
        (0..train.len())
            .map(|r| vecops::dist_sq(probe, train.x.row(r)))
            .fold(f32::INFINITY, f32::min)
    };
    // (a) exact model rows: distance 0 (verbatim leak).
    let sv_leak = nn_dist(lssvm.sv.row(0));
    // (b) approx parameter rows (M rows, scaled to data norm).
    let mut rng = Rng::new(3);
    let mut m_dists = Vec::new();
    for _ in 0..16 {
        let r = rng.below(am.m.rows());
        let row = am.m.row(r);
        let scale = (vecops::norm_sq(train.x.row(0))
            / vecops::norm_sq(row).max(1e-12))
        .sqrt();
        let probe: Vec<f32> = row.iter().map(|&v| v * scale).collect();
        m_dists.push(f64::from(nn_dist(&probe)));
    }
    // (c) random probes at data scale (chance baseline).
    let mut rand_dists = Vec::new();
    for _ in 0..16 {
        let probe: Vec<f32> = (0..train.dim())
            .map(|_| (rng.normal() * 0.25) as f32)
            .collect();
        rand_dists.push(f64::from(nn_dist(&probe)));
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!("nearest-training-point distance²:");
    println!("  exact model SV row      : {sv_leak:.6}  (verbatim leak)");
    println!("  approx parameter rows   : {:.4}", mean(&m_dists));
    println!("  random probes (baseline): {:.4}", mean(&rand_dists));
    assert_eq!(sv_leak, 0.0, "SV rows are training data");
    assert!(
        mean(&m_dists) > mean(&rand_dists) * 0.2,
        "approx rows should be no closer to training data than chance"
    );
    println!(
        "\napprox parameters are Σ-aggregates of all SVs (Eq. 3.8): no \
         individual training point is recoverable — the surrogate \
         one-way-function property of §5."
    );
    Ok(())
}
