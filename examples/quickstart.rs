//! Quickstart: the 60-second tour of the library.
//!
//! Trains a small RBF SVM, approximates it with the paper's
//! second-order Maclaurin scheme (Eq. 3.8), checks the validity bound
//! (Eq. 3.11), and compares accuracy + speed + model size.
//!
//! Run: `cargo run --release --example quickstart`

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::error_analysis;
use approxrbf::data::SynthProfile;
use approxrbf::linalg::MathBackend;
use approxrbf::predictor::{ApproxPredictor, Predictor};
use approxrbf::svm::predict::ExactPredictor;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;

fn main() -> approxrbf::Result<()> {
    // 1. Data: a synthetic stand-in for ijcnn1 (d = 22).
    let (train, test) = SynthProfile::ControlLike.generate(42, 2000, 2000);
    println!(
        "data: {} train / {} test, d = {}",
        train.len(),
        test.len(),
        train.dim()
    );

    // 2. The paper's pre-training bound: γ_MAX = 1/(4·max‖x‖²).
    let gamma_max = gamma_max_for_data(&train);
    let gamma = gamma_max * 0.8; // stay inside the guarantee
    println!("gamma_MAX = {gamma_max:.4}; training with gamma = {gamma:.4}");

    // 3. Train the exact model (SMO, the LIBSVM role).
    let t0 = std::time::Instant::now();
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    println!(
        "trained: {} SVs ({} bounded), {} iterations, {:.2}s",
        stats.n_sv,
        stats.n_bounded_sv,
        stats.iterations,
        t0.elapsed().as_secs_f64()
    );

    // 4. Approximate: O(n_SV·d) model → O(d²) model.
    let t0 = std::time::Instant::now();
    let am = build_approx_model(&model, MathBackend::Blocked)?;
    println!(
        "approximated in {:.4}s; ‖z‖² budget = {:.3}",
        t0.elapsed().as_secs_f64(),
        am.znorm_sq_budget()
    );

    // 5. Compare predictions.
    let t0 = std::time::Instant::now();
    let exact = ExactPredictor::new(&model, MathBackend::Loops)?;
    let _ = exact.decision_batch(&test.x)?;
    let t_exact = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let _ = am.decision_batch(&test.x, MathBackend::Blocked)?;
    let t_approx = t0.elapsed().as_secs_f64();
    let rep = error_analysis::compare(&model, &am, &test)?;
    println!("\n== results ==");
    println!("exact  predict: {t_exact:.4}s   acc {:.2}%", rep.exact_acc * 100.0);
    println!(
        "approx predict: {t_approx:.4}s   acc {:.2}%   ({:.0}x faster)",
        rep.approx_acc * 100.0,
        t_exact / t_approx
    );
    println!(
        "labels differing: {:.2}%   instances in bound: {:.1}%",
        rep.label_diff * 100.0,
        rep.in_bound_fraction * 100.0
    );
    println!(
        "model size: exact {} B -> approx {} B (ratio {:.1})",
        model.text_size_bytes(),
        am.text_size_bytes(),
        model.text_size_bytes() as f64 / am.text_size_bytes() as f64
    );

    // 6. One evaluation surface over every backend: the Predictor
    //    trait (the serving layer drives exact, approx and the XLA
    //    engine through exactly this interface).
    let approx_pred = ApproxPredictor::new(&am, MathBackend::Blocked)?;
    println!("\n== unified Predictor surface ==");
    for p in [&exact as &dyn Predictor, &approx_pred] {
        let f0 = p.predict_one(test.x.row(0))?;
        println!("{:<14} d={}  f(z_0) = {f0:.4}", p.kind(), p.dim());
    }
    Ok(())
}
