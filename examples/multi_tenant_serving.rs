//! Multi-tenant serving through the model registry (the scaling story
//! the paper's Table 3 enables: an approximated model is `O(d²)` bytes
//! regardless of `n_SV`, so one node can realistically host *many*
//! models).
//!
//! This example:
//!   1. trains three tenants on different synthetic profiles / γ
//!      settings and publishes each as an `.arbf` bundle into a
//!      directory-backed [`ModelStore`];
//!   2. serves a mixed-tenant workload through one hybrid-routing
//!      coordinator on the native executor — each tenant is routed with
//!      its *own* Eq. 3.11 budget;
//!   3. republishes one tenant mid-stream (hot swap) and shows the
//!      generation change taking effect without a single dropped or
//!      failed in-flight request;
//!   4. prints the per-model route mix / latency table from the metrics
//!      snapshot.
//!
//! Run: `cargo run --release --example multi_tenant_serving`

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::{
    Coordinator, CoordinatorConfig, Route, RoutePolicy,
};
use approxrbf::data::{Dataset, SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::registry::ModelStore;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::Rng;

const REQUESTS: usize = 9_000;

struct TenantSpec {
    id: &'static str,
    profile: SynthProfile,
    n_train: usize,
    seed: u64,
    gamma_mult: f32,
    /// Fraction of this tenant's traffic adversarially scaled outside
    /// the validity bound (exercises per-tenant hybrid routing).
    oob_traffic: f64,
}

const TENANTS: [TenantSpec; 3] = [
    TenantSpec {
        id: "control-a",
        profile: SynthProfile::ControlLike,
        n_train: 700,
        seed: 11,
        gamma_mult: 0.8,
        oob_traffic: 0.0,
    },
    TenantSpec {
        id: "control-b",
        profile: SynthProfile::ControlLike,
        n_train: 700,
        seed: 22,
        gamma_mult: 1.3, // γ > γ_MAX: the bound fails ⇒ exact escort
        oob_traffic: 0.0,
    },
    TenantSpec {
        id: "adult",
        profile: SynthProfile::AdultLike,
        n_train: 500,
        seed: 33,
        gamma_mult: 0.8,
        oob_traffic: 0.25, // mixed route profile
    },
];

fn train_tenant(
    spec: &TenantSpec,
    seed: u64,
) -> approxrbf::Result<(SvmModel, ApproxModel, Dataset)> {
    let (raw_train, raw_test) =
        spec.profile.generate(seed, spec.n_train, spec.n_train);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * spec.gamma_mult;
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    let am = build_approx_model(&model, MathBackend::Blocked)?;
    println!(
        "  trained '{}' ({}, d={}): n_sv={} γ/γ_MAX={:.2}",
        spec.id,
        spec.profile.name(),
        train.dim(),
        stats.n_sv,
        spec.gamma_mult
    );
    Ok((model, am, test))
}

fn main() -> approxrbf::Result<()> {
    // ---------- publish phase ----------
    let dir = std::env::temp_dir().join("approxrbf_multi_tenant_example");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir)?);
    println!("[publish] registry at {}", dir.display());
    let mut tests: HashMap<&'static str, Dataset> = HashMap::new();
    for spec in &TENANTS {
        let (model, am, test) = train_tenant(spec, spec.seed)?;
        let generation = store.publish(spec.id, &model, &am)?;
        let info = store.peek(spec.id)?;
        println!(
            "  published '{}' generation {generation} ({} B binary bundle)",
            spec.id, info.size_bytes
        );
        tests.insert(spec.id, test);
    }

    // ---------- serve a mixed-tenant workload ----------
    let coord = Coordinator::start_registry(
        store.clone(),
        CoordinatorConfig {
            policy: RoutePolicy::Hybrid,
            max_wait: Duration::from_micros(500),
            swap_poll: Duration::from_millis(20),
            ..Default::default()
        },
    )?;
    println!(
        "\n[serve] {REQUESTS} requests round-robin across {} tenants…",
        TENANTS.len()
    );
    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut responses = Vec::with_capacity(REQUESTS);
    let mut submitted = 0usize;
    let mut swapped = false;
    while responses.len() < REQUESTS {
        if submitted < REQUESTS {
            let spec = &TENANTS[submitted % TENANTS.len()];
            let test = &tests[spec.id];
            let row = (submitted / TENANTS.len()) % test.len();
            let mut z = test.x.row(row).to_vec();
            if rng.chance(spec.oob_traffic) {
                let s = rng.range(2.5, 5.0) as f32;
                for v in &mut z {
                    *v *= s; // push ‖z‖² past the tenant's budget
                }
            }
            coord.submit_to(spec.id, z)?;
            submitted += 1;
        }
        // Mid-stream: republish tenant 'control-a' (a retrain) and ask
        // the coordinator to pick it up — the hot swap.
        if !swapped && submitted == REQUESTS / 2 {
            let spec = &TENANTS[0];
            let (model2, am2, _) = train_tenant(spec, spec.seed + 1000)?;
            let generation = store.publish(spec.id, &model2, &am2)?;
            coord.refresh();
            println!(
                "[swap] republished '{}' as generation {generation} \
                 mid-stream ({} requests in flight)",
                spec.id,
                submitted - responses.len()
            );
            swapped = true;
        }
        while let Some(r) = coord.recv(Duration::from_micros(0)) {
            responses.push(r);
        }
        if submitted >= REQUESTS {
            while responses.len() < REQUESTS {
                match coord.recv(Duration::from_millis(200)) {
                    Some(r) => responses.push(r),
                    None => {
                        return Err(approxrbf::Error::Other(
                            "lost responses".into(),
                        ))
                    }
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---------- report ----------
    // Invariants: every request answered exactly once; under Hybrid no
    // approx-routed response may violate its tenant's bound.
    assert_eq!(responses.len(), REQUESTS);
    assert!(responses
        .iter()
        .all(|r| r.route != Route::Approx || r.in_bound));
    let mut generations: HashMap<(String, u64), usize> = HashMap::new();
    for r in &responses {
        *generations.entry((r.model.to_string(), r.generation)).or_insert(0) +=
            1;
    }
    println!(
        "\n== multi-tenant results ==\nthroughput : {:.0} req/s \
         ({REQUESTS} requests in {wall:.2}s)\n",
        REQUESTS as f64 / wall
    );
    let snapshot = coord.metrics();
    print!("{}", snapshot.per_model_table());
    println!("\nserved generations per tenant:");
    let mut gen_rows: Vec<_> = generations.into_iter().collect();
    gen_rows.sort();
    for ((model, generation), count) in gen_rows {
        println!("  {model:<12} gen {generation}: {count} responses");
    }
    println!(
        "\n'control-a' traffic was served by generation 1 before the \
         republish and generation 2 after it — no request was dropped \
         or failed across the swap."
    );
    coord.shutdown()?;
    Ok(())
}
