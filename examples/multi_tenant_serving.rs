//! Multi-tenant serving through the model registry (the scaling story
//! the paper's Table 3 enables: an approximated model is `O(d²)` bytes
//! regardless of `n_SV`, so one node can realistically host *many*
//! models).
//!
//! This example:
//!   1. trains three tenants on different synthetic profiles / γ
//!      settings and publishes each as an `.arbf` bundle into a
//!      directory-backed [`ModelStore`] — `control-a` ships with a
//!      [`TenantPolicy`] pinning it to the exact path, `adult` is
//!      published warm (cache pre-seeded before its first request);
//!   2. serves a mixed-tenant workload through a two-shard
//!      hybrid-routing coordinator via the cloneable [`Client`] API —
//!      each tenant is placed on its owning shard (rendezvous hashing)
//!      and routed with its *own* Eq. 3.11 budget and policy;
//!   3. republishes `control-a` mid-stream *without* the policy (hot
//!      swap): its served route mix flips from all-exact to all-approx
//!      with zero dropped or failed in-flight requests;
//!   4. prints the per-model route mix / latency table from the metrics
//!      snapshot.
//!
//! Run: `cargo run --release --example multi_tenant_serving`

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::{Coordinator, Route, RoutePolicy, TenantPolicy};
use approxrbf::data::{Dataset, SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::registry::{ModelStore, PublishOptions};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::Rng;

const REQUESTS: usize = 9_000;

struct TenantSpec {
    id: &'static str,
    profile: SynthProfile,
    n_train: usize,
    seed: u64,
    gamma_mult: f32,
    /// Fraction of this tenant's traffic adversarially scaled outside
    /// the validity bound (exercises per-tenant hybrid routing).
    oob_traffic: f64,
}

const TENANTS: [TenantSpec; 3] = [
    TenantSpec {
        id: "control-a",
        profile: SynthProfile::ControlLike,
        n_train: 700,
        seed: 11,
        gamma_mult: 0.8,
        oob_traffic: 0.0,
    },
    TenantSpec {
        id: "control-b",
        profile: SynthProfile::ControlLike,
        n_train: 700,
        seed: 22,
        gamma_mult: 1.3, // γ > γ_MAX: the bound fails ⇒ exact escort
        oob_traffic: 0.0,
    },
    TenantSpec {
        id: "adult",
        profile: SynthProfile::AdultLike,
        n_train: 500,
        seed: 33,
        gamma_mult: 0.8,
        oob_traffic: 0.25, // mixed route profile
    },
];

fn train_tenant(
    spec: &TenantSpec,
    seed: u64,
) -> approxrbf::Result<(SvmModel, ApproxModel, Dataset)> {
    let (raw_train, raw_test) =
        spec.profile.generate(seed, spec.n_train, spec.n_train);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * spec.gamma_mult;
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    let am = build_approx_model(&model, MathBackend::Blocked)?;
    println!(
        "  trained '{}' ({}, d={}): n_sv={} γ/γ_MAX={:.2}",
        spec.id,
        spec.profile.name(),
        train.dim(),
        stats.n_sv,
        spec.gamma_mult
    );
    Ok((model, am, test))
}

fn main() -> approxrbf::Result<()> {
    // ---------- publish phase ----------
    let dir = std::env::temp_dir().join("approxrbf_multi_tenant_example");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir)?);
    println!("[publish] registry at {}", dir.display());
    let mut tests: HashMap<&'static str, Dataset> = HashMap::new();
    for spec in &TENANTS {
        let (model, am, test) = train_tenant(spec, spec.seed)?;
        // Per-tenant policy travels inside the bundle: 'control-a' is
        // pinned to the exact path (e.g. a tenant whose SLA forbids
        // any approximation), 'adult' is published warm so its first
        // request skips the cold decode.
        let opts = match spec.id {
            "control-a" => PublishOptions {
                policy: Some(TenantPolicy {
                    route: Some(RoutePolicy::AlwaysExact),
                    ..Default::default()
                }),
                ..Default::default()
            },
            // 'adult' is published warm AND int8-quantized: a ~4×
            // smaller resident model whose dequantization drift is
            // folded into its routing budget.
            "adult" => PublishOptions {
                warm: true,
                quantize: Some(
                    approxrbf::registry::PayloadKind::Int8,
                ),
                ..Default::default()
            },
            _ => PublishOptions::default(),
        };
        let described = if opts.policy.is_some() {
            " policy=always-exact"
        } else if opts.warm {
            " (warm)"
        } else {
            ""
        };
        let generation = store.publish_with(spec.id, &model, &am, opts)?;
        let info = store.peek(spec.id)?;
        println!(
            "  published '{}' generation {generation} ({} B binary \
             bundle){described}",
            spec.id, info.size_bytes
        );
        tests.insert(spec.id, test);
    }

    // ---------- serve a mixed-tenant workload ----------
    let coord = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .max_wait(Duration::from_micros(500))
        .swap_poll(Duration::from_millis(20))
        .shards(2)
        .start_registry(store.clone())?;
    let client = coord.client();
    println!(
        "\n[serve] {REQUESTS} requests round-robin across {} tenants \
         on {} shards…",
        TENANTS.len(),
        coord.shard_count()
    );
    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut responses = Vec::with_capacity(REQUESTS);
    let mut submitted = 0usize;
    let mut swapped = false;
    while responses.len() < REQUESTS {
        if submitted < REQUESTS {
            let spec = &TENANTS[submitted % TENANTS.len()];
            let test = &tests[spec.id];
            let row = (submitted / TENANTS.len()) % test.len();
            let mut z = test.x.row(row).to_vec();
            if rng.chance(spec.oob_traffic) {
                let s = rng.range(2.5, 5.0) as f32;
                for v in &mut z {
                    *v *= s; // push ‖z‖² past the tenant's budget
                }
            }
            client.submit_to(spec.id, z)?;
            submitted += 1;
        }
        // Mid-stream: republish tenant 'control-a' (a retrain, this
        // time with no pinned policy) and ask the coordinator to pick
        // it up — the hot swap changes weights AND route policy.
        if !swapped && submitted == REQUESTS / 2 {
            let spec = &TENANTS[0];
            let (model2, am2, _) = train_tenant(spec, spec.seed + 1000)?;
            let generation = store.publish(spec.id, &model2, &am2)?;
            coord.refresh();
            println!(
                "[swap] republished '{}' as generation {generation} \
                 (policy dropped) mid-stream ({} requests in flight)",
                spec.id,
                submitted - responses.len()
            );
            swapped = true;
        }
        // Completions are typed; any fail-fast error aborts the demo
        // with its cause instead of a silent drop.
        while let Some(c) = client.recv(Duration::from_micros(0)) {
            responses.push(c?);
        }
        if submitted >= REQUESTS {
            while responses.len() < REQUESTS {
                match client.recv(Duration::from_millis(200)) {
                    Some(c) => responses.push(c?),
                    None => {
                        return Err(approxrbf::Error::Other(
                            "lost responses".into(),
                        ))
                    }
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---------- report ----------
    // Invariants: every request answered exactly once; under Hybrid no
    // approx-routed response may violate its tenant's bound; and the
    // published policy controlled 'control-a's route mix: all-exact
    // while generation 1 (pinned) served, all-approx after the swap
    // dropped the pin (its traffic is in-bound).
    assert_eq!(responses.len(), REQUESTS);
    assert!(responses
        .iter()
        .all(|r| r.route != Route::Approx || r.in_bound));
    for r in &responses {
        if &*r.model == "control-a" {
            match r.generation {
                1 => assert_eq!(
                    r.route,
                    Route::Exact,
                    "generation 1 is policy-pinned to exact"
                ),
                _ => assert_eq!(
                    r.route,
                    Route::Approx,
                    "post-swap control-a is hybrid and in-bound"
                ),
            }
        }
    }
    let mut generations: HashMap<(String, u64), usize> = HashMap::new();
    for r in &responses {
        *generations.entry((r.model.to_string(), r.generation)).or_insert(0) +=
            1;
    }
    println!(
        "\n== multi-tenant results ==\nthroughput : {:.0} req/s \
         ({REQUESTS} requests in {wall:.2}s)\n",
        REQUESTS as f64 / wall
    );
    let snapshot = coord.metrics();
    print!("{}", snapshot.per_model_table());
    println!("\nserved generations per tenant:");
    let mut gen_rows: Vec<_> = generations.into_iter().collect();
    gen_rows.sort();
    for ((model, generation), count) in gen_rows {
        println!("  {model:<12} gen {generation}: {count} responses");
    }
    println!(
        "\n'control-a' was served exact-only by generation 1 (its \
         published TenantPolicy) and approx by generation 2 (policy \
         dropped at republish) — the route mix followed the bundle, \
         and no request was dropped or failed across the swap."
    );
    coord.shutdown()?;
    Ok(())
}
