//! Stub of the `xla` (xla-rs) crate API surface used by `approxrbf`.
//!
//! This crate exists so the `pjrt` feature of `approxrbf` always
//! *compiles*, even on machines without the PJRT runtime or the real
//! xla-rs bindings. Every entry point fails fast at run time with a
//! clear message from [`PjRtClient::cpu`] — the only constructor — so
//! no stubbed compute path can ever be silently exercised.
//!
//! To run on real PJRT, replace the `xla = { path = "vendor/xla" }`
//! dependency in `rust/Cargo.toml` with the actual xla-rs crate (same
//! API) and rebuild with `--features pjrt`.

use std::borrow::Borrow;

/// Error type mirroring `xla::Error` (approxrbf only calls
/// `to_string()` on it).
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT is unavailable: approxrbf was built against the in-tree \
         `xla` stub (rust/vendor/xla). Install the real xla-rs crate + \
         PJRT plugin and point rust/Cargo.toml at it to enable the XLA \
         execution path."
            .into(),
    ))
}

/// Host-side tensor value.
#[derive(Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Compilable computation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub — see the crate docs.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}
