//! PJRT runtime: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py` (`make artifacts`) and executes them from the
//! Rust hot path. This is the "vendor math library" slot of the paper's
//! LOOPS/BLAS/ATLAS axis, and the only place the compiled L1/L2 compute
//! graphs are touched at run time — Python is never invoked.
//!
//! The engine (and its `xla` dependency) only compiles with the `pjrt`
//! feature, so tier-1 builds work on machines without PJRT; the
//! artifact [`Manifest`] stays available unconditionally for tooling.

#![forbid(unsafe_code)]

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::{
    Engine, EngineApproxPredictor, EngineExactPredictor, PreparedApprox,
    PreparedExact,
};
pub use manifest::{ArtifactEntry, ArtifactKind, ImplKind, Manifest};
