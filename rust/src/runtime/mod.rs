//! PJRT runtime: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py` (`make artifacts`) and executes them from the
//! Rust hot path. This is the "vendor math library" slot of the paper's
//! LOOPS/BLAS/ATLAS axis, and the only place the compiled L1/L2 compute
//! graphs are touched at run time — Python is never invoked.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, PreparedApprox, PreparedExact};
pub use manifest::{ArtifactEntry, ArtifactKind, ImplKind, Manifest};
