//! Artifact manifest: `artifacts/manifest.txt`, one line per AOT-lowered
//! executable, written by `python/compile/aot.py`:
//!
//! ```text
//! kind=approx impl=jnp d=128 nsv=0 batch=256 outputs=2 file=approx_jnp_d128_b256.hlo.txt
//! ```
//!
//! The Rust side selects the smallest shape bucket that fits a request
//! and pads inputs (see `python/compile/kernels/ref.py` for the padding
//! contract: zero-coef SVs and zero feature columns are exact no-ops).

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// What a compiled artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// f̂(z) via (c, v, M): outputs (decisions, ‖z‖²).
    Approx,
    /// f(z) via the SVs: outputs (decisions,).
    Exact,
    /// (c, v, M) from the SVs: outputs 3.
    Build,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "approx" => Ok(ArtifactKind::Approx),
            "exact" => Ok(ArtifactKind::Exact),
            "build" => Ok(ArtifactKind::Build),
            other => Err(Error::Parse(format!("unknown kind '{other}'"))),
        }
    }
}

/// Which L2 implementation produced the artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ImplKind {
    /// Pure-jnp lowering (XLA-fused; the performance artifact).
    Jnp,
    /// Pallas interpret-mode lowering (structure/correctness artifact).
    Pallas,
}

impl ImplKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "jnp" => Ok(ImplKind::Jnp),
            "pallas" => Ok(ImplKind::Pallas),
            other => Err(Error::Parse(format!("unknown impl '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ImplKind::Jnp => "jnp",
            ImplKind::Pallas => "pallas",
        }
    }
}

/// One manifest line.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub impl_kind: ImplKind,
    pub d: usize,
    pub nsv: usize,
    pub batch: usize,
    pub outputs: usize,
    pub file: String,
}

/// Parsed manifest with bucket selection.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Other(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut kind = None;
            let mut impl_kind = None;
            let (mut d, mut nsv, mut batch, mut outputs) = (0, 0, 0, 0);
            let mut file = String::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    Error::Parse(format!("bad manifest token '{tok}'"))
                })?;
                match k {
                    "kind" => kind = Some(ArtifactKind::parse(v)?),
                    "impl" => impl_kind = Some(ImplKind::parse(v)?),
                    "d" => d = parse_usize(v)?,
                    "nsv" => nsv = parse_usize(v)?,
                    "batch" => batch = parse_usize(v)?,
                    "outputs" => outputs = parse_usize(v)?,
                    "file" => file = v.to_string(),
                    other => {
                        return Err(Error::Parse(format!(
                            "unknown manifest key '{other}'"
                        )))
                    }
                }
            }
            entries.push(ArtifactEntry {
                kind: kind
                    .ok_or_else(|| Error::Parse("missing kind".into()))?,
                impl_kind: impl_kind
                    .ok_or_else(|| Error::Parse("missing impl".into()))?,
                d,
                nsv,
                batch,
                outputs,
                file,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Smallest bucket of `kind`/`impl_kind` with `d ≥ need_d` and
    /// (when applicable) `nsv ≥ need_nsv`. Ties break toward smaller
    /// padding waste, then toward the smallest batch.
    pub fn select(
        &self,
        kind: ArtifactKind,
        impl_kind: ImplKind,
        need_d: usize,
        need_nsv: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.impl_kind == impl_kind
                    && e.d >= need_d
                    && (kind == ArtifactKind::Approx || e.nsv >= need_nsv)
            })
            .min_by_key(|e| (e.d, e.nsv, e.batch))
    }

    /// Like [`Manifest::select`] but preferring the largest batch
    /// bucket ≤ `batch_hint` (falling back to the smallest available).
    /// Bulk offline prediction uses this to amortize per-execute
    /// overhead (§Perf L3-P3); latency-sensitive serving keeps the
    /// small bucket.
    pub fn select_bulk(
        &self,
        kind: ArtifactKind,
        impl_kind: ImplKind,
        need_d: usize,
        need_nsv: usize,
        batch_hint: usize,
    ) -> Option<&ArtifactEntry> {
        let candidates: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.impl_kind == impl_kind
                    && e.d >= need_d
                    && (kind == ArtifactKind::Approx || e.nsv >= need_nsv)
            })
            .collect();
        let min_d = candidates.iter().map(|e| e.d).min()?;
        candidates
            .into_iter()
            .filter(|e| e.d == min_d)
            .filter(|e| e.batch <= batch_hint.max(1))
            .max_by_key(|e| e.batch)
            .or_else(|| self.select(kind, impl_kind, need_d, need_nsv))
    }

    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

fn parse_usize(s: &str) -> Result<usize> {
    s.parse()
        .map_err(|_| Error::Parse(format!("bad manifest integer '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
kind=approx impl=jnp d=32 nsv=0 batch=256 outputs=2 file=a32.hlo.txt
kind=approx impl=jnp d=128 nsv=0 batch=256 outputs=2 file=a128.hlo.txt
kind=exact impl=jnp d=32 nsv=1024 batch=256 outputs=1 file=e32_1k.hlo.txt
kind=exact impl=jnp d=32 nsv=4096 batch=256 outputs=1 file=e32_4k.hlo.txt
kind=build impl=pallas d=32 nsv=1024 batch=0 outputs=3 file=b32.hlo.txt
";

    fn manifest() -> Manifest {
        Manifest::parse(Path::new("/tmp/art"), SAMPLE).unwrap()
    }

    #[test]
    fn parse_all_lines() {
        let m = manifest();
        assert_eq!(m.entries.len(), 5);
        assert_eq!(m.entries[0].kind, ArtifactKind::Approx);
        assert_eq!(m.entries[4].impl_kind, ImplKind::Pallas);
        assert_eq!(m.entries[3].nsv, 4096);
    }

    #[test]
    fn select_smallest_fitting_bucket() {
        let m = manifest();
        let e = m
            .select(ArtifactKind::Approx, ImplKind::Jnp, 22, 0)
            .unwrap();
        assert_eq!(e.d, 32);
        let e = m
            .select(ArtifactKind::Approx, ImplKind::Jnp, 33, 0)
            .unwrap();
        assert_eq!(e.d, 128);
        let e = m
            .select(ArtifactKind::Exact, ImplKind::Jnp, 22, 2000)
            .unwrap();
        assert_eq!(e.nsv, 4096);
        assert!(m.select(ArtifactKind::Approx, ImplKind::Jnp, 999, 0).is_none());
        assert!(m
            .select(ArtifactKind::Exact, ImplKind::Jnp, 22, 9999)
            .is_none());
    }

    #[test]
    fn approx_selection_ignores_nsv() {
        let m = manifest();
        assert!(m
            .select(ArtifactKind::Approx, ImplKind::Jnp, 22, 123_456)
            .is_some());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "kind=approx junk").is_err());
        assert!(Manifest::parse(Path::new("."), "kind=wat impl=jnp").is_err());
        assert!(
            Manifest::parse(Path::new("."), "impl=jnp d=1 file=x").is_err()
        );
    }
}
