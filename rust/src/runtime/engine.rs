//! PJRT execution engine.
//!
//! Loads HLO-text artifacts (see module docs in [`super::manifest`]),
//! compiles each shape bucket once (lazily, cached), pads request
//! tensors to the bucket shape, executes, and unpads the results.
//!
//! Thread model: PJRT handles are not `Send`, so the [`Engine`] is
//! deliberately single-threaded; the coordinator dedicates one executor
//! thread to it and feeds it via channels (see `coordinator::worker`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use crate::approx::ApproxModel;
use crate::log_debug;
use crate::linalg::Mat;
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

use super::manifest::{ArtifactEntry, ArtifactKind, ImplKind, Manifest};

/// PJRT engine over an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Preferred L2 implementation (jnp = performance, pallas = the
    /// paper-faithful tiled kernels).
    pub impl_kind: ImplKind,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

/// An approx model padded + uploaded once, reusable across batches:
/// the serving hot path never re-pads `M`.
pub struct PreparedApprox {
    entry: ArtifactEntry,
    m_lit: xla::Literal,
    v_lit: xla::Literal,
    s_lit: xla::Literal,
    pub d: usize,
    pub d_pad: usize,
    pub batch: usize,
}

/// An exact model padded + uploaded once (SVs, coefs, scalars).
pub struct PreparedExact {
    entry: ArtifactEntry,
    x_lit: xla::Literal,
    coef_lit: xla::Literal,
    s_lit: xla::Literal,
    pub d: usize,
    pub d_pad: usize,
    pub batch: usize,
}

impl Engine {
    /// Load the manifest and connect the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log_debug!(
            "pjrt: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.entries.len()
        );
        let impl_kind = match std::env::var("APPROXRBF_IMPL").ok().as_deref() {
            Some("pallas") => ImplKind::Pallas,
            _ => ImplKind::Jnp,
        };
        Ok(Engine { client, manifest, impl_kind, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact.
    fn executable(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&entry.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(entry);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Other("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        log_debug!(
            "compiled {} in {:.1} ms",
            entry.file,
            t0.elapsed().as_secs_f64() * 1e3
        );
        self.cache.borrow_mut().insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    fn select(
        &self,
        kind: ArtifactKind,
        d: usize,
        nsv: usize,
    ) -> Result<ArtifactEntry> {
        self.manifest
            .select(kind, self.impl_kind, d, nsv)
            .cloned()
            .ok_or_else(|| {
                Error::Other(format!(
                    "no {kind:?}/{:?} artifact for d={d} nsv={nsv} \
                     (re-run `make artifacts` with larger buckets)",
                    self.impl_kind
                ))
            })
    }

    // ---------- approx predict ----------

    /// Pad + upload an approx model once (latency bucket, batch=256).
    pub fn prepare_approx(&self, am: &ApproxModel) -> Result<PreparedApprox> {
        let d = am.dim();
        let entry = self.select(ArtifactKind::Approx, d, 0)?;
        self.prepare_approx_entry(am, entry)
    }

    /// Bulk variant: prefers the largest batch bucket ≤ `batch_hint`,
    /// amortizing per-execute overhead for offline prediction
    /// (EXPERIMENTS.md §Perf L3-P3).
    pub fn prepare_approx_bulk(
        &self,
        am: &ApproxModel,
        batch_hint: usize,
    ) -> Result<PreparedApprox> {
        let d = am.dim();
        let entry = self
            .manifest
            .select_bulk(ArtifactKind::Approx, self.impl_kind, d, 0, batch_hint)
            .cloned()
            .ok_or_else(|| {
                Error::Other(format!("no approx artifact for d={d}"))
            })?;
        self.prepare_approx_entry(am, entry)
    }

    fn prepare_approx_entry(
        &self,
        am: &ApproxModel,
        entry: ArtifactEntry,
    ) -> Result<PreparedApprox> {
        let d = am.dim();
        let dp = entry.d;
        let m_pad = am.m.pad_to(dp, dp);
        let mut v_pad = am.v.clone();
        v_pad.resize(dp, 0.0);
        let m_lit =
            xla::Literal::vec1(m_pad.as_slice()).reshape(&[dp as i64, dp as i64])?;
        let v_lit = xla::Literal::vec1(&v_pad);
        let s_lit = xla::Literal::vec1(&[am.c, am.gamma, am.b]);
        Ok(PreparedApprox {
            batch: entry.batch,
            entry,
            m_lit,
            v_lit,
            s_lit,
            d,
            d_pad: dp,
        })
    }

    /// Approximated decisions for a batch. Returns (decisions, ‖z‖²).
    pub fn approx_predict(
        &self,
        prep: &PreparedApprox,
        z: &Mat,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if z.cols() != prep.d {
            return Err(Error::Shape(format!(
                "batch dim {} vs prepared dim {}",
                z.cols(),
                prep.d
            )));
        }
        let exe = self.executable(&prep.entry)?;
        let bt = prep.batch;
        let mut dec = Vec::with_capacity(z.rows());
        let mut norms = Vec::with_capacity(z.rows());
        let mut row0 = 0;
        while row0 < z.rows() {
            let take = bt.min(z.rows() - row0);
            let chunk = z.rows_slice(row0, take).pad_to(bt, prep.d_pad);
            let z_lit = xla::Literal::vec1(chunk.as_slice())
                .reshape(&[bt as i64, prep.d_pad as i64])?;
            let result = exe.execute::<&xla::Literal>(&[
                &z_lit,
                &prep.m_lit,
                &prep.v_lit,
                &prep.s_lit,
            ])?[0][0]
                .to_literal_sync()?;
            let (d_out, n_out) = result.to_tuple2()?;
            let d_vec = d_out.to_vec::<f32>()?;
            let n_vec = n_out.to_vec::<f32>()?;
            dec.extend_from_slice(&d_vec[..take]);
            norms.extend_from_slice(&n_vec[..take]);
            row0 += take;
        }
        Ok((dec, norms))
    }

    // ---------- exact predict ----------

    /// Pad + upload an exact RBF model once. Padded SVs carry coef = 0
    /// (exact no-ops per the padding contract).
    pub fn prepare_exact(&self, model: &SvmModel) -> Result<PreparedExact> {
        let gamma = match model.kernel {
            Kernel::Rbf { gamma } => gamma,
            ref k => {
                return Err(Error::InvalidArg(format!(
                    "exact artifacts are RBF-only, got {}",
                    k.name()
                )))
            }
        };
        let d = model.dim();
        let n = model.n_sv();
        let entry = self.select(ArtifactKind::Exact, d, n)?;
        let (dp, np) = (entry.d, entry.nsv);
        let x_pad = model.sv.pad_to(np, dp);
        let mut coef_pad = model.coef.clone();
        coef_pad.resize(np, 0.0);
        let x_lit = xla::Literal::vec1(x_pad.as_slice())
            .reshape(&[np as i64, dp as i64])?;
        let coef_lit = xla::Literal::vec1(&coef_pad);
        let s_lit = xla::Literal::vec1(&[gamma, model.b]);
        Ok(PreparedExact {
            batch: entry.batch,
            entry,
            x_lit,
            coef_lit,
            s_lit,
            d,
            d_pad: dp,
        })
    }

    /// Exact decisions for a batch.
    pub fn exact_predict(
        &self,
        prep: &PreparedExact,
        z: &Mat,
    ) -> Result<Vec<f32>> {
        if z.cols() != prep.d {
            return Err(Error::Shape(format!(
                "batch dim {} vs prepared dim {}",
                z.cols(),
                prep.d
            )));
        }
        let exe = self.executable(&prep.entry)?;
        let bt = prep.batch;
        let mut dec = Vec::with_capacity(z.rows());
        let mut row0 = 0;
        while row0 < z.rows() {
            let take = bt.min(z.rows() - row0);
            let chunk = z.rows_slice(row0, take).pad_to(bt, prep.d_pad);
            let z_lit = xla::Literal::vec1(chunk.as_slice())
                .reshape(&[bt as i64, prep.d_pad as i64])?;
            let result = exe.execute::<&xla::Literal>(&[
                &z_lit,
                &prep.x_lit,
                &prep.coef_lit,
                &prep.s_lit,
            ])?[0][0]
                .to_literal_sync()?;
            let d_out = result.to_tuple1()?;
            let d_vec = d_out.to_vec::<f32>()?;
            dec.extend_from_slice(&d_vec[..take]);
            row0 += take;
        }
        Ok(dec)
    }

    // ---------- build ----------

    /// Build an [`ApproxModel`] on the XLA backend (the paper's t_approx
    /// stage executed as the AOT `build` artifact).
    pub fn build_approx(&self, model: &SvmModel) -> Result<ApproxModel> {
        let gamma = match model.kernel {
            Kernel::Rbf { gamma } => gamma,
            ref k => {
                return Err(Error::InvalidArg(format!(
                    "approximation requires RBF, got {}",
                    k.name()
                )))
            }
        };
        let d = model.dim();
        let n = model.n_sv();
        let entry = self.select(ArtifactKind::Build, d, n)?;
        let (dp, np) = (entry.d, entry.nsv);
        let exe = self.executable(&entry)?;
        let x_pad = model.sv.pad_to(np, dp);
        let mut coef_pad = model.coef.clone();
        coef_pad.resize(np, 0.0);
        let x_lit = xla::Literal::vec1(x_pad.as_slice())
            .reshape(&[np as i64, dp as i64])?;
        let coef_lit = xla::Literal::vec1(&coef_pad);
        let g_lit = xla::Literal::vec1(&[gamma]);
        let result = exe.execute::<&xla::Literal>(&[&x_lit, &coef_lit, &g_lit])?
            [0][0]
            .to_literal_sync()?;
        let (c_out, v_out, m_out) = result.to_tuple3()?;
        let c = c_out.to_vec::<f32>()?[0];
        let v_full = v_out.to_vec::<f32>()?;
        let m_full = m_out.to_vec::<f32>()?;
        // Unpad: take the leading d×d block / d prefix.
        let mut m = Mat::zeros(d, d);
        for r in 0..d {
            m.row_mut(r).copy_from_slice(&m_full[r * dp..r * dp + d]);
        }
        Ok(ApproxModel {
            gamma,
            b: model.b,
            c,
            v: v_full[..d].to_vec(),
            m,
            max_sv_norm_sq: model.max_sv_norm_sq(),
        })
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// The XLA approx path as a [`crate::predictor::Predictor`]: borrows
/// the engine and a prepared (padded + uploaded) model, so the serving
/// executor can cache the preparation per generation and hand the
/// cheap wrapper to the uniform evaluation surface per batch.
pub struct EngineApproxPredictor<'e> {
    engine: &'e Engine,
    prepared: &'e PreparedApprox,
}

impl<'e> EngineApproxPredictor<'e> {
    pub fn new(
        engine: &'e Engine,
        prepared: &'e PreparedApprox,
    ) -> EngineApproxPredictor<'e> {
        EngineApproxPredictor { engine, prepared }
    }
}

impl crate::predictor::Predictor for EngineApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.prepared.d
    }

    fn kind(&self) -> &'static str {
        "approx-xla"
    }

    fn predict_batch(
        &self,
        z: &Mat,
    ) -> Result<crate::predictor::PredictOutput> {
        let (decisions, norms) =
            self.engine.approx_predict(self.prepared, z)?;
        Ok(crate::predictor::PredictOutput {
            decisions,
            znorms_sq: Some(norms),
        })
    }
}

/// The XLA exact path as a [`crate::predictor::Predictor`].
pub struct EngineExactPredictor<'e> {
    engine: &'e Engine,
    prepared: &'e PreparedExact,
}

impl<'e> EngineExactPredictor<'e> {
    pub fn new(
        engine: &'e Engine,
        prepared: &'e PreparedExact,
    ) -> EngineExactPredictor<'e> {
        EngineExactPredictor { engine, prepared }
    }
}

impl crate::predictor::Predictor for EngineExactPredictor<'_> {
    fn dim(&self) -> usize {
        self.prepared.d
    }

    fn kind(&self) -> &'static str {
        "exact-xla"
    }

    fn predict_batch(
        &self,
        z: &Mat,
    ) -> Result<crate::predictor::PredictOutput> {
        let decisions = self.engine.exact_predict(self.prepared, z)?;
        Ok(crate::predictor::PredictOutput { decisions, znorms_sq: None })
    }
}
