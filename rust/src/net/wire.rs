//! `ARBW` wire protocol: length-prefixed, CRC32-checked binary frames
//! over a byte stream.
//!
//! The frame discipline deliberately mirrors the `.arbf` record format
//! (`crate::registry::binfmt`): a fixed little-endian header carrying a
//! magic, a kind tag, a CRC32 of the payload and the payload length —
//! with the same alloc-bomb caps (a length field is *never* trusted
//! before it is bounds-checked) and the same typed
//! [`Error::Corrupt`](crate::Error::Corrupt) on any damage: bad magic,
//! unknown kind, checksum mismatch, truncation, trailing bytes.
//!
//! ```text
//! frame   := header payload
//! header  := magic[4]="ARBW" kind:u16 reserved:u16 crc32:u32 len:u32
//! payload := kind-specific body, len bytes, crc32 over payload only
//! ```
//!
//! Messages (kind tags):
//!
//! | tag | message       | direction        | body                       |
//! |-----|---------------|------------------|----------------------------|
//! | 1   | `Hello`       | client → server  | protocol version, client   |
//! | 2   | `HelloAck`    | server → client  | version, shard id/count, dim table |
//! | 3   | `Request`     | client → server  | id, model, features        |
//! | 4   | `Response`    | server → client  | served prediction          |
//! | 5   | `Error`       | server → client  | typed fail-fast error      |
//! | 6   | `MetricsPull` | client → server  | —                          |
//! | 7   | `Metrics`     | server → client  | per-lane raw sink states   |
//! | 8   | `Refresh`     | client → server  | —                          |
//! | 9   | `Ack`         | server → client  | —                          |
//! | 10  | `Ping`        | either           | —                          |
//! | 11  | `Pong`        | either           | —                          |
//!
//! Versioning: the version rides in `Hello`/`HelloAck`, not in every
//! frame header. A server refuses a `Hello` whose version it does not
//! speak (the client gets a clean `Error` frame, not a hang), and
//! unknown *kinds* are `Corrupt` — forward compatibility is by version
//! negotiation, never by silently skipping frames. See `docs/WIRE.md`.

use std::io::{Read, Write};
use std::time::Duration;

use crate::coordinator::{
    MetricsState, ModelMetricsState, PredictError, PredictErrorKind,
    PredictResponse, Route, WelfordState,
};
use crate::registry::binfmt::{
    push_f32, push_f64, push_u16, push_u32, push_u64, Reader,
};
use crate::util::crc32::crc32;
use crate::{Error, Result};

/// Frame magic: `ARBW` ("approx RBF wire"; the `.arbf` sibling).
pub const WIRE_MAGIC: [u8; 4] = *b"ARBW";
/// Protocol version negotiated in `Hello`/`HelloAck`.
pub const WIRE_VERSION: u16 = 1;
/// Fixed frame header length in bytes.
pub const FRAME_HEADER_LEN: usize = 16;
/// Hard cap on a single frame payload (alloc-bomb guard: a corrupted
/// or hostile length field can never make the reader allocate more
/// than this before the CRC is even checked).
pub const MAX_FRAME_PAYLOAD: usize = 1 << 24;
/// Cap on counted tables in a payload (dim tables, per-model rows) —
/// mirrors `binfmt::MAX_RECORDS` in spirit: counts are validated
/// before any allocation sized by them.
pub const MAX_WIRE_MODELS: usize = 4096;
/// Cap on a transported string (model ids are ≤128 by
/// [`crate::registry::ModelStore`] validation; error details are
/// clipped to this at encode).
pub const MAX_WIRE_STR: usize = 4096;
/// Cap on a transported latency histogram's bucket count.
pub const MAX_WIRE_BUCKETS: usize = 1024;

const K_HELLO: u16 = 1;
const K_HELLO_ACK: u16 = 2;
const K_REQUEST: u16 = 3;
const K_RESPONSE: u16 = 4;
const K_ERROR: u16 = 5;
const K_METRICS_PULL: u16 = 6;
const K_METRICS: u16 = 7;
const K_REFRESH: u16 = 8;
const K_ACK: u16 = 9;
const K_PING: u16 = 10;
const K_PONG: u16 = 11;

/// One protocol message. `Response`/`Error` carry the coordinator's
/// own types, so the network tier converts at the wire boundary only.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Client's opening frame on every connection.
    Hello {
        /// [`WIRE_VERSION`] the client speaks.
        version: u16,
        /// Free-form client name for diagnostics (e.g. `"router"`).
        client: String,
    },
    /// Server's handshake reply: who this shard is and what it serves.
    HelloAck {
        version: u16,
        /// This server's shard index in the plane (diagnostics).
        shard_id: u32,
        /// Executor lanes behind this server.
        shard_count: u32,
        /// `(model id, feature dimension)` for every published model,
        /// so routers validate dimensions client-side without a
        /// round-trip per request.
        dims: Vec<(String, u32)>,
    },
    /// One instance for one model. `id` is the *client's* correlation
    /// id, echoed verbatim in the matching `Response`/`Error`.
    Request { id: u64, model: String, features: Vec<f32> },
    /// A served prediction (ids are rewritten back to the client's
    /// correlation id by the server).
    Response(PredictResponse),
    /// A typed fail-fast completion for a request that could not be
    /// served — same contract as the in-process plane.
    Error(PredictError),
    /// Ask the server for its raw metrics sink states.
    MetricsPull,
    /// Reply to [`Message::MetricsPull`]: one raw state per executor
    /// lane, in shard order. Raw sufficient statistics, not
    /// pre-averaged numbers, so the router's
    /// [`crate::coordinator::Metrics::aggregate`] is exact.
    Metrics(Vec<MetricsState>),
    /// Ask the server to revalidate model generations now
    /// ([`crate::coordinator::Coordinator::refresh`]); answered with
    /// [`Message::Ack`].
    Refresh,
    Ack,
    Ping,
    Pong,
}

impl Message {
    /// This message's frame kind tag.
    pub fn kind(&self) -> u16 {
        match self {
            Message::Hello { .. } => K_HELLO,
            Message::HelloAck { .. } => K_HELLO_ACK,
            Message::Request { .. } => K_REQUEST,
            Message::Response(_) => K_RESPONSE,
            Message::Error(_) => K_ERROR,
            Message::MetricsPull => K_METRICS_PULL,
            Message::Metrics(_) => K_METRICS,
            Message::Refresh => K_REFRESH,
            Message::Ack => K_ACK,
            Message::Ping => K_PING,
            Message::Pong => K_PONG,
        }
    }
}

// ---------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------

/// Clip a diagnostic string to `max` bytes on a char boundary (error
/// details may quote arbitrary input; the wire caps them rather than
/// refusing to transport the error).
fn clipped(s: &str, max: usize) -> &str {
    if s.len() <= max {
        return s;
    }
    let mut end = max;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn push_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u16::MAX as usize {
        return Err(Error::InvalidArg(format!(
            "wire string too long ({} bytes)",
            s.len()
        )));
    }
    push_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn push_welford(out: &mut Vec<u8>, w: &WelfordState) {
    push_u64(out, w.count);
    push_f64(out, w.mean);
    push_f64(out, w.m2);
    push_f64(out, w.min);
    push_f64(out, w.max);
}

fn encode_payload(msg: &Message) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    match msg {
        Message::Hello { version, client } => {
            push_u16(&mut out, *version);
            push_str(&mut out, clipped(client, MAX_WIRE_STR))?;
        }
        Message::HelloAck { version, shard_id, shard_count, dims } => {
            if dims.len() > MAX_WIRE_MODELS {
                return Err(Error::InvalidArg(format!(
                    "dim table has {} entries (cap {MAX_WIRE_MODELS})",
                    dims.len()
                )));
            }
            push_u16(&mut out, *version);
            push_u32(&mut out, *shard_id);
            push_u32(&mut out, *shard_count);
            push_u32(&mut out, dims.len() as u32);
            for (id, dim) in dims {
                push_str(&mut out, id)?;
                push_u32(&mut out, *dim);
            }
        }
        Message::Request { id, model, features } => {
            push_u64(&mut out, *id);
            push_str(&mut out, model)?;
            push_u32(&mut out, features.len() as u32);
            for &f in features {
                push_f32(&mut out, f);
            }
        }
        Message::Response(r) => {
            push_u64(&mut out, r.id);
            push_str(&mut out, &r.model)?;
            push_u64(&mut out, r.generation);
            push_f32(&mut out, r.decision);
            push_f32(&mut out, r.label);
            out.push(match r.route {
                Route::Approx => 0,
                Route::Exact => 1,
            });
            push_f32(&mut out, r.znorm_sq);
            out.push(u8::from(r.in_bound));
            push_u64(&mut out, r.latency.as_micros() as u64);
        }
        Message::Error(e) => {
            push_u64(&mut out, e.id);
            push_str(&mut out, &e.model)?;
            match &e.kind {
                PredictErrorKind::UnknownModel { detail } => {
                    out.push(1);
                    push_str(&mut out, clipped(detail, MAX_WIRE_STR))?;
                }
                PredictErrorKind::DimMismatch { got, want } => {
                    out.push(2);
                    push_u64(&mut out, *got as u64);
                    push_u64(&mut out, *want as u64);
                }
                PredictErrorKind::Exec { detail } => {
                    out.push(3);
                    push_str(&mut out, clipped(detail, MAX_WIRE_STR))?;
                }
                PredictErrorKind::Shutdown => out.push(4),
            }
        }
        Message::Metrics(states) => {
            if states.len() > MAX_WIRE_MODELS {
                return Err(Error::InvalidArg(format!(
                    "{} metrics states (cap {MAX_WIRE_MODELS})",
                    states.len()
                )));
            }
            push_u32(&mut out, states.len() as u32);
            for s in states {
                if s.histogram.len() > MAX_WIRE_BUCKETS {
                    return Err(Error::InvalidArg(format!(
                        "histogram has {} buckets (cap {MAX_WIRE_BUCKETS})",
                        s.histogram.len()
                    )));
                }
                if s.per_model.len() > MAX_WIRE_MODELS {
                    return Err(Error::InvalidArg(format!(
                        "{} per-model rows (cap {MAX_WIRE_MODELS})",
                        s.per_model.len()
                    )));
                }
                push_u64(&mut out, s.served_approx);
                push_u64(&mut out, s.served_exact);
                push_u64(&mut out, s.out_of_bound);
                push_u64(&mut out, s.dropped);
                push_u64(&mut out, s.batches);
                push_u64(&mut out, s.queue_depth);
                push_f64(&mut out, s.uptime_s);
                push_welford(&mut out, &s.batch_sizes);
                push_welford(&mut out, &s.latency);
                push_u32(&mut out, s.histogram.len() as u32);
                for &h in &s.histogram {
                    push_u64(&mut out, h);
                }
                push_u32(&mut out, s.per_model.len() as u32);
                for m in &s.per_model {
                    push_str(&mut out, &m.id)?;
                    push_u64(&mut out, m.served_approx);
                    push_u64(&mut out, m.served_exact);
                    push_u64(&mut out, m.out_of_bound);
                    push_u64(&mut out, m.dropped);
                    push_welford(&mut out, &m.latency);
                    push_str(&mut out, clipped(&m.substrate, MAX_WIRE_STR))?;
                }
            }
        }
        Message::MetricsPull
        | Message::Refresh
        | Message::Ack
        | Message::Ping
        | Message::Pong => {}
    }
    Ok(out)
}

/// Encode one message as a complete frame (header + payload).
pub fn encode_frame(msg: &Message) -> Result<Vec<u8>> {
    let payload = encode_payload(msg)?;
    if payload.len() > MAX_FRAME_PAYLOAD {
        return Err(Error::InvalidArg(format!(
            "frame payload of {} bytes exceeds cap {MAX_FRAME_PAYLOAD}",
            payload.len()
        )));
    }
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&WIRE_MAGIC);
    push_u16(&mut out, msg.kind());
    push_u16(&mut out, 0); // reserved
    push_u32(&mut out, crc32(&payload));
    push_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Encode and write one frame. The caller owns flushing (a writer
/// thread batches several frames per flush under load).
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let bytes = encode_frame(msg)?;
    w.write_all(&bytes).map_err(Error::Io)
}

// ---------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------

fn read_str(rd: &mut Reader<'_>, what: &str) -> Result<String> {
    let n = rd.u16(what)? as usize;
    let bytes = rd.take(n, what)?;
    String::from_utf8(bytes.to_vec()).map_err(|_| {
        Error::Corrupt(format!("{what}: invalid utf-8 in wire string"))
    })
}

fn read_welford(rd: &mut Reader<'_>, what: &str) -> Result<WelfordState> {
    Ok(WelfordState {
        count: rd.u64(what)?,
        mean: rd.f64(what)?,
        m2: rd.f64(what)?,
        min: rd.f64(what)?,
        max: rd.f64(what)?,
    })
}

fn read_route(rd: &mut Reader<'_>) -> Result<Route> {
    match rd.u8("route")? {
        0 => Ok(Route::Approx),
        1 => Ok(Route::Exact),
        other => {
            Err(Error::Corrupt(format!("unknown route tag {other}")))
        }
    }
}

/// Validate a counted-table length against its cap *before* any
/// allocation sized by it.
fn checked_count(n: u32, cap: usize, what: &str) -> Result<usize> {
    let n = n as usize;
    if n > cap {
        return Err(Error::Corrupt(format!(
            "{what}: count {n} exceeds cap {cap}"
        )));
    }
    Ok(n)
}

fn decode_payload(kind: u16, payload: &[u8]) -> Result<Message> {
    let mut rd = Reader { buf: payload, pos: 0 };
    let msg = match kind {
        K_HELLO => Message::Hello {
            version: rd.u16("hello version")?,
            client: read_str(&mut rd, "hello client")?,
        },
        K_HELLO_ACK => {
            let version = rd.u16("helloack version")?;
            let shard_id = rd.u32("helloack shard id")?;
            let shard_count = rd.u32("helloack shard count")?;
            let n = checked_count(
                rd.u32("helloack dim count")?,
                MAX_WIRE_MODELS,
                "dim table",
            )?;
            let mut dims = Vec::with_capacity(n);
            for _ in 0..n {
                let id = read_str(&mut rd, "dim table id")?;
                let dim = rd.u32("dim table dim")?;
                dims.push((id, dim));
            }
            Message::HelloAck { version, shard_id, shard_count, dims }
        }
        K_REQUEST => {
            let id = rd.u64("request id")?;
            let model = read_str(&mut rd, "request model")?;
            let n = rd.u32("request feature count")? as usize;
            // f32_vec bounds-checks against the actual buffer before
            // allocating, so a hostile count cannot alloc-bomb.
            let features = rd.f32_vec(n, "request features")?;
            Message::Request { id, model, features }
        }
        K_RESPONSE => {
            let id = rd.u64("response id")?;
            let model = read_str(&mut rd, "response model")?;
            let generation = rd.u64("response generation")?;
            let decision = rd.f32("response decision")?;
            let label = rd.f32("response label")?;
            let route = read_route(&mut rd)?;
            let znorm_sq = rd.f32("response znorm_sq")?;
            let in_bound = match rd.u8("response in_bound")? {
                0 => false,
                1 => true,
                other => {
                    return Err(Error::Corrupt(format!(
                        "in_bound must be 0/1, got {other}"
                    )))
                }
            };
            let latency =
                Duration::from_micros(rd.u64("response latency")?);
            Message::Response(PredictResponse {
                id,
                model: std::sync::Arc::from(model.as_str()),
                generation,
                decision,
                label,
                route,
                znorm_sq,
                in_bound,
                latency,
            })
        }
        K_ERROR => {
            let id = rd.u64("error id")?;
            let model = read_str(&mut rd, "error model")?;
            let kind = match rd.u8("error kind tag")? {
                1 => PredictErrorKind::UnknownModel {
                    detail: read_str(&mut rd, "error detail")?,
                },
                2 => PredictErrorKind::DimMismatch {
                    got: rd.u64("error got")? as usize,
                    want: rd.u64("error want")? as usize,
                },
                3 => PredictErrorKind::Exec {
                    detail: read_str(&mut rd, "error detail")?,
                },
                4 => PredictErrorKind::Shutdown,
                other => {
                    return Err(Error::Corrupt(format!(
                        "unknown error kind tag {other}"
                    )))
                }
            };
            Message::Error(PredictError {
                id,
                model: std::sync::Arc::from(model.as_str()),
                kind,
            })
        }
        K_METRICS => {
            let n = checked_count(
                rd.u32("metrics state count")?,
                MAX_WIRE_MODELS,
                "metrics states",
            )?;
            let mut states = Vec::with_capacity(n);
            for _ in 0..n {
                let served_approx = rd.u64("metrics served_approx")?;
                let served_exact = rd.u64("metrics served_exact")?;
                let out_of_bound = rd.u64("metrics out_of_bound")?;
                let dropped = rd.u64("metrics dropped")?;
                let batches = rd.u64("metrics batches")?;
                let queue_depth = rd.u64("metrics queue_depth")?;
                let uptime_s = rd.f64("metrics uptime")?;
                let batch_sizes =
                    read_welford(&mut rd, "metrics batch_sizes")?;
                let latency = read_welford(&mut rd, "metrics latency")?;
                let hn = checked_count(
                    rd.u32("metrics histogram len")?,
                    MAX_WIRE_BUCKETS,
                    "histogram",
                )?;
                let mut histogram = Vec::with_capacity(hn);
                for _ in 0..hn {
                    histogram.push(rd.u64("metrics histogram bucket")?);
                }
                let mn = checked_count(
                    rd.u32("metrics model count")?,
                    MAX_WIRE_MODELS,
                    "per-model rows",
                )?;
                let mut per_model = Vec::with_capacity(mn);
                for _ in 0..mn {
                    per_model.push(ModelMetricsState {
                        id: read_str(&mut rd, "model row id")?,
                        served_approx: rd.u64("model row served_approx")?,
                        served_exact: rd.u64("model row served_exact")?,
                        out_of_bound: rd.u64("model row out_of_bound")?,
                        dropped: rd.u64("model row dropped")?,
                        latency: read_welford(&mut rd, "model row latency")?,
                        substrate: read_str(&mut rd, "model row substrate")?,
                    });
                }
                states.push(MetricsState {
                    served_approx,
                    served_exact,
                    out_of_bound,
                    dropped,
                    batches,
                    queue_depth,
                    uptime_s,
                    batch_sizes,
                    latency,
                    histogram,
                    per_model,
                });
            }
            Message::Metrics(states)
        }
        K_METRICS_PULL => Message::MetricsPull,
        K_REFRESH => Message::Refresh,
        K_ACK => Message::Ack,
        K_PING => Message::Ping,
        K_PONG => Message::Pong,
        other => {
            return Err(Error::Corrupt(format!(
                "unknown frame kind {other}"
            )))
        }
    };
    if rd.pos != rd.buf.len() {
        return Err(Error::Corrupt(format!(
            "{} trailing byte(s) after frame payload",
            rd.buf.len() - rd.pos
        )));
    }
    Ok(msg)
}

/// Parse and validate a frame header; returns `(kind, crc, len)`.
fn parse_header(header: &[u8; FRAME_HEADER_LEN]) -> Result<(u16, u32, usize)> {
    if header[0..4] != WIRE_MAGIC {
        return Err(Error::Corrupt(format!(
            "bad wire magic {:02x?} (want {:02x?})",
            &header[0..4],
            WIRE_MAGIC
        )));
    }
    let kind = u16::from_le_bytes([header[4], header[5]]);
    // header[6..8] is reserved; tolerated on read (forward compat),
    // always written 0 — same contract as .arbf reserved bytes.
    let crc =
        u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    let len =
        u32::from_le_bytes([header[12], header[13], header[14], header[15]])
            as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(Error::Corrupt(format!(
            "frame payload length {len} exceeds cap {MAX_FRAME_PAYLOAD}"
        )));
    }
    Ok((kind, crc, len))
}

/// Decode one complete frame from a byte slice; returns the message
/// and the total number of bytes consumed. Mirrors `binfmt::decode`'s
/// negative space: every class of damage is a typed `Corrupt`.
pub fn decode_frame(bytes: &[u8]) -> Result<(Message, usize)> {
    if bytes.len() < FRAME_HEADER_LEN {
        return Err(Error::Corrupt(format!(
            "truncated frame header: {} of {FRAME_HEADER_LEN} bytes",
            bytes.len()
        )));
    }
    let mut header = [0u8; FRAME_HEADER_LEN];
    header.copy_from_slice(&bytes[..FRAME_HEADER_LEN]);
    let (kind, crc, len) = parse_header(&header)?;
    let total = FRAME_HEADER_LEN + len;
    if bytes.len() < total {
        return Err(Error::Corrupt(format!(
            "truncated frame payload: {} of {len} bytes",
            bytes.len() - FRAME_HEADER_LEN
        )));
    }
    let payload = &bytes[FRAME_HEADER_LEN..total];
    let got = crc32(payload);
    if got != crc {
        return Err(Error::Corrupt(format!(
            "frame crc mismatch: stored {crc:#010x}, computed {got:#010x}"
        )));
    }
    Ok((decode_payload(kind, payload)?, total))
}

/// Read one frame from a blocking stream.
///
/// Returns `Ok(None)` on a clean EOF at a frame boundary (the peer
/// closed between frames — the normal end of a connection); EOF
/// *inside* a frame is `Corrupt`. Read timeouts and other I/O failures
/// surface as [`Error::Io`] for the caller's reconnect logic.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Message>> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let mut got = 0usize;
    while got < FRAME_HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(Error::Corrupt(format!(
                    "eof inside frame header ({got} of \
                     {FRAME_HEADER_LEN} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let (kind, crc, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    match r.read_exact(&mut payload) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(Error::Corrupt(
                "eof inside frame payload".to_string(),
            ))
        }
        Err(e) => return Err(Error::Io(e)),
    }
    let computed = crc32(&payload);
    if computed != crc {
        return Err(Error::Corrupt(format!(
            "frame crc mismatch: stored {crc:#010x}, computed \
             {computed:#010x}"
        )));
    }
    decode_payload(kind, &payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;

    fn mid(s: &str) -> crate::coordinator::ModelId {
        std::sync::Arc::from(s)
    }

    fn sample_response() -> Message {
        Message::Response(PredictResponse {
            id: 42,
            model: mid("tenant-α"),
            generation: 7,
            decision: -0.25,
            label: -1.0,
            route: Route::Exact,
            znorm_sq: 1.5,
            in_bound: false,
            latency: Duration::from_micros(1234),
        })
    }

    fn sample_metrics() -> Message {
        Message::Metrics(vec![MetricsState {
            served_approx: 10,
            served_exact: 3,
            out_of_bound: 1,
            dropped: 2,
            batches: 4,
            queue_depth: 6,
            uptime_s: 1.5,
            batch_sizes: WelfordState {
                count: 4,
                mean: 3.25,
                m2: 0.5,
                min: 1.0,
                max: 5.0,
            },
            latency: WelfordState {
                count: 13,
                mean: 1e-4,
                m2: 1e-9,
                min: 5e-5,
                max: 3e-4,
            },
            histogram: vec![0, 1, 5, 7],
            per_model: vec![ModelMetricsState {
                id: "alpha".to_string(),
                served_approx: 10,
                served_exact: 3,
                out_of_bound: 1,
                dropped: 2,
                latency: WelfordState {
                    count: 13,
                    mean: 1e-4,
                    m2: 1e-9,
                    min: 5e-5,
                    max: 3e-4,
                },
                substrate: "rff".to_string(),
            }],
        }])
    }

    fn all_samples() -> Vec<Message> {
        vec![
            Message::Hello {
                version: WIRE_VERSION,
                client: "router".to_string(),
            },
            Message::HelloAck {
                version: WIRE_VERSION,
                shard_id: 2,
                shard_count: 3,
                dims: vec![
                    ("alpha".to_string(), 8),
                    ("bravo.v2".to_string(), 128),
                ],
            },
            Message::Request {
                id: 9,
                model: "alpha".to_string(),
                features: vec![0.5, -1.25, 3.75],
            },
            Message::Request {
                id: 10,
                model: "empty".to_string(),
                features: vec![],
            },
            sample_response(),
            Message::Error(PredictError {
                id: 11,
                model: mid("ghost"),
                kind: PredictErrorKind::UnknownModel {
                    detail: "no such bundle".to_string(),
                },
            }),
            Message::Error(PredictError {
                id: 12,
                model: mid("alpha"),
                kind: PredictErrorKind::DimMismatch { got: 3, want: 8 },
            }),
            Message::Error(PredictError {
                id: 13,
                model: mid("alpha"),
                kind: PredictErrorKind::Exec {
                    detail: "boom".to_string(),
                },
            }),
            Message::Error(PredictError {
                id: 14,
                model: mid("alpha"),
                kind: PredictErrorKind::Shutdown,
            }),
            Message::MetricsPull,
            sample_metrics(),
            Message::Refresh,
            Message::Ack,
            Message::Ping,
            Message::Pong,
        ]
    }

    #[test]
    fn every_message_kind_roundtrips() {
        for msg in all_samples() {
            let frame = encode_frame(&msg).unwrap();
            let (back, used) = decode_frame(&frame).unwrap();
            assert_eq!(used, frame.len(), "{msg:?}");
            assert_eq!(back, msg);
            // And through the stream reader.
            let mut cursor: &[u8] = &frame;
            let back = read_frame(&mut cursor).unwrap().unwrap();
            assert_eq!(back, msg);
            assert!(cursor.is_empty());
        }
    }

    #[test]
    fn stream_reader_handles_back_to_back_frames_and_clean_eof() {
        let msgs = all_samples();
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend_from_slice(&encode_frame(m).unwrap());
        }
        let mut cursor: &[u8] = &stream;
        for want in &msgs {
            let got = read_frame(&mut cursor).unwrap().unwrap();
            assert_eq!(&got, want);
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncation_at_every_length_is_typed_corrupt() {
        let frame = encode_frame(&sample_response()).unwrap();
        for cut in 1..frame.len() {
            let err = decode_frame(&frame[..cut]).unwrap_err();
            assert!(
                matches!(err, Error::Corrupt(_)),
                "cut at {cut}: {err}"
            );
            // The stream reader agrees (EOF mid-frame is corruption,
            // not a clean end).
            let mut cursor = &frame[..cut];
            let err = read_frame(&mut cursor).unwrap_err();
            assert!(
                matches!(err, Error::Corrupt(_)),
                "stream cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn crc_bitflip_anywhere_in_payload_is_corrupt() {
        let frame = encode_frame(&sample_metrics()).unwrap();
        for pos in FRAME_HEADER_LEN..frame.len() {
            let mut bad = frame.clone();
            bad[pos] ^= 0x20;
            let err = decode_frame(&bad).unwrap_err();
            assert!(
                matches!(err, Error::Corrupt(_)),
                "flip at {pos}: {err}"
            );
            assert!(
                err.to_string().contains("crc"),
                "flip at {pos} should fail the checksum: {err}"
            );
        }
    }

    #[test]
    fn header_negatives_are_typed_corrupt() {
        let frame = encode_frame(&Message::Ping).unwrap();

        // Bad magic.
        let mut bad = frame.clone();
        bad[0] ^= 0xff;
        let err = decode_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // Unknown kind (payload empty, crc still valid).
        let mut bad = frame.clone();
        bad[4] = 0xee;
        bad[5] = 0xee;
        let err = decode_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");

        // Oversized length field: rejected before any allocation.
        let mut bad = frame.clone();
        bad[12..16]
            .copy_from_slice(&(MAX_FRAME_PAYLOAD as u32 + 1).to_le_bytes());
        let err = decode_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");

        // Reserved bytes are tolerated (forward compat).
        let mut ok = frame;
        ok[6] = 0xab;
        assert_eq!(decode_frame(&ok).unwrap().0, Message::Ping);
    }

    #[test]
    fn trailing_bytes_inside_payload_are_corrupt() {
        // Craft a Ping frame whose payload carries one stray byte with
        // a *valid* crc and length: structural validation must still
        // reject it.
        let payload = [0u8; 1];
        let mut bad = Vec::new();
        bad.extend_from_slice(&WIRE_MAGIC);
        bad.extend_from_slice(&K_PING.to_le_bytes());
        bad.extend_from_slice(&0u16.to_le_bytes());
        bad.extend_from_slice(&crc32(&payload).to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&payload);
        let err = decode_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn hostile_table_counts_are_capped_before_allocation() {
        // A HelloAck claiming u32::MAX dim-table entries must die on
        // the count check, not attempt the allocation.
        let mut payload = Vec::new();
        push_u16(&mut payload, WIRE_VERSION);
        push_u32(&mut payload, 0);
        push_u32(&mut payload, 1);
        push_u32(&mut payload, u32::MAX);
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&K_HELLO_ACK.to_le_bytes());
        frame.extend_from_slice(&0u16.to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        let err = decode_frame(&frame).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn long_error_details_are_clipped_not_refused() {
        let msg = Message::Error(PredictError {
            id: 1,
            model: mid("m"),
            kind: PredictErrorKind::Exec {
                detail: "x".repeat(3 * MAX_WIRE_STR),
            },
        });
        let frame = encode_frame(&msg).unwrap();
        let (back, _) = decode_frame(&frame).unwrap();
        match back {
            Message::Error(e) => match e.kind {
                PredictErrorKind::Exec { detail } => {
                    assert_eq!(detail.len(), MAX_WIRE_STR);
                }
                other => panic!("wrong kind {other:?}"),
            },
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn property_ragged_requests_roundtrip() {
        prop_cases!("wire request roundtrip", 64, |rng| {
            let dim = rng.below(33); // 0..=32, ragged
            let features: Vec<f32> = (0..dim)
                .map(|_| (rng.normal() * 10.0) as f32)
                .collect();
            let name_len = 1 + rng.below(16);
            let model: String = (0..name_len)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            let msg = Message::Request {
                id: rng.below(1 << 48) as u64,
                model,
                features,
            };
            let frame = encode_frame(&msg).unwrap();
            let (back, used) = decode_frame(&frame).unwrap();
            assert_eq!(used, frame.len());
            assert_eq!(back, msg);

            // Any truncation of this frame is typed Corrupt.
            if frame.len() > 1 {
                let cut = 1 + rng.below(frame.len() - 1);
                let err = decode_frame(&frame[..cut]).unwrap_err();
                assert!(matches!(err, Error::Corrupt(_)), "{err}");
            }

            // Any single-byte payload flip is caught by the crc.
            if frame.len() > FRAME_HEADER_LEN {
                let pos = FRAME_HEADER_LEN
                    + rng.below(frame.len() - FRAME_HEADER_LEN);
                let mut bad = frame.clone();
                bad[pos] ^= 1u8 << rng.below(8);
                let err = decode_frame(&bad).unwrap_err();
                assert!(matches!(err, Error::Corrupt(_)), "{err}");
            }
        });
    }

    /// Decoder fuzz: stack seeded mutations — bit flips anywhere
    /// (header included), length-field lies, hostile kinds, and
    /// truncation at any offset — onto valid frames of every message
    /// kind. The decoders must return Ok or a typed
    /// `Corrupt`/`Io`, and never panic; this is the same hostile
    /// surface `faultnet` exercises over real sockets in the chaos
    /// tier.
    #[test]
    fn property_mutated_frames_fail_typed_never_panic() {
        let samples = all_samples();
        prop_cases!("wire decoder fuzz", 128, |rng| {
            let base =
                encode_frame(&samples[rng.below(samples.len())]).unwrap();
            let mut bytes = base;
            for _ in 0..(1 + rng.below(4)) {
                if bytes.is_empty() {
                    break;
                }
                match rng.below(4) {
                    0 => {
                        // Single-bit flip anywhere, header included.
                        let pos = rng.below(bytes.len());
                        bytes[pos] ^= 1u8 << rng.below(8);
                    }
                    1 if bytes.len() >= FRAME_HEADER_LEN => {
                        // Length-field lie: any u32, including values
                        // far past the payload and past the cap.
                        let lie = rng.next_u64() as u32;
                        bytes[12..16]
                            .copy_from_slice(&lie.to_le_bytes());
                    }
                    2 if bytes.len() >= 6 => {
                        // Hostile kind.
                        let kind = rng.next_u64() as u16;
                        bytes[4..6]
                            .copy_from_slice(&kind.to_le_bytes());
                    }
                    3 => {
                        // Truncation at any offset (possibly to 0).
                        bytes.truncate(rng.below(bytes.len()));
                    }
                    _ => {}
                }
            }

            match decode_frame(&bytes) {
                Ok((_, used)) => assert!(used <= bytes.len()),
                Err(e) => assert!(
                    matches!(e, Error::Corrupt(_) | Error::Io(_)),
                    "untyped decode failure: {e}"
                ),
            }
            // The stream reader sees the same bytes as a socket would:
            // whole frames until a clean EOF, or one typed error.
            let mut cursor: &[u8] = &bytes;
            loop {
                match read_frame(&mut cursor) {
                    Ok(Some(_)) => {}
                    Ok(None) => break,
                    Err(e) => {
                        assert!(
                            matches!(
                                e,
                                Error::Corrupt(_) | Error::Io(_)
                            ),
                            "untyped stream failure: {e}"
                        );
                        break;
                    }
                }
            }
        });
    }
}
