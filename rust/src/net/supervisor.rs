//! serve-plane supervisor: keep N `serve-shard` processes alive.
//!
//! [`Supervisor::start`] spawns one `approxrbf serve-shard` child per
//! shard (binding an ephemeral loopback port, scraped from the
//! child's banner line) and then tends each one from a monitor
//! thread: a crashed child (or one that stops answering the wire
//! Hello/Ping health probe) is killed and respawned with capped
//! exponential backoff — the same 50ms→ceiling ladder the
//! [`Router`](super::Router) uses for reconnects, so a flapping shard
//! is never hammered.
//!
//! The port a shard first binds is **pinned**: restarts pass the same
//! `--listen` address, so routers connected to the plane reconnect to
//! the very address they already know and resume serving
//! bit-identically (placement depends only on address order, which
//! never changes). `std`'s listener sets `SO_REUSEADDR` on Unix, so
//! rebinding the pinned port behind lingering `TIME_WAIT` entries
//! succeeds; a transiently busy port is absorbed by the restart
//! backoff.
//!
//! Restart counts are exported via [`Supervisor::restarts`] and feed
//! the `restarts` column of
//! [`MetricsSnapshot::record_restarts`](crate::coordinator::MetricsSnapshot::record_restarts),
//! so operators can see process churn next to the router's reconnect
//! counters.

use std::io::{BufRead, BufReader};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::router::sleep_interruptible;
use super::wire::{self, Message, WIRE_VERSION};
use crate::util::sync::lock_unpoisoned;
use crate::{log_info, log_warn, Error, Result};

/// Tuning knobs for a [`Supervisor`].
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// Shard processes to keep alive.
    pub shards: usize,
    /// Registry directory every shard serves from.
    pub store: PathBuf,
    /// Binary to spawn (`approxrbf`; the CLI passes its own path).
    pub binary: PathBuf,
    /// Executor lanes per shard process (`--shards` of `serve-shard`).
    pub lanes: usize,
    /// Optional `--policy` forwarded to each shard.
    pub policy: Option<String>,
    /// Optional `--drift-tol` forwarded to each shard.
    pub drift_tol: Option<f32>,
    /// Pause between wire health probes of a live shard.
    pub health_interval: Duration,
    /// Connect/read timeout of one health probe.
    pub health_timeout: Duration,
    /// Consecutive failed probes before a shard is declared wedged
    /// and restarted (a crashed process restarts immediately).
    pub health_strikes: u32,
    /// First restart backoff; doubles per attempt.
    pub backoff_floor: Duration,
    /// Restart backoff ceiling.
    pub backoff_ceiling: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            shards: 2,
            store: PathBuf::from("registry"),
            binary: PathBuf::from("approxrbf"),
            lanes: 1,
            policy: None,
            drift_tol: None,
            health_interval: Duration::from_millis(250),
            health_timeout: Duration::from_secs(1),
            health_strikes: 3,
            backoff_floor: Duration::from_millis(50),
            backoff_ceiling: Duration::from_secs(2),
        }
    }
}

/// One supervised shard slot: the live child (if any), its pinned
/// listen address, and how often it has been restarted.
struct ShardSlot {
    index: usize,
    child: Mutex<Option<Child>>,
    addr: Mutex<Option<String>>,
    restarts: AtomicU64,
}

/// Process supervisor for a `serve-plane`: spawns, health-checks and
/// restarts `serve-shard` children. See the module docs.
pub struct Supervisor {
    slots: Vec<Arc<ShardSlot>>,
    stop: Arc<AtomicBool>,
    monitors: Mutex<Vec<JoinHandle<()>>>,
}

impl Supervisor {
    /// Spawn every shard and start its monitor. Fails (tearing down
    /// anything already spawned) unless all shards come up and
    /// announce an address.
    pub fn start(config: SupervisorConfig) -> Result<Supervisor> {
        if config.shards == 0 {
            return Err(Error::InvalidArg(
                "serve-plane needs at least one shard".into(),
            ));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut slots = Vec::with_capacity(config.shards);
        for index in 0..config.shards {
            match spawn_shard(&config, index, None) {
                Ok((child, addr)) => {
                    log_info!(
                        "serve-plane: shard {index} up on {addr}"
                    );
                    slots.push(Arc::new(ShardSlot {
                        index,
                        child: Mutex::new(Some(child)),
                        addr: Mutex::new(Some(addr)),
                        restarts: AtomicU64::new(0),
                    }));
                }
                Err(e) => {
                    for slot in &slots {
                        kill_child(slot);
                    }
                    return Err(Error::Other(format!(
                        "serve-plane: shard {index} failed to start: {e}"
                    )));
                }
            }
        }
        let mut monitors = Vec::with_capacity(slots.len());
        for slot in &slots {
            let tended = Arc::clone(slot);
            let cfg = config.clone();
            let stop2 = Arc::clone(&stop);
            let name = format!("serve-plane-monitor-{}", tended.index);
            let spawned = std::thread::Builder::new()
                .name(name)
                .spawn(move || run_monitor(tended, cfg, stop2));
            match spawned {
                Ok(handle) => monitors.push(handle),
                Err(e) => {
                    stop.store(true, Ordering::SeqCst);
                    for m in monitors {
                        let _ = m.join();
                    }
                    for slot in &slots {
                        kill_child(slot);
                    }
                    return Err(Error::Other(format!(
                        "spawn monitor: {e}"
                    )));
                }
            }
        }
        Ok(Supervisor {
            slots,
            stop,
            monitors: Mutex::new(monitors),
        })
    }

    /// Pinned shard addresses in placement order — hand these to
    /// [`Router::connect`](super::Router::connect). Stable across
    /// restarts.
    pub fn addrs(&self) -> Vec<String> {
        self.slots
            .iter()
            .map(|s| {
                lock_unpoisoned(&s.addr).clone().unwrap_or_default()
            })
            .collect()
    }

    /// Restart count per shard, in placement order.
    pub fn restarts(&self) -> Vec<u64> {
        self.slots
            .iter()
            .map(|s| s.restarts.load(Ordering::Relaxed))
            .collect()
    }

    /// Kill shard `index`'s process (SIGKILL) — the chaos suite's
    /// crash lever. The monitor notices and restarts it.
    pub fn kill_shard(&self, index: usize) -> Result<()> {
        let slot = self.slots.get(index).ok_or_else(|| {
            Error::InvalidArg(format!("no shard {index}"))
        })?;
        kill_child(slot);
        Ok(())
    }

    /// Stop the monitors and kill every child. Idempotent; also runs
    /// on drop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let monitors: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.monitors).drain(..).collect();
        for m in monitors {
            let _ = m.join();
        }
        for slot in &self.slots {
            kill_child(slot);
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Kill and reap a slot's child, if any.
fn kill_child(slot: &ShardSlot) {
    let mut guard = lock_unpoisoned(&slot.child);
    if let Some(child) = guard.as_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
    *guard = None;
}

/// Has the slot's child exited (or vanished)?
fn child_gone(slot: &ShardSlot) -> bool {
    let mut guard = lock_unpoisoned(&slot.child);
    match guard.as_mut() {
        None => true,
        Some(child) => match child.try_wait() {
            Ok(None) => false,
            // Exited — reap happened in try_wait; drop the handle.
            Ok(Some(_)) => {
                *guard = None;
                true
            }
            Err(_) => true,
        },
    }
}

/// Spawn one `serve-shard` child and scrape its banner for the bound
/// address. `listen` pins the address on restart; `None` asks the OS
/// for an ephemeral port.
fn spawn_shard(
    config: &SupervisorConfig,
    index: usize,
    listen: Option<&str>,
) -> Result<(Child, String)> {
    let mut cmd = Command::new(&config.binary);
    cmd.arg("serve-shard")
        .arg("--listen")
        .arg(listen.unwrap_or("127.0.0.1:0"))
        .arg("--store")
        .arg(&config.store)
        .arg("--shards")
        .arg(config.lanes.max(1).to_string())
        .arg("--shard-id")
        .arg(index.to_string());
    if let Some(policy) = &config.policy {
        cmd.arg("--policy").arg(policy);
    }
    if let Some(tol) = config.drift_tol {
        cmd.arg("--drift-tol").arg(tol.to_string());
    }
    cmd.stdout(Stdio::piped()).stderr(Stdio::null());
    let mut child = cmd.spawn().map_err(Error::Io)?;
    let stdout = match child.stdout.take() {
        Some(s) => s,
        None => {
            let _ = child.kill();
            let _ = child.wait();
            return Err(Error::Other(
                "serve-shard child has no stdout pipe".into(),
            ));
        }
    };
    let mut banner = String::new();
    let read = BufReader::new(stdout).read_line(&mut banner);
    let addr = read
        .ok()
        .filter(|&n| n > 0)
        .and_then(|_| {
            banner
                .split(" serving on ")
                .nth(1)
                .and_then(|rest| rest.split_whitespace().next())
                .map(str::to_string)
        });
    match addr {
        Some(addr) => Ok((child, addr)),
        None => {
            let _ = child.kill();
            let status = child.wait();
            Err(Error::Other(format!(
                "serve-shard {index} died before announcing an \
                 address (banner {banner:?}, status {status:?})"
            )))
        }
    }
}

/// One wire health probe: TCP connect, Hello/HelloAck, Ping/Pong.
/// Anything short of a well-formed Pong is a strike.
fn probe(addr: &str, config: &SupervisorConfig) -> Result<()> {
    let sa = addr
        .to_socket_addrs()
        .map_err(Error::Io)?
        .next()
        .ok_or_else(|| {
            Error::InvalidArg(format!("unresolvable address '{addr}'"))
        })?;
    let mut stream =
        TcpStream::connect_timeout(&sa, config.health_timeout)
            .map_err(Error::Io)?;
    stream
        .set_read_timeout(Some(config.health_timeout))
        .map_err(Error::Io)?;
    stream
        .set_write_timeout(Some(config.health_timeout))
        .map_err(Error::Io)?;
    let _ = stream.set_nodelay(true);
    wire::write_frame(
        &mut stream,
        &Message::Hello {
            version: WIRE_VERSION,
            client: "serve-plane".to_string(),
        },
    )?;
    match wire::read_frame(&mut stream)? {
        Some(Message::HelloAck { .. }) => {}
        other => {
            return Err(Error::Other(format!(
                "health probe: expected HelloAck, got {other:?}"
            )));
        }
    }
    wire::write_frame(&mut stream, &Message::Ping)?;
    match wire::read_frame(&mut stream)? {
        Some(Message::Pong) => Ok(()),
        other => Err(Error::Other(format!(
            "health probe: expected Pong, got {other:?}"
        ))),
    }
}

/// Tend one shard slot for the supervisor's lifetime: probe while
/// healthy, restart (with capped backoff, on the pinned address) when
/// crashed or wedged.
fn run_monitor(
    slot: Arc<ShardSlot>,
    config: SupervisorConfig,
    stop: Arc<AtomicBool>,
) {
    let mut strikes = 0u32;
    let mut backoff = config.backoff_floor;
    while !stop.load(Ordering::Relaxed) {
        if !child_gone(&slot) {
            let addr = lock_unpoisoned(&slot.addr).clone();
            let healthy = match addr {
                Some(a) => probe(&a, &config).is_ok(),
                None => false,
            };
            if healthy {
                strikes = 0;
                backoff = config.backoff_floor;
                sleep_interruptible(config.health_interval, &stop);
                continue;
            }
            strikes += 1;
            if strikes < config.health_strikes {
                sleep_interruptible(config.health_interval, &stop);
                continue;
            }
            log_warn!(
                "serve-plane: shard {} unresponsive after {} probes — \
                 restarting",
                slot.index,
                strikes
            );
            kill_child(&slot);
        } else {
            log_warn!(
                "serve-plane: shard {} process died — restarting",
                slot.index
            );
        }
        strikes = 0;
        if stop.load(Ordering::Relaxed) {
            break;
        }
        sleep_interruptible(backoff, &stop);
        backoff = (backoff * 2).min(config.backoff_ceiling);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let pinned = lock_unpoisoned(&slot.addr).clone();
        match spawn_shard(&config, slot.index, pinned.as_deref()) {
            Ok((child, addr)) => {
                *lock_unpoisoned(&slot.child) = Some(child);
                *lock_unpoisoned(&slot.addr) = Some(addr.clone());
                slot.restarts.fetch_add(1, Ordering::Relaxed);
                log_info!(
                    "serve-plane: shard {} restarted on {addr}",
                    slot.index
                );
            }
            Err(e) => {
                log_warn!(
                    "serve-plane: shard {} restart failed ({e}); \
                     backing off",
                    slot.index
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_match_router_backoff_envelope() {
        let cfg = SupervisorConfig::default();
        assert_eq!(cfg.backoff_floor, Duration::from_millis(50));
        assert_eq!(cfg.backoff_ceiling, Duration::from_secs(2));
        assert!(cfg.health_strikes >= 1);
        assert!(cfg.shards >= 1);
    }

    #[test]
    fn start_refuses_zero_shards() {
        let cfg = SupervisorConfig {
            shards: 0,
            ..SupervisorConfig::default()
        };
        assert!(Supervisor::start(cfg).is_err());
    }

    #[test]
    fn start_surfaces_bad_binary() {
        let cfg = SupervisorConfig {
            shards: 1,
            binary: PathBuf::from("/nonexistent/approxrbf-missing"),
            ..SupervisorConfig::default()
        };
        let err = Supervisor::start(cfg);
        assert!(err.is_err());
    }
}
