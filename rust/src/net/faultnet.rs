//! faultnet — deterministic TCP fault injection for the serving
//! tier.
//!
//! [`FaultProxy`] is an in-process TCP relay that sits between a
//! [`Router`](super::Router) and a [`ShardServer`](super::ShardServer)
//! and injects network faults on a schedule that is a pure function
//! of a u64 seed: forwarding delays, single-bit byte corruption (the
//! ARBW frame CRC must catch it), mid-stream cuts (mid-frame
//! truncation + reset from the peer's point of view), bounded
//! black-hole stalls, and flap partitions that refuse reconnection
//! attempts. Inbound connections are numbered in accept order, and
//! connection `k` draws its schedule from `Rng::new(seed).fork(k)`
//! with a fixed draw order — the schedule does not depend on timing,
//! thread interleaving, or which fault classes are enabled, so
//! replaying a seed replays the faults.
//!
//! A [`FaultStats`] ledger counts what was actually injected, so a
//! chaos test can assert that the fault it is pinning invariants
//! against really fired, instead of silently passing on a schedule
//! that never triggered.
//!
//! The proxy never parses ARBW frames; it works on the raw byte
//! stream. Fault offsets start at [`FaultSpec::min_offset`] bytes
//! into a connection (default: safely past the Hello/HelloAck
//! handshake), so a plane can always finish its startup barrier
//! before the weather turns.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::router::sleep_interruptible;
use crate::util::sync::lock_unpoisoned;
use crate::util::Rng;
use crate::{log_info, log_warn, Error, Result};

/// Which fault classes a [`FaultPlan`] injects, and how hard. The
/// default is a fully transparent proxy (every class off); the
/// [`FaultPlan`] constructors enable one class each, which is how the
/// chaos suite isolates invariants per class.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Per-chunk probability of pausing the forwarder before
    /// relaying (0.0 disables delay injection).
    pub delay_chance: f64,
    /// Upper bound on one injected delay; the actual pause is drawn
    /// uniformly from `[1ms, max_delay]`.
    pub max_delay: Duration,
    /// Flip one scheduled bit of one scheduled byte per connection.
    pub corrupt: bool,
    /// Sever the connection once a scheduled byte offset is reached,
    /// truncating whatever frame is in flight.
    pub cut: bool,
    /// Stop forwarding at a scheduled byte offset (black hole), hold
    /// for a bounded stall drawn from `[max_stall/2, max_stall]`,
    /// then sever.
    pub black_hole: bool,
    /// Upper bound on one black-hole stall.
    pub max_stall: Duration,
    /// Flap partition: refuse this many reconnection attempts
    /// (connections `1..=flap_refusals`) before accepting again.
    /// Connection 0 is accepted and cut at its scheduled offset to
    /// start the flap.
    pub flap_refusals: u32,
    /// Byte offsets below this are never faulted, so the ARBW
    /// handshake always completes.
    pub min_offset: u64,
    /// Fault offsets are drawn from `[min_offset, min_offset + span)`.
    pub offset_span: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            delay_chance: 0.0,
            max_delay: Duration::from_millis(25),
            corrupt: false,
            cut: false,
            black_hole: false,
            max_stall: Duration::from_millis(600),
            flap_refusals: 0,
            min_offset: 512,
            offset_span: 4096,
        }
    }
}

/// A seeded fault schedule. `FaultPlan { seed, spec }` is the entire
/// state: per-connection schedules are regenerated on demand from the
/// seed, never stored, so two proxies built from the same plan inject
/// identical faults.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
}

impl FaultPlan {
    pub fn new(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan { seed, spec }
    }

    /// Transparent relay: no faults, but the ledger still counts
    /// connections and bytes.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan::new(seed, FaultSpec::default())
    }

    /// Forwarding delays only — traffic is slowed, never damaged.
    pub fn delays(seed: u64) -> FaultPlan {
        let spec = FaultSpec {
            delay_chance: 0.15,
            ..FaultSpec::default()
        };
        FaultPlan::new(seed, spec)
    }

    /// One flipped bit per connection; the receiver's CRC must turn
    /// it into a typed `Corrupt` teardown.
    pub fn corruption(seed: u64) -> FaultPlan {
        let spec = FaultSpec { corrupt: true, ..FaultSpec::default() };
        FaultPlan::new(seed, spec)
    }

    /// Mid-stream cuts: truncates a frame in flight and resets the
    /// connection.
    pub fn cuts(seed: u64) -> FaultPlan {
        let spec = FaultSpec { cut: true, ..FaultSpec::default() };
        FaultPlan::new(seed, spec)
    }

    /// Bounded black-hole stalls followed by a sever.
    pub fn black_hole(seed: u64) -> FaultPlan {
        let spec = FaultSpec {
            black_hole: true,
            ..FaultSpec::default()
        };
        FaultPlan::new(seed, spec)
    }

    /// Flap partition: cut connection 0, refuse the next `refusals`
    /// attempts (driving the router's backoff ladder), then accept.
    pub fn flap(seed: u64, refusals: u32) -> FaultPlan {
        let spec = FaultSpec {
            cut: true,
            flap_refusals: refusals,
            // Keep the cut early so modest warm-up traffic reaches it.
            offset_span: 1536,
            ..FaultSpec::default()
        };
        FaultPlan::new(seed, spec)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// The schedule for inbound connection number `conn` (accept
    /// order, 0-based). Every parameter is drawn in a fixed canonical
    /// order regardless of which classes are enabled, so enabling a
    /// class never shifts another class's draws.
    fn schedule_for(&self, conn: u64) -> ConnSchedule {
        let spec = &self.spec;
        let mut rng = Rng::new(self.seed).fork(conn);
        let span = spec.offset_span.max(1) as usize;
        // Canonical draw order: delay stream, corrupt, cut, stall.
        let delay_seed = rng.next_u64();
        let corrupt_at = spec.min_offset + rng.below(span) as u64;
        let corrupt_bit = rng.below(8) as u8;
        let corrupt_dir = dir_from(rng.chance(0.5));
        let cut_at = spec.min_offset + rng.below(span) as u64;
        let cut_dir = dir_from(rng.chance(0.5));
        let stall_at = spec.min_offset + rng.below(span) as u64;
        let stall_dir = dir_from(rng.chance(0.5));
        let stall_ms = spec.max_stall.as_millis().max(2) as u64;
        let stall_for = Duration::from_millis(
            stall_ms / 2 + rng.below((stall_ms / 2).max(1) as usize) as u64,
        );

        let flapping = spec.flap_refusals > 0;
        let refuse =
            flapping && conn >= 1 && conn <= u64::from(spec.flap_refusals);
        // Under a flap plan only connection 0 is cut; once the
        // partition heals, traffic must flow clean again.
        let cut_on = spec.cut && (!flapping || conn == 0);
        ConnSchedule {
            refuse,
            delay_chance: spec.delay_chance,
            max_delay: spec.max_delay,
            delay_seed,
            corrupt: spec
                .corrupt
                .then_some((corrupt_at, corrupt_bit, corrupt_dir)),
            cut: cut_on.then_some((cut_at, cut_dir)),
            stall: spec
                .black_hole
                .then_some((stall_at, stall_for, stall_dir)),
        }
    }
}

/// Direction of one forwarder inside a proxied connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    /// Router → shard bytes.
    ClientToServer,
    /// Shard → router bytes.
    ServerToClient,
}

fn dir_from(server_to_client: bool) -> Dir {
    if server_to_client {
        Dir::ServerToClient
    } else {
        Dir::ClientToServer
    }
}

/// Fully-drawn schedule for one connection. Byte-offset faults carry
/// the direction whose byte stream they apply to.
#[derive(Clone, Debug)]
struct ConnSchedule {
    refuse: bool,
    delay_chance: f64,
    max_delay: Duration,
    delay_seed: u64,
    corrupt: Option<(u64, u8, Dir)>,
    cut: Option<(u64, Dir)>,
    stall: Option<(u64, Duration, Dir)>,
}

/// What a proxy actually injected — a snapshot of the live ledger.
/// Chaos tests assert on these counters so a schedule that never
/// triggered cannot produce a vacuous green.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Inbound connections accepted (including refused ones).
    pub connections: u64,
    /// Connections dropped before relaying (flap partition).
    pub refused: u64,
    /// Forwarding delays injected.
    pub delays: u64,
    /// Bytes whose scheduled bit was flipped.
    pub corrupted: u64,
    /// Connections severed at a scheduled cut offset.
    pub cuts: u64,
    /// Black-hole stalls held (each ends in a sever).
    pub stalls: u64,
    /// Bytes relayed untouched, both directions combined.
    pub bytes_forwarded: u64,
}

/// Live atomic counters shared by the accept loop and forwarders.
#[derive(Debug, Default)]
struct Ledger {
    connections: AtomicU64,
    refused: AtomicU64,
    delays: AtomicU64,
    corrupted: AtomicU64,
    cuts: AtomicU64,
    stalls: AtomicU64,
    bytes_forwarded: AtomicU64,
}

impl Ledger {
    fn snapshot(&self) -> FaultStats {
        FaultStats {
            connections: self.connections.load(Ordering::Relaxed),
            refused: self.refused.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            corrupted: self.corrupted.load(Ordering::Relaxed),
            cuts: self.cuts.load(Ordering::Relaxed),
            stalls: self.stalls.load(Ordering::Relaxed),
            bytes_forwarded: self.bytes_forwarded.load(Ordering::Relaxed),
        }
    }
}

/// Deterministic fault-injecting TCP relay. Listens on an ephemeral
/// loopback port ([`FaultProxy::addr`]); every accepted connection is
/// relayed to `target` through two forwarder threads that apply the
/// connection's [`FaultPlan`] schedule.
pub struct FaultProxy {
    addr: SocketAddr,
    ledger: Arc<Ledger>,
    stop: Arc<AtomicBool>,
    accept: Mutex<Option<JoinHandle<()>>>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl FaultProxy {
    /// Bind an ephemeral loopback listener and start relaying to
    /// `target` under `plan`.
    pub fn spawn(target: SocketAddr, plan: FaultPlan) -> Result<FaultProxy> {
        let listener =
            TcpListener::bind("127.0.0.1:0").map_err(Error::Io)?;
        let addr = listener.local_addr().map_err(Error::Io)?;
        listener.set_nonblocking(true).map_err(Error::Io)?;
        let ledger = Arc::new(Ledger::default());
        let stop = Arc::new(AtomicBool::new(false));
        let workers = Arc::new(Mutex::new(Vec::new()));
        let handle = {
            let ledger = Arc::clone(&ledger);
            let stop = Arc::clone(&stop);
            let workers = Arc::clone(&workers);
            std::thread::Builder::new()
                .name(format!("faultnet-{}", addr.port()))
                .spawn(move || {
                    run_accept(listener, target, plan, ledger, stop, workers)
                })
                .map_err(Error::Io)?
        };
        log_info!(
            "faultnet: proxy on {} -> {} (seed pinned per plan)",
            addr,
            target
        );
        Ok(FaultProxy {
            addr,
            ledger,
            stop,
            accept: Mutex::new(Some(handle)),
            workers,
        })
    }

    /// Address clients should dial instead of the target's.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the injection ledger.
    pub fn stats(&self) -> FaultStats {
        self.ledger.snapshot()
    }

    /// Stop accepting, sever every relay, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = lock_unpoisoned(&self.accept).take() {
            let _ = h.join();
        }
        let drained: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.workers).drain(..).collect();
        for h in drained {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run_accept(
    listener: TcpListener,
    target: SocketAddr,
    plan: FaultPlan,
    ledger: Arc<Ledger>,
    stop: Arc<AtomicBool>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut conn_index: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        let client = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            Err(e) => {
                log_warn!("faultnet: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
        };
        let sched = plan.schedule_for(conn_index);
        conn_index += 1;
        ledger.connections.fetch_add(1, Ordering::Relaxed);
        if sched.refuse {
            ledger.refused.fetch_add(1, Ordering::Relaxed);
            let _ = client.shutdown(Shutdown::Both);
            continue;
        }
        let server = match TcpStream::connect_timeout(
            &target,
            Duration::from_secs(2),
        ) {
            Ok(s) => s,
            Err(e) => {
                log_warn!("faultnet: target {target} unreachable: {e}");
                let _ = client.shutdown(Shutdown::Both);
                continue;
            }
        };
        let _ = client.set_nodelay(true);
        let _ = server.set_nodelay(true);
        let pair = match (client.try_clone(), server.try_clone()) {
            (Ok(c2), Ok(s2)) => Some((c2, s2)),
            _ => None,
        };
        let Some((client_rd, server_rd)) = pair else {
            log_warn!("faultnet: could not clone relay sockets");
            let _ = client.shutdown(Shutdown::Both);
            let _ = server.shutdown(Shutdown::Both);
            continue;
        };
        let sched = Arc::new(sched);
        let mut spawned = Vec::new();
        let legs = [
            (Dir::ClientToServer, client_rd, server),
            (Dir::ServerToClient, server_rd, client),
        ];
        for (dir, src, dst) in legs {
            let sched = Arc::clone(&sched);
            let ledger = Arc::clone(&ledger);
            let stop = Arc::clone(&stop);
            let res = std::thread::Builder::new()
                .name(format!("faultnet-fwd-{conn_index}"))
                .spawn(move || {
                    run_forwarder(src, dst, dir, sched, ledger, stop)
                });
            match res {
                Ok(h) => spawned.push(h),
                Err(e) => log_warn!("faultnet: forwarder spawn: {e}"),
            }
        }
        let mut workers = lock_unpoisoned(&workers);
        workers.retain(|h| !h.is_finished());
        workers.extend(spawned);
    }
}

/// Relay one direction of one connection, applying the schedule's
/// faults at their byte offsets. Exits (severing both ends) on EOF,
/// socket error, a scheduled cut/stall, or proxy shutdown.
fn run_forwarder(
    src: TcpStream,
    dst: TcpStream,
    dir: Dir,
    sched: Arc<ConnSchedule>,
    ledger: Arc<Ledger>,
    stop: Arc<AtomicBool>,
) {
    let mut src = src;
    let mut dst = dst;
    if src
        .set_read_timeout(Some(Duration::from_millis(50)))
        .is_err()
    {
        sever(&src, &dst);
        return;
    }
    let mut rng = {
        let mut base = Rng::new(sched.delay_seed);
        base.fork(dir as u64)
    };
    let mut corrupt = sched.corrupt.filter(|&(_, _, d)| d == dir);
    let cut = sched.cut.filter(|&(_, d)| d == dir);
    let stall = sched.stall.filter(|&(_, _, d)| d == dir);
    let mut offset: u64 = 0;
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let n = match src.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock
                        | ErrorKind::TimedOut
                        | ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(_) => break,
        };
        let end = offset + n as u64;
        let chunk = &mut buf[..n];
        if sched.delay_chance > 0.0 && rng.chance(sched.delay_chance) {
            let max_ms = sched.max_delay.as_millis().max(1) as usize;
            let ms = 1 + rng.below(max_ms) as u64;
            std::thread::sleep(Duration::from_millis(ms));
            ledger.delays.fetch_add(1, Ordering::Relaxed);
        }
        if let Some((at, bit, _)) = corrupt {
            if at >= offset && at < end {
                chunk[(at - offset) as usize] ^= 1u8 << bit;
                ledger.corrupted.fetch_add(1, Ordering::Relaxed);
                corrupt = None;
            }
        }
        if let Some((at, hold, _)) = stall {
            if at < end {
                let keep = at.saturating_sub(offset) as usize;
                if keep > 0 && dst.write_all(&chunk[..keep]).is_err() {
                    break;
                }
                ledger
                    .bytes_forwarded
                    .fetch_add(keep as u64, Ordering::Relaxed);
                ledger.stalls.fetch_add(1, Ordering::Relaxed);
                sleep_interruptible(hold, &stop);
                break;
            }
        }
        if let Some((at, _)) = cut {
            if at < end {
                let keep = at.saturating_sub(offset) as usize;
                if keep > 0 && dst.write_all(&chunk[..keep]).is_err() {
                    break;
                }
                ledger
                    .bytes_forwarded
                    .fetch_add(keep as u64, Ordering::Relaxed);
                ledger.cuts.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        if dst.write_all(chunk).is_err() {
            break;
        }
        ledger
            .bytes_forwarded
            .fetch_add(n as u64, Ordering::Relaxed);
        offset = end;
    }
    sever(&src, &dst);
}

/// Shut both ends of a relay leg. The paired forwarder sees EOF or an
/// error on its next read and exits too, so one scheduled fault tears
/// the whole proxied connection down — exactly like a real reset.
fn sever(a: &TcpStream, b: &TcpStream) {
    let _ = a.shutdown(Shutdown::Both);
    let _ = b.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo server: accepts connections in a loop, echoing each one's
    /// bytes back until EOF. Returns (addr, stop, handle).
    fn echo_server() -> (SocketAddr, Arc<AtomicBool>, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut s, _)) => {
                        conns.push(std::thread::spawn(move || {
                            let mut buf = [0u8; 4096];
                            s.set_read_timeout(Some(
                                Duration::from_millis(50),
                            ))
                            .unwrap();
                            loop {
                                match s.read(&mut buf) {
                                    Ok(0) => break,
                                    Ok(n) => {
                                        if s.write_all(&buf[..n]).is_err()
                                        {
                                            break;
                                        }
                                    }
                                    Err(e)
                                        if matches!(
                                            e.kind(),
                                            ErrorKind::WouldBlock
                                                | ErrorKind::TimedOut
                                        ) =>
                                    {
                                        continue;
                                    }
                                    Err(_) => break,
                                }
                            }
                        }));
                    }
                    Err(_) => {
                        std::thread::sleep(Duration::from_millis(5))
                    }
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        (addr, stop, handle)
    }

    fn pattern(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i % 251) as u8).collect()
    }

    /// Round-trip `data` through `proxy` → echo server; returns what
    /// came back (may be shorter than sent if the proxy severed).
    fn round_trip(proxy: &FaultProxy, data: &[u8]) -> Vec<u8> {
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        let _ = s.write_all(data);
        let mut got = Vec::new();
        let mut buf = [0u8; 4096];
        let mut idle = 0;
        while got.len() < data.len() && idle < 20 {
            match s.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    got.extend_from_slice(&buf[..n]);
                    idle = 0;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    idle += 1;
                }
                Err(_) => break,
            }
        }
        got
    }

    #[test]
    fn schedules_regenerate_deterministically() {
        let a = FaultPlan::corruption(42);
        let b = FaultPlan::corruption(42);
        for conn in 0..8 {
            let sa = a.schedule_for(conn);
            let sb = b.schedule_for(conn);
            assert_eq!(sa.corrupt, sb.corrupt, "conn {conn}");
            assert_eq!(sa.delay_seed, sb.delay_seed, "conn {conn}");
        }
        let c = FaultPlan::corruption(43);
        let diverges = (0..8).any(|k| {
            a.schedule_for(k).corrupt != c.schedule_for(k).corrupt
        });
        assert!(diverges, "different seeds must give different plans");
        // Connections draw distinct schedules from one seed.
        assert_ne!(
            a.schedule_for(0).delay_seed,
            a.schedule_for(1).delay_seed
        );
    }

    #[test]
    fn draw_order_is_independent_of_enabled_classes() {
        // The corruption schedule drawn under a corrupt-only spec
        // must match the one drawn under an everything-on spec.
        let lean = FaultPlan::corruption(7);
        let full = FaultPlan::new(
            7,
            FaultSpec {
                delay_chance: 0.5,
                corrupt: true,
                cut: true,
                black_hole: true,
                ..FaultSpec::default()
            },
        );
        for conn in 0..8 {
            assert_eq!(
                lean.schedule_for(conn).corrupt,
                full.schedule_for(conn).corrupt,
                "conn {conn}"
            );
        }
    }

    #[test]
    fn clean_proxy_is_transparent() {
        let (addr, stop, server) = echo_server();
        let proxy = FaultProxy::spawn(addr, FaultPlan::clean(1)).unwrap();
        let sent = pattern(4096);
        let got = round_trip(&proxy, &sent);
        assert_eq!(got, sent);
        let stats = proxy.stats();
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.refused, 0);
        assert_eq!(stats.corrupted + stats.cuts + stats.stalls, 0);
        assert!(stats.bytes_forwarded >= 2 * sent.len() as u64);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap();
    }

    #[test]
    fn corruption_flips_exactly_one_bit() {
        let (addr, stop, server) = echo_server();
        // Narrow span so a 4 KiB payload always crosses the offset.
        let plan = FaultPlan::new(
            5,
            FaultSpec {
                corrupt: true,
                offset_span: 1024,
                ..FaultSpec::default()
            },
        );
        let proxy = FaultProxy::spawn(addr, plan).unwrap();
        let sent = pattern(4096);
        let got = round_trip(&proxy, &sent);
        assert_eq!(got.len(), sent.len());
        let flipped: u32 = sent
            .iter()
            .zip(&got)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1, "exactly one bit must flip");
        assert_eq!(proxy.stats().corrupted, 1);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap();
    }

    #[test]
    fn cut_severs_mid_stream() {
        let (addr, stop, server) = echo_server();
        let plan = FaultPlan::new(
            9,
            FaultSpec {
                cut: true,
                offset_span: 1024,
                ..FaultSpec::default()
            },
        );
        let proxy = FaultProxy::spawn(addr, plan).unwrap();
        let sent = pattern(8192);
        let got = round_trip(&proxy, &sent);
        assert!(
            got.len() < sent.len(),
            "cut connection returned everything"
        );
        assert_eq!(proxy.stats().cuts, 1);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap();
    }

    #[test]
    fn black_hole_stalls_then_severs() {
        let (addr, stop, server) = echo_server();
        let plan = FaultPlan::new(
            11,
            FaultSpec {
                black_hole: true,
                max_stall: Duration::from_millis(100),
                offset_span: 1024,
                ..FaultSpec::default()
            },
        );
        let proxy = FaultProxy::spawn(addr, plan).unwrap();
        let sent = pattern(8192);
        let got = round_trip(&proxy, &sent);
        assert!(got.len() < sent.len());
        assert_eq!(proxy.stats().stalls, 1);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap();
    }

    #[test]
    fn flap_refuses_scheduled_attempts_then_heals() {
        let (addr, stop, server) = echo_server();
        let proxy =
            FaultProxy::spawn(addr, FaultPlan::flap(3, 2)).unwrap();
        let mut outcomes = Vec::new();
        for _ in 0..4 {
            // One byte sits below min_offset, so an accepted
            // connection echoes it back; a refused one sees EOF.
            let got = round_trip(&proxy, &[0xA5]);
            outcomes.push(got == [0xA5]);
        }
        assert_eq!(
            outcomes,
            vec![true, false, false, true],
            "conn 0 accepted, 1..=2 refused, 3 accepted"
        );
        let stats = proxy.stats();
        assert_eq!(stats.connections, 4);
        assert_eq!(stats.refused, 2);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap();
    }
}
