//! Router: multiplexes clients over remote shard-server processes with
//! the *same* rendezvous placement as the in-process plane.
//!
//! Placement is [`crate::coordinator::shard::assign`] over the model id
//! and the number of shard *addresses* — the identical function the
//! in-process `ShardSet` uses over executor lanes. A model's traffic
//! therefore always lands on one shard process, and an `n`-process
//! remote plane serves decisions bit-identical to an in-process one
//! (sharding changes *where* a tenant is served, never *what*).
//!
//! Each shard address gets one TCP connection plus a tender thread that
//! owns its lifecycle: connect → handshake → demultiplex responses →
//! on death, fail every in-flight request of *that shard only* with a
//! typed [`PredictErrorKind::Exec`] and reconnect with exponential
//! backoff (50 ms doubling to the configured ceiling). While a shard is
//! down, submissions placed on it fail fast at submit; other shards'
//! tenants are untouched. Nothing ever hangs waiting for a dead peer.
//!
//! [`RemoteClient`] and [`RemoteSession`] mirror the in-process
//! [`crate::coordinator::Client`]/[`crate::coordinator::Session`]
//! surface method-for-method, so callers swap a local plane for a
//! remote one without touching their submit/completion logic.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::shard::assign;
use crate::coordinator::{
    Completion, Metrics, MetricsSnapshot, MetricsState, ModelId,
    PredictError, PredictErrorKind, PredictResponse, ShardHealth,
    DEFAULT_MODEL,
};
use crate::linalg::Mat;
use crate::util::sync::lock_unpoisoned;
use crate::{log_info, log_warn, Error, Result};

use super::wire::{self, Message, WIRE_VERSION};

/// Tuning knobs for a [`Router`].
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Name announced in the wire handshake (diagnostics only).
    pub client_name: String,
    /// Per-attempt TCP connect + handshake timeout. [`Router::connect`]
    /// waits up to twice this for every shard to come up.
    pub connect_timeout: Duration,
    /// Reconnect backoff ceiling (floor is 50 ms, doubling).
    pub reconnect_ceiling: Duration,
    /// Round-trip timeout for control messages (metrics pull, refresh).
    pub control_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            client_name: "router".to_string(),
            connect_timeout: Duration::from_secs(2),
            reconnect_ceiling: Duration::from_secs(2),
            control_timeout: Duration::from_secs(5),
        }
    }
}

const BACKOFF_FLOOR: Duration = Duration::from_millis(50);

/// In-flight bookkeeping for one request: where its completion goes
/// and which model it addressed (needed to type a fail-fast error).
struct PendingEntry {
    reply: Sender<Completion>,
    model: ModelId,
}

/// Mutable half of one shard connection, shared by submitters (write
/// side) and the tender's read loop (demux side).
#[derive(Default)]
struct LinkState {
    /// Write half of the live connection; `None` while the shard is
    /// down — submits placed here fail fast instead of queueing.
    conn: Option<TcpStream>,
    pending: HashMap<u64, PendingEntry>,
    /// FIFO waiters for `Metrics` replies (TCP preserves order, so
    /// pull k is answered by reply k).
    metrics_waiters: VecDeque<Sender<Vec<MetricsState>>>,
    /// FIFO waiters for `Ack` replies to `Refresh`.
    ack_waiters: VecDeque<Sender<()>>,
}

/// Connection-lifecycle counters for one link, kept by its tender.
/// Exposed through [`Router::link_health`] so chaos tests (and the
/// metrics surface) can assert reconnect behaviour without timing
/// heuristics: the tender records exactly what it did.
#[derive(Default)]
struct LinkLedger {
    /// Successful connect + handshake cycles.
    connects: AtomicU64,
    /// Failed connect attempts (refused, timed out, bad handshake).
    failures: AtomicU64,
    /// Largest backoff actually slept, in ms (the 50ms→ceiling
    /// envelope a chaos test pins).
    max_backoff_ms: AtomicU64,
}

/// Snapshot of one link's lifecycle, from [`Router::link_health`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkHealth {
    /// Shard index (position in the connect-time address list).
    pub shard: usize,
    /// Shard address as dialed.
    pub addr: String,
    /// Successful connect + handshake cycles.
    pub connects: u64,
    /// Recoveries: successful connects after the first.
    pub reconnects: u64,
    /// Failed connect attempts.
    pub failures: u64,
    /// Largest reconnect backoff actually slept, in ms.
    pub max_backoff_ms: u64,
}

struct Link {
    index: usize,
    addr: String,
    state: Mutex<LinkState>,
    ledger: LinkLedger,
}

impl Link {
    fn alive(&self) -> bool {
        lock_unpoisoned(&self.state).conn.is_some()
    }

    /// Kill the connection (if any) and fail every in-flight request of
    /// this shard with a typed `Exec` error — fail fast, never hang.
    fn teardown(&self, why: &str) {
        let (pending, had_conn) = {
            let mut st = lock_unpoisoned(&self.state);
            let had = match st.conn.take() {
                Some(c) => {
                    let _ = c.shutdown(Shutdown::Both);
                    true
                }
                None => false,
            };
            st.metrics_waiters.clear();
            st.ack_waiters.clear();
            (std::mem::take(&mut st.pending), had)
        };
        if had_conn || !pending.is_empty() {
            log_warn!(
                "router: shard {} ({}) down ({why}), failing {} in-flight",
                self.index,
                self.addr,
                pending.len()
            );
        }
        for (id, entry) in pending {
            let err = PredictError {
                id,
                model: entry.model,
                kind: PredictErrorKind::Exec {
                    detail: format!(
                        "shard {} ({}) disconnected: {why}",
                        self.index, self.addr
                    ),
                },
            };
            let _ = entry.reply.send(Err(err));
        }
    }
}

struct RouterInner {
    links: Vec<Arc<Link>>,
    /// Model → feature dimension, merged from every shard's handshake
    /// (client-side dim validation without a round-trip per request).
    dims: Arc<Mutex<HashMap<String, u32>>>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    tenders: Mutex<Vec<JoinHandle<()>>>,
    config: RouterConfig,
}

/// A connected remote serving plane over one or more shard-server
/// processes. Cheap to clone (shared handle); hand out
/// [`Router::client`]s for submission, exactly like
/// [`crate::coordinator::Coordinator::client`].
#[derive(Clone)]
pub struct Router {
    inner: Arc<RouterInner>,
}

impl Router {
    /// Connect to shard servers at `addrs` (placement order — must
    /// match across every router of a plane). Fails unless every shard
    /// answers its handshake within the startup window; after that,
    /// individual shard deaths degrade to fail-fast errors for their
    /// tenants only, with reconnection in the background.
    pub fn connect(addrs: &[String], config: RouterConfig) -> Result<Router> {
        if addrs.is_empty() {
            return Err(Error::InvalidArg(
                "router needs at least one shard address".into(),
            ));
        }
        let links: Vec<Arc<Link>> = addrs
            .iter()
            .enumerate()
            .map(|(index, addr)| {
                Arc::new(Link {
                    index,
                    addr: addr.clone(),
                    state: Mutex::new(LinkState::default()),
                    ledger: LinkLedger::default(),
                })
            })
            .collect();
        let inner = Arc::new(RouterInner {
            links,
            dims: Arc::new(Mutex::new(HashMap::new())),
            next_id: AtomicU64::new(0),
            stop: Arc::new(AtomicBool::new(false)),
            tenders: Mutex::new(Vec::new()),
            config,
        });
        for link in &inner.links {
            let link = link.clone();
            let dims = inner.dims.clone();
            let stop = inner.stop.clone();
            let cfg = inner.config.clone();
            let handle = std::thread::Builder::new()
                .name(format!("approxrbf-net-tender-{}", link.index))
                .spawn(move || run_tender(link, dims, stop, cfg))
                .map_err(|e| Error::Other(format!("spawn tender: {e}")))?;
            lock_unpoisoned(&inner.tenders).push(handle);
        }
        // Startup barrier: every shard must come up once.
        let deadline = Instant::now() + inner.config.connect_timeout * 2;
        loop {
            if inner.links.iter().all(|l| l.alive()) {
                break;
            }
            if Instant::now() >= deadline {
                let down: Vec<&str> = inner
                    .links
                    .iter()
                    .filter(|l| !l.alive())
                    .map(|l| l.addr.as_str())
                    .collect();
                inner.shutdown_impl();
                return Err(Error::Other(format!(
                    "router: shard(s) unreachable at startup: {}",
                    down.join(", ")
                )));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        Ok(Router { inner })
    }

    /// Where `model` is placed: the same rendezvous function the
    /// in-process `ShardSet` uses, over shard *processes*.
    pub fn place_for(model: &str, n_shards: usize) -> usize {
        assign(model, n_shards)
    }

    /// Number of shard processes behind this router.
    pub fn shard_count(&self) -> usize {
        self.inner.links.len()
    }

    /// A new independent [`RemoteClient`] handle (cheap; cloneable).
    pub fn client(&self) -> RemoteClient {
        RemoteClient::new(self.inner.clone())
    }

    /// Model → feature dimension table merged from the shard
    /// handshakes.
    pub fn model_dims(&self) -> HashMap<String, u32> {
        lock_unpoisoned(&self.inner.dims).clone()
    }

    /// Serving metrics aggregated across every reachable shard: each
    /// shard ships its raw per-lane sink states, the router rebuilds
    /// them with [`Metrics::from_state`] and merges through the same
    /// [`Metrics::aggregate`] the in-process plane uses (exact, not
    /// averaged averages). Unreachable shards are skipped with a
    /// warning.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut sinks: Vec<Metrics> = Vec::new();
        for link in &self.inner.links {
            match self.inner.pull_metrics(link) {
                Ok(states) => {
                    sinks.extend(states.iter().map(Metrics::from_state));
                }
                Err(e) => log_warn!(
                    "router: metrics pull from shard {} ({}) failed: {e}",
                    link.index,
                    link.addr
                ),
            }
        }
        let refs: Vec<&Metrics> = sinks.iter().collect();
        let mut snap = Metrics::aggregate(&refs);
        snap.shard_health = self
            .link_health()
            .into_iter()
            .map(|h| ShardHealth {
                shard: h.shard,
                reconnects: h.reconnects,
                // Process restarts are the supervisor's to report;
                // merge via `MetricsSnapshot::record_restarts`.
                restarts: 0,
            })
            .collect();
        snap
    }

    /// Per-link connection-lifecycle counters, as recorded by the
    /// tender threads (connects, reconnects, failed attempts, and the
    /// largest backoff actually slept).
    pub fn link_health(&self) -> Vec<LinkHealth> {
        self.inner
            .links
            .iter()
            .map(|l| {
                let connects =
                    l.ledger.connects.load(Ordering::Relaxed);
                LinkHealth {
                    shard: l.index,
                    addr: l.addr.clone(),
                    connects,
                    reconnects: connects.saturating_sub(1),
                    failures: l.ledger.failures.load(Ordering::Relaxed),
                    max_backoff_ms: l
                        .ledger
                        .max_backoff_ms
                        .load(Ordering::Relaxed),
                }
            })
            .collect()
    }

    /// Ask every reachable shard to revalidate model generations now
    /// (remote [`crate::coordinator::Coordinator::refresh`]); returns
    /// how many shards acknowledged.
    pub fn refresh(&self) -> Result<usize> {
        let mut acked = 0usize;
        for link in &self.inner.links {
            match self.inner.refresh_link(link) {
                Ok(()) => acked += 1,
                Err(e) => log_warn!(
                    "router: refresh of shard {} ({}) failed: {e}",
                    link.index,
                    link.addr
                ),
            }
        }
        Ok(acked)
    }

    /// Disconnect every shard, failing whatever is still in flight,
    /// and join the tender threads. Idempotent; also runs on drop of
    /// the last handle.
    pub fn shutdown(&self) {
        self.inner.shutdown_impl();
    }
}

impl RouterInner {
    /// The submit path shared by [`RemoteClient`] and
    /// [`RemoteSession`] — mirrors the in-process `Shared::submit_with`
    /// contract: validate, place, enqueue (here: frame onto the owning
    /// shard's socket), return the request id; every accepted request
    /// is answered with exactly one completion on `reply`.
    fn submit_with(
        &self,
        model: &str,
        features: Vec<f32>,
        reply: &Sender<Completion>,
    ) -> std::result::Result<u64, PredictError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mid: ModelId = Arc::from(model);
        if let Some(&want) = lock_unpoisoned(&self.dims).get(model) {
            if features.len() != want as usize {
                return Err(PredictError {
                    id,
                    model: mid,
                    kind: PredictErrorKind::DimMismatch {
                        got: features.len(),
                        want: want as usize,
                    },
                });
            }
        }
        let shard = assign(model, self.links.len());
        let link = &self.links[shard];
        let frame = wire::encode_frame(&Message::Request {
            id,
            model: model.to_string(),
            features,
        })
        .map_err(|e| PredictError {
            id,
            model: mid.clone(),
            kind: PredictErrorKind::Exec {
                detail: format!("request encode failed: {e}"),
            },
        })?;
        let mut st = lock_unpoisoned(&link.state);
        // Taking the stream out (and putting it back after a good
        // write) sidesteps a second `conn` unwrap; the link lock is
        // held throughout, so no other submitter observes the gap.
        let Some(mut conn) = st.conn.take() else {
            return Err(PredictError {
                id,
                model: mid,
                kind: PredictErrorKind::Exec {
                    detail: format!(
                        "shard {} ({}) unreachable",
                        link.index, link.addr
                    ),
                },
            });
        };
        st.pending.insert(
            id,
            PendingEntry { reply: reply.clone(), model: mid.clone() },
        );
        // Holding the link lock across the write keeps frames atomic on
        // the socket across concurrent submitters.
        if let Err(e) = conn.write_all(&frame) {
            st.pending.remove(&id);
            let _ = conn.shutdown(Shutdown::Both);
            return Err(PredictError {
                id,
                model: mid,
                kind: PredictErrorKind::Exec {
                    detail: format!(
                        "shard {} ({}): write failed: {e}",
                        link.index, link.addr
                    ),
                },
            });
        }
        st.conn = Some(conn);
        Ok(id)
    }

    /// Send one control frame and register a FIFO waiter for its reply
    /// under the link lock (so registration order matches wire order).
    fn send_control<T>(
        &self,
        link: &Link,
        msg: &Message,
        enqueue: impl FnOnce(&mut LinkState, Sender<T>),
    ) -> Result<Receiver<T>> {
        let frame = wire::encode_frame(msg)?;
        let (tx, rx) = mpsc::channel();
        let mut st = lock_unpoisoned(&link.state);
        let Some(conn) = st.conn.as_mut() else {
            return Err(Error::Other(format!(
                "shard {} ({}) unreachable",
                link.index, link.addr
            )));
        };
        if let Err(e) = conn.write_all(&frame) {
            if let Some(c) = st.conn.take() {
                let _ = c.shutdown(Shutdown::Both);
            }
            return Err(Error::Other(format!(
                "shard {} ({}): write failed: {e}",
                link.index, link.addr
            )));
        }
        enqueue(&mut st, tx);
        Ok(rx)
    }

    fn pull_metrics(&self, link: &Link) -> Result<Vec<MetricsState>> {
        let rx = self.send_control(link, &Message::MetricsPull, |st, tx| {
            st.metrics_waiters.push_back(tx)
        })?;
        rx.recv_timeout(self.config.control_timeout).map_err(|_| {
            Error::Other(format!(
                "shard {} ({}): metrics pull timed out",
                link.index, link.addr
            ))
        })
    }

    fn refresh_link(&self, link: &Link) -> Result<()> {
        let rx = self.send_control(link, &Message::Refresh, |st, tx| {
            st.ack_waiters.push_back(tx)
        })?;
        rx.recv_timeout(self.config.control_timeout).map_err(|_| {
            Error::Other(format!(
                "shard {} ({}): refresh timed out",
                link.index, link.addr
            ))
        })
    }

    fn shutdown_impl(&self) {
        self.stop.store(true, Ordering::Relaxed);
        for link in &self.links {
            link.teardown("router shutdown");
        }
        let tenders: Vec<_> =
            lock_unpoisoned(&self.tenders).drain(..).collect();
        for t in tenders {
            let _ = t.join();
        }
    }
}

impl Drop for RouterInner {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Sleep in 50 ms slices so shutdown is not held up by a backoff nap.
/// Shared with the `faultnet` proxy and the `serve-plane` supervisor,
/// whose pauses must yield to shutdown the same way.
pub(crate) fn sleep_interruptible(total: Duration, stop: &AtomicBool) {
    let mut left = total;
    while left > Duration::ZERO && !stop.load(Ordering::Relaxed) {
        let step = left.min(Duration::from_millis(50));
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
}

/// One connect + handshake attempt. Returns the stream (read timeout
/// cleared) and the shard's advertised dim table.
fn connect_once(
    link: &Link,
    cfg: &RouterConfig,
) -> Result<(TcpStream, Vec<(String, u32)>)> {
    let sa = link
        .addr
        .to_socket_addrs()
        .map_err(Error::Io)?
        .next()
        .ok_or_else(|| {
            Error::InvalidArg(format!("unresolvable address '{}'", link.addr))
        })?;
    let mut stream =
        TcpStream::connect_timeout(&sa, cfg.connect_timeout)
            .map_err(Error::Io)?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(cfg.connect_timeout))
        .map_err(Error::Io)?;
    wire::write_frame(
        &mut stream,
        &Message::Hello {
            version: WIRE_VERSION,
            client: cfg.client_name.clone(),
        },
    )?;
    match wire::read_frame(&mut stream)? {
        Some(Message::HelloAck { version, shard_id, shard_count, dims }) => {
            if version != WIRE_VERSION {
                return Err(Error::Other(format!(
                    "shard {} speaks wire v{version}, router speaks \
                     v{WIRE_VERSION}",
                    link.index
                )));
            }
            if shard_id as usize != link.index {
                log_warn!(
                    "router: shard at {} announces id {shard_id}, placed \
                     as {} — check --shard-id flags",
                    link.addr,
                    link.index
                );
            }
            log_info!(
                "router: shard {} ({}) up — {} lanes, {} models",
                link.index,
                link.addr,
                shard_count,
                dims.len()
            );
            // Blocking reads from here on; death arrives as EOF/reset.
            stream.set_read_timeout(None).map_err(Error::Io)?;
            Ok((stream, dims))
        }
        Some(Message::Error(e)) => {
            Err(Error::Other(format!("shard refused handshake: {e}")))
        }
        Some(m) => Err(Error::Corrupt(format!(
            "expected HelloAck, got frame kind {}",
            m.kind()
        ))),
        None => Err(Error::Other(
            "connection closed during handshake".to_string(),
        )),
    }
}

/// Own one shard connection for the router's lifetime: connect,
/// handshake, demux until death, fail in-flight, back off, repeat.
fn run_tender(
    link: Arc<Link>,
    dims: Arc<Mutex<HashMap<String, u32>>>,
    stop: Arc<AtomicBool>,
    cfg: RouterConfig,
) {
    let mut backoff = BACKOFF_FLOOR;
    while !stop.load(Ordering::Relaxed) {
        match connect_once(&link, &cfg) {
            Ok((stream, table)) => {
                backoff = BACKOFF_FLOOR;
                link.ledger.connects.fetch_add(1, Ordering::Relaxed);
                {
                    let mut d = lock_unpoisoned(&dims);
                    for (id, dim) in table {
                        d.insert(id, dim);
                    }
                }
                match stream.try_clone() {
                    Ok(write_half) => {
                        lock_unpoisoned(&link.state).conn =
                            Some(write_half);
                    }
                    Err(e) => {
                        log_warn!("router: stream clone failed: {e}");
                        continue;
                    }
                }
                let why = read_loop(&link, stream, &stop);
                link.teardown(&why);
            }
            Err(e) => {
                link.ledger.failures.fetch_add(1, Ordering::Relaxed);
                log_warn!(
                    "router: connect to shard {} ({}) failed: {e}",
                    link.index,
                    link.addr
                );
            }
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
        link.ledger
            .max_backoff_ms
            .fetch_max(backoff.as_millis() as u64, Ordering::Relaxed);
        sleep_interruptible(backoff, &stop);
        backoff = (backoff * 2).min(cfg.reconnect_ceiling);
    }
    link.teardown("router shutdown");
}

/// Demultiplex one live connection until it dies; returns why.
fn read_loop(
    link: &Link,
    mut stream: TcpStream,
    stop: &AtomicBool,
) -> String {
    loop {
        if stop.load(Ordering::Relaxed) {
            return "router shutdown".to_string();
        }
        let msg = match wire::read_frame(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => return "connection closed".to_string(),
            Err(e) => return format!("read failed: {e}"),
        };
        match msg {
            Message::Response(r) => deliver(link, r.id, Ok(r)),
            Message::Error(e) => {
                let oob = e.id == 0
                    && !lock_unpoisoned(&link.state)
                        .pending
                        .contains_key(&e.id);
                if oob {
                    // Out-of-band server complaint (e.g. handshake-era
                    // refusal); nothing to correlate it with.
                    log_warn!("router: shard {} reports: {e}", link.index);
                } else {
                    deliver(link, e.id, Err(e));
                }
            }
            Message::Metrics(states) => {
                let waiter =
                    lock_unpoisoned(&link.state).metrics_waiters.pop_front();
                match waiter {
                    Some(tx) => {
                        let _ = tx.send(states);
                    }
                    None => log_warn!(
                        "router: unsolicited metrics from shard {}",
                        link.index
                    ),
                }
            }
            Message::Ack => {
                let waiter =
                    lock_unpoisoned(&link.state).ack_waiters.pop_front();
                match waiter {
                    Some(tx) => {
                        let _ = tx.send(());
                    }
                    None => log_warn!(
                        "router: unsolicited ack from shard {}",
                        link.index
                    ),
                }
            }
            Message::Pong => {}
            other => {
                return format!(
                    "protocol violation: frame kind {} from server",
                    other.kind()
                );
            }
        }
    }
}

/// Hand a completion to whoever is waiting on its request id.
fn deliver(link: &Link, id: u64, completion: Completion) {
    let entry = lock_unpoisoned(&link.state).pending.remove(&id);
    match entry {
        Some(e) => {
            let _ = e.reply.send(completion);
        }
        None => log_warn!(
            "router: completion for unknown request {id} from shard {}",
            link.index
        ),
    }
}

// ---------------------------------------------------------------------
// RemoteClient / RemoteSession — the in-process Client surface, remote
// ---------------------------------------------------------------------

/// A submission handle over a [`Router`], mirroring
/// [`crate::coordinator::Client`] method-for-method: per-client
/// completion channel, typed fail-fast errors, same batch helpers. Code
/// written against the in-process client runs unmodified against a
/// remote plane.
pub struct RemoteClient {
    inner: Arc<RouterInner>,
    reply_tx: Sender<Completion>,
    reply_rx: Mutex<Receiver<Completion>>,
}

impl Clone for RemoteClient {
    /// A clone is an independent client: same plane, fresh completion
    /// channel.
    fn clone(&self) -> RemoteClient {
        RemoteClient::new(self.inner.clone())
    }
}

impl RemoteClient {
    fn new(inner: Arc<RouterInner>) -> RemoteClient {
        let (reply_tx, reply_rx) = mpsc::channel();
        RemoteClient { inner, reply_tx, reply_rx: Mutex::new(reply_rx) }
    }

    /// Enqueue one instance for [`DEFAULT_MODEL`]; returns its request
    /// id.
    pub fn submit(
        &self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Enqueue one instance for a named model on its owning shard
    /// process.
    pub fn submit_to(
        &self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.inner.submit_with(model, features, &self.reply_tx)
    }

    /// Receive this client's next completion (any order across
    /// shards). `None` on timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        lock_unpoisoned(&self.reply_rx).recv_timeout(timeout).ok()
    }

    /// Open a [`RemoteSession`]: a scoped group of submissions with its
    /// own completion channel and ordered [`RemoteSession::wait_all`].
    pub fn session(&self) -> RemoteSession<'_> {
        let (reply_tx, reply_rx) = mpsc::channel();
        RemoteSession {
            client: self,
            reply_tx,
            reply_rx,
            submitted: Vec::new(),
        }
    }

    /// Synchronous convenience: submit every row of `z` to
    /// [`DEFAULT_MODEL`] and return the responses ordered by row,
    /// failing fast on the first [`PredictError`].
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        self.predict_all_for(DEFAULT_MODEL, z)
    }

    /// [`RemoteClient::predict_all`] addressed to a named model.
    pub fn predict_all_for(
        &self,
        model: &str,
        z: &Mat,
    ) -> Result<Vec<PredictResponse>> {
        if z.rows() == 0 {
            return Err(Error::InvalidArg("empty batch".into()));
        }
        let mut session = self.session();
        for r in 0..z.rows() {
            session
                .submit_to(model, z.row(r).to_vec())
                .map_err(Error::from)?;
        }
        let completions = session.wait_all(Duration::from_secs(600))?;
        completions
            .into_iter()
            .map(|c| c.map_err(Error::from))
            .collect()
    }

    /// Serving metrics aggregated across every reachable shard (see
    /// [`Router::metrics`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        Router { inner: self.inner.clone() }.metrics()
    }

    /// Requests queued across the remote plane's ingresses, from the
    /// shards' queue-depth gauges (one metrics round-trip).
    pub fn queue_depth(&self) -> usize {
        self.metrics().queue_depth as usize
    }
}

/// A scoped batch of submissions with a private completion channel —
/// the remote mirror of [`crate::coordinator::Session`], with the same
/// ordering and fail-fast guarantees.
pub struct RemoteSession<'c> {
    client: &'c RemoteClient,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    submitted: Vec<(u64, ModelId)>,
}

impl RemoteSession<'_> {
    /// Submit one instance for [`DEFAULT_MODEL`].
    pub fn submit(
        &mut self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Submit one instance for a named model.
    pub fn submit_to(
        &mut self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        let id = self
            .client
            .inner
            .submit_with(model, features, &self.reply_tx)?;
        self.submitted.push((id, Arc::from(model)));
        Ok(id)
    }

    /// Number of submissions made through this session.
    pub fn len(&self) -> usize {
        self.submitted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.submitted.is_empty()
    }

    /// Receive this session's next completion (unordered). `None` on
    /// timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        self.reply_rx.recv_timeout(timeout).ok()
    }

    /// Wait for every submission's completion and return them in
    /// submission order — the same contract as the in-process
    /// [`crate::coordinator::Session::wait_all`]: a dead shard's
    /// requests come back as typed errors (delivered by the router's
    /// teardown), and if every reply sender disappears the remainder
    /// completes as [`PredictErrorKind::Shutdown`] rather than
    /// hanging. Errors with [`Error::Other`] only if `timeout` elapses
    /// first.
    pub fn wait_all(self, timeout: Duration) -> Result<Vec<Completion>> {
        let RemoteSession { client: _, reply_tx, reply_rx, submitted } =
            self;
        drop(reply_tx);
        let n = submitted.len();
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(n);
        for (i, (id, _)) in submitted.iter().enumerate() {
            index.insert(*id, i);
        }
        let mut out: Vec<Option<Completion>> = vec![None; n];
        let mut got = 0usize;
        let deadline = Instant::now() + timeout;
        while got < n {
            let Some(remaining) =
                deadline.checked_duration_since(Instant::now())
            else {
                return Err(Error::Other(format!(
                    "session wait_all timed out with {got}/{n} completions"
                )));
            };
            match reply_rx.recv_timeout(remaining) {
                Ok(c) => {
                    let id = match &c {
                        Ok(resp) => resp.id,
                        Err(e) => e.id,
                    };
                    if let Some(&i) = index.get(&id) {
                        if out[i].is_none() {
                            out[i] = Some(c);
                            got += 1;
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    for (i, (id, model)) in submitted.iter().enumerate() {
                        if out[i].is_none() {
                            out[i] = Some(Err(PredictError {
                                id: *id,
                                model: model.clone(),
                                kind: PredictErrorKind::Shutdown,
                            }));
                            got += 1;
                        }
                    }
                }
            }
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;

    /// Independent FNV-1a/HRW reimplementation. Pins the placement
    /// function: if either side drifts, router-side placement would
    /// silently diverge from the in-process `ShardSet`'s and a tenant
    /// would be served by a shard that does not own it.
    fn hrw_reference(model: &str, n_shards: usize) -> usize {
        if n_shards <= 1 {
            return 0;
        }
        let weight = |shard: u64| -> u64 {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for &b in model.as_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            for b in shard.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            h
        };
        (0..n_shards as u64)
            .max_by_key(|&s| weight(s))
            .unwrap_or(0) as usize
    }

    #[test]
    fn placement_parity_router_vs_inprocess_10k() {
        prop_cases!("placement-parity", 10_000, |rng| {
            let len = 1 + rng.below(24);
            let name: String = (0..len)
                .map(|_| {
                    let alphabet =
                        b"abcdefghijklmnopqrstuvwxyz0123456789-_.";
                    alphabet[rng.below(alphabet.len())] as char
                })
                .collect();
            for n in [1usize, 2, 3, 5, 8, 64] {
                let router_side = Router::place_for(&name, n);
                let in_process = assign(&name, n);
                assert_eq!(
                    router_side, in_process,
                    "router and ShardSet disagree on '{name}' over {n} \
                     shards"
                );
                assert_eq!(
                    router_side,
                    hrw_reference(&name, n),
                    "placement drifted from the pinned FNV-1a/HRW for \
                     '{name}' over {n} shards"
                );
                assert!(router_side < n);
            }
        });
    }

    #[test]
    fn placement_is_stable_as_shards_join() {
        // Rendezvous property: growing the plane only ever moves a
        // tenant to the *new* shard, never between old ones.
        let models: Vec<String> =
            (0..200).map(|i| format!("tenant-{i}")).collect();
        for n in 2..10usize {
            let mut moved_elsewhere = 0;
            for m in &models {
                let before = Router::place_for(m, n);
                let after = Router::place_for(m, n + 1);
                if after != before && after != n {
                    moved_elsewhere += 1;
                }
            }
            assert_eq!(
                moved_elsewhere, 0,
                "a tenant moved between pre-existing shards when shard \
                 {n} joined"
            );
        }
    }

    #[test]
    fn config_defaults_are_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.connect_timeout > Duration::ZERO);
        assert!(cfg.reconnect_ceiling >= BACKOFF_FLOOR);
        assert!(cfg.control_timeout > Duration::ZERO);
        assert_eq!(cfg.client_name, "router");
    }
}
