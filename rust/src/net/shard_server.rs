//! Shard server: fronts one in-process [`Coordinator`] with the `ARBW`
//! wire protocol over `std::net::TcpListener`.
//!
//! Per connection the server runs three threads:
//!
//! * **reader** — parses frames off the socket, answers control
//!   messages inline (metrics pull, refresh, ping) and submits
//!   `Request` frames through the coordinator's transport seam
//!   ([`Coordinator`] `submit_with`), under a bounded in-flight window
//!   (backpressure per connection, not just per ingress queue);
//! * **pump** — drains the connection's completion channel and
//!   rewrites coordinator-assigned request ids back to the client's
//!   correlation ids;
//! * **writer** — serializes outbound frames behind a `BufWriter`,
//!   flushing whenever its queue drains.
//!
//! Because the coordinator answers every accepted request with exactly
//! one completion, a dying connection never strands client state: the
//! pump drains whatever is still in flight (the frames go to a dead
//! socket, which is fine) and all three threads exit.
//!
//! Timeouts: the socket read timeout doubles as the idle timeout — a
//! peer that sends nothing for [`ShardServerConfig::read_timeout`] is
//! disconnected. There is deliberately no *write* pacing: slow readers
//! are bounded by the in-flight window instead.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{
    Completion, Coordinator, PredictError, PredictErrorKind,
};
use crate::registry::ModelStore;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};
use crate::{log_info, log_warn, Error, Result};

use super::wire::{self, Message, WIRE_VERSION};

/// Tuning knobs for a [`ShardServer`].
#[derive(Clone, Debug)]
pub struct ShardServerConfig {
    /// This server's shard index in the plane it participates in
    /// (announced in the handshake; a router sanity-checks it against
    /// the position of this address in its `--shards` list).
    pub shard_id: u32,
    /// Max requests in flight per connection before the reader stops
    /// pulling new frames off the socket (bounded window).
    pub max_in_flight: usize,
    /// Socket read timeout; doubles as the idle timeout after which a
    /// silent peer is disconnected.
    pub read_timeout: Duration,
}

impl Default for ShardServerConfig {
    fn default() -> Self {
        ShardServerConfig {
            shard_id: 0,
            max_in_flight: 1024,
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// Bounded in-flight window, shared by a connection's reader (acquire)
/// and pump (release).
struct InFlight {
    n: Mutex<usize>,
    cv: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { n: Mutex::new(0), cv: Condvar::new() }
    }

    /// Block until a slot frees up; `false` if shutdown was requested
    /// while waiting.
    fn acquire(&self, max: usize, shutdown: &AtomicBool) -> bool {
        let mut n = lock_unpoisoned(&self.n);
        while *n >= max {
            if shutdown.load(Ordering::Relaxed) {
                return false;
            }
            let (guard, _) = wait_timeout_unpoisoned(
                &self.cv,
                n,
                Duration::from_millis(100),
            );
            n = guard;
        }
        *n += 1;
        true
    }

    fn release(&self) {
        let mut n = lock_unpoisoned(&self.n);
        *n = n.saturating_sub(1);
        self.cv.notify_one();
    }
}

/// Correlation state shared by a connection's reader and pump: the
/// coordinator assigns its own request ids, the wire carries the
/// client's. `orphans` holds completions that raced ahead of the
/// reader's id registration (the executor can complete a request
/// before `submit_with`'s caller regains the lock).
#[derive(Default)]
struct ConnState {
    map: HashMap<u64, u64>,
    orphans: Vec<Completion>,
}

fn completion_id(c: &Completion) -> u64 {
    match c {
        Ok(r) => r.id,
        Err(e) => e.id,
    }
}

/// Rewrite a completion's coordinator id to the client's correlation
/// id and wrap it as a wire message.
fn completion_to_wire(c: Completion, wire_id: u64) -> Message {
    match c {
        Ok(mut r) => {
            r.id = wire_id;
            Message::Response(r)
        }
        Err(mut e) => {
            e.id = wire_id;
            Message::Error(e)
        }
    }
}

fn io_timed_out(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// A running shard server. Owns its coordinator: dropping (or
/// [`ShardServer::shutdown`]-ing) the server tears the whole lane
/// down.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    coord: Option<Arc<Coordinator>>,
}

impl ShardServer {
    /// Bind `listen` (e.g. `"127.0.0.1:7070"`, port 0 for ephemeral)
    /// and serve `coord` over it. `store` supplies the handshake's
    /// model dimension table.
    pub fn bind(
        listen: &str,
        coord: Coordinator,
        store: Arc<ModelStore>,
        config: ShardServerConfig,
    ) -> Result<ShardServer> {
        let listener = TcpListener::bind(listen).map_err(Error::Io)?;
        let addr = listener.local_addr().map_err(Error::Io)?;
        listener.set_nonblocking(true).map_err(Error::Io)?;
        let coord = Arc::new(coord);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> =
            Arc::new(Mutex::new(Vec::new()));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let a_stop = stop.clone();
        let a_conns = conns.clone();
        let a_handlers = handlers.clone();
        let a_coord = coord.clone();
        let accept = std::thread::Builder::new()
            .name("approxrbf-net-accept".to_string())
            .spawn(move || {
                while !a_stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log_info!("shard server: connection from {peer}");
                            let _ = stream.set_nodelay(true);
                            let _ = stream
                                .set_read_timeout(Some(config.read_timeout));
                            if let Ok(clone) = stream.try_clone() {
                                lock_unpoisoned(&a_conns).push(clone);
                            }
                            let coord = a_coord.clone();
                            let store = store.clone();
                            let cfg = config.clone();
                            let stop = a_stop.clone();
                            let h = std::thread::Builder::new()
                                .name("approxrbf-net-conn".to_string())
                                .spawn(move || {
                                    handle_connection(
                                        stream, coord, store, cfg, stop,
                                    );
                                });
                            match h {
                                Ok(h) => {
                                    lock_unpoisoned(&a_handlers).push(h)
                                }
                                Err(e) => log_warn!(
                                    "shard server: spawn failed: {e}"
                                ),
                            }
                        }
                        Err(e) if io_timed_out(&e) => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(e) => {
                            log_warn!("shard server: accept failed: {e}");
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })
            .map_err(|e| Error::Other(format!("spawn accept loop: {e}")))?;

        Ok(ShardServer {
            addr,
            stop,
            accept: Some(accept),
            conns,
            handlers,
            coord: Some(coord),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, disconnect every peer, join every connection
    /// thread, then shut the coordinator down.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_serving();
        match self.coord.take() {
            Some(coord) => match Arc::try_unwrap(coord) {
                Ok(c) => c.shutdown(),
                // A handler leaked a reference (should not happen after
                // the joins above); its Drop will tear the plane down.
                Err(_) => Ok(()),
            },
            None => Ok(()),
        }
    }

    fn stop_serving(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for s in lock_unpoisoned(&self.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handlers: Vec<_> =
            lock_unpoisoned(&self.handlers).drain(..).collect();
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.stop_serving();
        // The coordinator Arc drops here; its own Drop shuts the
        // serving plane down once the last reference is gone.
    }
}

/// Serve one accepted connection until EOF, idle timeout, damage or
/// server shutdown.
fn handle_connection(
    mut stream: TcpStream,
    coord: Arc<Coordinator>,
    store: Arc<ModelStore>,
    config: ShardServerConfig,
    stop: Arc<AtomicBool>,
) {
    // Handshake: the first frame must be a version-compatible Hello.
    match wire::read_frame(&mut stream) {
        Ok(Some(Message::Hello { version, client }))
            if version == WIRE_VERSION =>
        {
            log_info!("shard server: hello from '{client}' (v{version})");
        }
        Ok(Some(Message::Hello { version, .. })) => {
            // A clean typed refusal, not a hang: the client hears why.
            let refuse = Message::Error(PredictError {
                id: 0,
                model: Arc::from(""),
                kind: PredictErrorKind::Exec {
                    detail: format!(
                        "unsupported wire version {version} (server \
                         speaks {WIRE_VERSION})"
                    ),
                },
            });
            let _ = wire::write_frame(&mut stream, &refuse);
            let _ = stream.flush();
            return;
        }
        Ok(other) => {
            log_warn!(
                "shard server: peer opened with {:?} instead of Hello",
                other.map(|m| m.kind())
            );
            return;
        }
        Err(e) => {
            log_warn!("shard server: handshake read failed: {e}");
            return;
        }
    }
    let dims = match store.list() {
        Ok(infos) => infos
            .iter()
            .map(|i| (i.id.clone(), i.dim as u32))
            .collect(),
        Err(e) => {
            log_warn!("shard server: dim table unavailable: {e}");
            Vec::new()
        }
    };
    let ack = Message::HelloAck {
        version: WIRE_VERSION,
        shard_id: config.shard_id,
        shard_count: coord.shard_count() as u32,
        dims,
    };
    if wire::write_frame(&mut stream, &ack)
        .and_then(|()| stream.flush().map_err(Error::Io))
        .is_err()
    {
        return;
    }

    let Ok(write_stream) = stream.try_clone() else {
        log_warn!("shard server: stream clone failed");
        return;
    };
    let (out_tx, out_rx) = mpsc::channel::<Message>();
    let (reply_tx, reply_rx) = mpsc::channel::<Completion>();
    let window = Arc::new(InFlight::new());
    let state = Arc::new(Mutex::new(ConnState::default()));

    let writer =
        std::thread::spawn(move || run_writer(write_stream, out_rx));
    let pump = {
        let out_tx = out_tx.clone();
        let window = window.clone();
        let state = state.clone();
        std::thread::spawn(move || run_pump(reply_rx, out_tx, window, state))
    };

    // Reader loop (this thread).
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let msg = match wire::read_frame(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => break, // clean EOF
            Err(Error::Io(e)) if io_timed_out(&e) => {
                log_info!("shard server: idle timeout, disconnecting");
                break;
            }
            Err(e) => {
                log_warn!("shard server: dropping connection: {e}");
                break;
            }
        };
        match msg {
            Message::Request { id: wire_id, model, features } => {
                if !window.acquire(config.max_in_flight, &stop) {
                    break;
                }
                match coord.submit_with(&model, features, &reply_tx) {
                    Ok(coord_id) => {
                        let mut st = lock_unpoisoned(&state);
                        if let Some(pos) = st
                            .orphans
                            .iter()
                            .position(|c| completion_id(c) == coord_id)
                        {
                            // The executor finished before we could
                            // register the id; deliver directly.
                            let c = st.orphans.swap_remove(pos);
                            drop(st);
                            window.release();
                            let _ =
                                out_tx.send(completion_to_wire(c, wire_id));
                        } else {
                            st.map.insert(coord_id, wire_id);
                        }
                    }
                    Err(mut e) => {
                        // Submit-side refusal: no completion will ever
                        // arrive for this request, answer inline.
                        window.release();
                        e.id = wire_id;
                        let _ = out_tx.send(Message::Error(e));
                    }
                }
            }
            Message::MetricsPull => {
                let _ =
                    out_tx.send(Message::Metrics(coord.metrics_states()));
            }
            Message::Refresh => {
                coord.refresh();
                let _ = out_tx.send(Message::Ack);
            }
            Message::Ping => {
                let _ = out_tx.send(Message::Pong);
            }
            other => {
                log_warn!(
                    "shard server: unexpected frame kind {} mid-stream, \
                     dropping connection",
                    other.kind()
                );
                break;
            }
        }
    }

    // Teardown: once our reply sender is gone, the pump's channel
    // disconnects after the last in-flight completion arrives (the
    // coordinator completes every accepted request exactly once), then
    // the writer's queue disconnects after the pump drops its sender.
    drop(reply_tx);
    let _ = pump.join();
    drop(out_tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// Translate completions to wire frames until every reply sender is
/// gone (reader exited *and* nothing is left in flight).
fn run_pump(
    reply_rx: Receiver<Completion>,
    out_tx: Sender<Message>,
    window: Arc<InFlight>,
    state: Arc<Mutex<ConnState>>,
) {
    loop {
        match reply_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(c) => {
                let coord_id = completion_id(&c);
                let mut st = lock_unpoisoned(&state);
                match st.map.remove(&coord_id) {
                    Some(wire_id) => {
                        drop(st);
                        window.release();
                        let _ = out_tx.send(completion_to_wire(c, wire_id));
                    }
                    // Raced ahead of the reader's registration; the
                    // reader delivers it when it learns the id.
                    None => st.orphans.push(c),
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Serialize outbound frames; flush whenever the queue drains so bursts
/// share a syscall but a lone reply never waits.
fn run_writer(stream: TcpStream, out_rx: Receiver<Message>) {
    let mut w = std::io::BufWriter::new(stream);
    while let Ok(msg) = out_rx.recv() {
        if wire::write_frame(&mut w, &msg).is_err() {
            return;
        }
        while let Ok(next) = out_rx.try_recv() {
            if wire::write_frame(&mut w, &next).is_err() {
                return;
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
}
