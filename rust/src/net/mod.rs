//! Network serving tier: shard processes behind a zero-dependency wire
//! protocol, with a transparent local-or-remote client.
//!
//! The in-process [`crate::coordinator`] plane stays the default and is
//! untouched by this module. The network tier *fronts* that same plane
//! over `std::net` TCP — no external crates:
//!
//! ```text
//!  RemoteClient/RemoteSession              (mirror Client/Session)
//!        │
//!        ▼
//!  Router ── rendezvous placement on model id (shard::assign, the
//!        │   SAME function the in-process ShardSet uses)
//!        ├──▶ TCP ──▶ ShardServer 0 ──▶ Coordinator (own process)
//!        └──▶ TCP ──▶ ShardServer 1 ──▶ Coordinator (own process)
//!                      └ ARBW frames: length-prefixed, CRC32-checked,
//!                        version-negotiated (wire.rs)
//! ```
//!
//! Layers:
//!
//! * [`wire`] — the `ARBW` frame codec: 16-byte header (magic, kind,
//!   CRC32 of payload, length), alloc-bomb caps inherited from the
//!   `.arbf` registry format, typed request/response/error bodies plus
//!   handshake, metrics-pull, refresh and ping control frames.
//! * [`shard_server`] — [`shard_server::ShardServer`] fronts one
//!   [`crate::coordinator::Coordinator`] behind a `TcpListener`:
//!   per-connection reader/pump/writer threads, a bounded in-flight
//!   window per connection, and the socket read timeout doubling as the
//!   idle timeout. CLI: `approxrbf serve-shard --listen ADDR --store
//!   DIR`.
//! * [`router`] — [`router::Router`] multiplexes any number of
//!   [`router::RemoteClient`]s over per-shard connections, reconnects
//!   with backoff, converts dead shards into fail-fast
//!   [`crate::coordinator::PredictError`]s for that shard's tenants
//!   only, and aggregates remote metrics through the same
//!   [`crate::coordinator::Metrics::aggregate`] as the local plane.
//!   CLI: `approxrbf route --shards HOST:PORT,HOST:PORT…`.
//! * [`supervisor`] — [`supervisor::Supervisor`] keeps N `serve-shard`
//!   processes alive: wire-level Hello/Ping health checks, SIGKILL
//!   detection, capped-backoff restarts on pinned addresses so routers
//!   reconnect and resume bit-identically. CLI: `approxrbf serve-plane
//!   --shards N --store DIR`.
//! * [`faultnet`] — [`faultnet::FaultProxy`], a deterministic
//!   fault-injecting TCP relay for the chaos test tier: seeded
//!   per-connection schedules of delays, corruption, cuts, black-hole
//!   stalls and flap partitions, with a [`faultnet::FaultStats`]
//!   ledger of what was actually injected. Test infrastructure, but
//!   shipped in-tree so every invariant it pins stays reproducible
//!   from one u64 seed (see `docs/TESTING.md`).
//!
//! Guarantees carried over from the in-process plane: every accepted
//! request is answered with exactly one completion; placement parity
//! means a remote plane's decisions are bit-identical to a local one's;
//! and a republish hot-swaps tenants mid-stream without dropping
//! in-flight requests. See `docs/WIRE.md` for the byte-level protocol.

#![forbid(unsafe_code)]

pub mod faultnet;
pub mod router;
pub mod shard_server;
pub mod supervisor;
pub mod wire;

pub use faultnet::{FaultPlan, FaultProxy, FaultSpec, FaultStats};
pub use router::{
    LinkHealth, RemoteClient, RemoteSession, Router, RouterConfig,
};
pub use shard_server::{ShardServer, ShardServerConfig};
pub use supervisor::{Supervisor, SupervisorConfig};
