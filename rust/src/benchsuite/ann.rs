//! A3 — comparison with the ANN decision-function approximation of
//! Kang & Cho [15] (paper §4.3): build time (distillation vs our
//! closed-form approximation), prediction time and label fidelity.

use crate::approx::builder::build_approx_model;
use crate::data::synth::SynthProfile;
use crate::linalg::MathBackend;
use crate::svm::ann_approx::{AnnApprox, AnnParams};
use crate::svm::predict::ExactPredictor;
use crate::util::bench::{markdown_table, Bencher};
use crate::util::stats::label_diff_fraction;
use crate::util::Json;
use crate::Result;

use super::context::BenchContext;

pub fn run(ctx: &BenchContext) -> Result<String> {
    // Representative low-d profile (where both methods are applicable).
    let case = ctx.trained(SynthProfile::ControlLike, 0.78)?;
    let test = &case.test;
    let cfg = ctx.scale.bench_config();
    let mut bench = Bencher::new(cfg);

    let exact = ExactPredictor::new(&case.model, MathBackend::Blocked)?;
    let exact_dec = exact.decision_batch(&test.x)?;

    // Ours: closed-form build + quadratic predict.
    let t_build_ours = bench
        .run("ours/build", || {
            std::hint::black_box(
                build_approx_model(&case.model, MathBackend::Blocked).unwrap(),
            );
        })
        .mean();
    let am = build_approx_model(&case.model, MathBackend::Blocked)?;
    let t_pred_ours = bench
        .run("ours/pred", || {
            std::hint::black_box(
                am.decision_batch(&test.x, MathBackend::Blocked).unwrap(),
            );
        })
        .mean();
    let (ours_dec, _) = am.decision_batch(&test.x, MathBackend::Blocked)?;
    let diff_ours = label_diff_fraction(&exact_dec, &ours_dec);

    // ANN: distillation (expensive build) + O(n_HN · d) predict.
    let hidden_sizes: &[usize] = match ctx.scale {
        super::Scale::Full => &[8, 32],
        super::Scale::Quick => &[8],
    };
    let mut rows = vec![vec![
        "method".to_string(),
        "t_build (s)".to_string(),
        "t_pred (s)".to_string(),
        "label diff vs exact (%)".to_string(),
    ]];
    rows.push(vec![
        "quadratic approx (ours)".into(),
        format!("{t_build_ours:.4}"),
        format!("{t_pred_ours:.4}"),
        format!("{:.2}", diff_ours * 100.0),
    ]);
    let mut json_rows = vec![Json::obj(vec![
        ("method", Json::str("quadratic")),
        ("t_build", Json::num(t_build_ours)),
        ("t_pred", Json::num(t_pred_ours)),
        ("label_diff", Json::num(diff_ours)),
    ])];
    for &h in hidden_sizes {
        let params = AnnParams {
            hidden: h,
            epochs: match ctx.scale {
                super::Scale::Full => 40,
                super::Scale::Quick => 10,
            },
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let ann = AnnApprox::distill(&case.model, &case.train.x, params)?;
        let t_build_ann = t0.elapsed().as_secs_f64(); // once: SGD is slow
        let t_pred_ann = bench
            .run(&format!("ann{h}/pred"), || {
                std::hint::black_box(ann.decision_batch(&test.x));
            })
            .mean();
        let ann_dec = ann.decision_batch(&test.x);
        let diff_ann = label_diff_fraction(&exact_dec, &ann_dec);
        rows.push(vec![
            format!("ANN distill (h={h}) [15]"),
            format!("{t_build_ann:.2}"),
            format!("{t_pred_ann:.4}"),
            format!("{:.2}", diff_ann * 100.0),
        ]);
        json_rows.push(Json::obj(vec![
            ("method", Json::str(format!("ann_h{h}"))),
            ("t_build", Json::num(t_build_ann)),
            ("t_pred", Json::num(t_pred_ann)),
            ("label_diff", Json::num(diff_ann)),
        ]));
    }
    let path = super::write_results_json("ann_comp", &Json::Arr(json_rows))?;
    let mut out = String::from(
        "## Comparator — quadratic approximation vs ANN distillation \
         (Kang & Cho [15])\n\n",
    );
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!(
        "\nn_SV={} d={} n_test={}  (JSON: {path})\n",
        case.model.n_sv(),
        case.model.dim(),
        test.len()
    ));
    Ok(out)
}
