//! Figure 1: absolute relative error of the second-order Maclaurin
//! approximation of e^x over x ∈ [−2, 2], with the Eq. (A.2) assertion
//! (error < 3.05% inside |x| < ½). Rendered as an ASCII plot + JSON.

use crate::approx::maclaurin;
use crate::util::Json;
use crate::Result;

pub fn run() -> Result<String> {
    let curve = maclaurin::error_curve(-2.0, 2.0, 201);
    let in_bound = maclaurin::error_curve(
        -maclaurin::EXPONENT_BOUND,
        maclaurin::EXPONENT_BOUND,
        1001,
    );
    let max_in_bound = in_bound.iter().map(|&(_, y)| y).fold(0.0, f64::max);
    assert!(
        max_in_bound < maclaurin::MAX_REL_ERROR_IN_BOUND,
        "Eq. (A.2) violated: {max_in_bound}"
    );

    // ASCII rendering: 61 columns × 20 rows, log-ish y clamped at 1.0.
    const W: usize = 61;
    const H: usize = 20;
    let mut grid = vec![vec![b' '; W]; H];
    for i in 0..W {
        let x = -2.0 + 4.0 * i as f64 / (W - 1) as f64;
        let y = maclaurin::rel_error(x).min(1.0);
        let row = ((1.0 - y) * (H - 1) as f64).round() as usize;
        grid[row][i] = b'*';
    }
    let mut plot = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            "1.00 |"
        } else if r == H - 1 {
            "0.00 |"
        } else {
            "     |"
        };
        plot.push_str(label);
        plot.push_str(std::str::from_utf8(row).unwrap());
        plot.push('\n');
    }
    plot.push_str("      ");
    plot.push_str(&"-".repeat(W));
    plot.push('\n');
    plot.push_str("      x = -2                    0                    +2\n");

    let json = Json::obj(vec![
        (
            "curve",
            Json::Arr(
                curve
                    .iter()
                    .map(|&(x, y)| {
                        Json::Arr(vec![Json::num(x), Json::num(y)])
                    })
                    .collect(),
            ),
        ),
        ("max_rel_error_in_bound", Json::num(max_in_bound)),
        ("bound", Json::num(maclaurin::MAX_REL_ERROR_IN_BOUND)),
    ]);
    let path = super::write_results_json("fig1", &json)?;
    Ok(format!(
        "## Figure 1 — |e^x − (1+x+x²/2)| / e^x on [−2, 2]\n\n```\n{plot}```\n\
         max relative error on |x| < 1/2: {max_in_bound:.4} \
         (paper bound: {:.4})\n(JSON: {path})\n",
        maclaurin::MAX_REL_ERROR_IN_BOUND
    ))
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig1_runs_and_asserts_bound() {
        let out = super::run().unwrap();
        assert!(out.contains("max relative error"));
    }
}
