//! Table 1: per-dataset accuracy of the exact model and the fraction of
//! labels that differ under the approximation, across γ/γ_MAX ratios.
//!
//! Paper columns: data set, d, γ_MAX, γ, n_test, n_SV, acc (%), diff (%).

use crate::approx::builder::build_approx_model;
use crate::approx::error_analysis;
use crate::data::synth::ALL_PROFILES;
use crate::linalg::MathBackend;
use crate::util::bench::markdown_table;
use crate::util::Json;
use crate::Result;

use super::context::{gamma_multipliers, BenchContext};

pub fn run(ctx: &BenchContext) -> Result<String> {
    let mut rows = vec![vec![
        "data set".to_string(),
        "d".to_string(),
        "gamma_MAX".to_string(),
        "gamma".to_string(),
        "n_test".to_string(),
        "n_SV".to_string(),
        "acc (%)".to_string(),
        "diff (%)".to_string(),
        "in-bound (%)".to_string(),
    ]];
    let mut json_rows = Vec::new();
    for profile in ALL_PROFILES {
        for &mult in gamma_multipliers(profile) {
            let case = ctx.trained(profile, mult)?;
            let am = build_approx_model(&case.model, MathBackend::Blocked)?;
            let rep =
                error_analysis::compare(&case.model, &am, &case.test)?;
            rows.push(vec![
                format!("{} ({})", profile.name(), profile.mirrors()),
                format!("{}", case.test.dim()),
                format!("{:.4}", case.gamma_max),
                format!("{:.4}", case.gamma),
                format!("{}", case.test.len()),
                format!("{}", case.model.n_sv()),
                format!("{:.1}", rep.exact_acc * 100.0),
                format!("{:.2}", rep.label_diff * 100.0),
                format!("{:.1}", rep.in_bound_fraction * 100.0),
            ]);
            json_rows.push(Json::obj(vec![
                ("profile", Json::str(profile.name())),
                ("mirrors", Json::str(profile.mirrors())),
                ("d", Json::num(case.test.dim() as f64)),
                ("gamma_max", Json::num(f64::from(case.gamma_max))),
                ("gamma", Json::num(f64::from(case.gamma))),
                ("gamma_ratio", Json::num(mult)),
                ("n_test", Json::num(case.test.len() as f64)),
                ("n_sv", Json::num(case.model.n_sv() as f64)),
                ("exact_acc", Json::num(rep.exact_acc)),
                ("approx_acc", Json::num(rep.approx_acc)),
                ("label_diff", Json::num(rep.label_diff)),
                ("in_bound_fraction", Json::num(rep.in_bound_fraction)),
                ("mean_abs_err", Json::num(rep.abs_err.mean)),
            ]));
        }
    }
    let path = super::write_results_json("table1", &Json::Arr(json_rows))?;
    let mut out = String::from(
        "## Table 1 — exact accuracy vs approximation label diff\n\n",
    );
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!("\n(JSON: {path})\n"));
    Ok(out)
}
