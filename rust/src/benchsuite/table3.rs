//! Table 3: model sizes (text format), exact vs approximated, plus the
//! LS-SVM ablation the paper calls out in §5 ("compression ratios would
//! be even larger" because LS-SVM models are non-sparse).

use crate::approx::builder::build_approx_model;
use crate::data::synth::{SynthProfile, ALL_PROFILES};
use crate::linalg::MathBackend;
use crate::svm::lssvm::{train_lssvm, LssvmParams};
use crate::svm::Kernel;
use crate::util::bench::markdown_table;
use crate::util::Json;
use crate::Result;

use super::context::BenchContext;

fn human(bytes: usize) -> String {
    if bytes >= 1 << 30 {
        format!("{:.1} GB", bytes as f64 / (1u64 << 30) as f64)
    } else if bytes >= 1 << 20 {
        format!("{:.1} MB", bytes as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1} KB", bytes as f64 / 1024.0)
    }
}

pub fn run(ctx: &BenchContext) -> Result<String> {
    let mut rows = vec![vec![
        "data set".to_string(),
        "d".to_string(),
        "n_SV".to_string(),
        "exact".to_string(),
        "approx".to_string(),
        "ratio".to_string(),
    ]];
    let mut json_rows = Vec::new();
    for profile in ALL_PROFILES {
        let mult = super::context::gamma_multipliers(profile)[0];
        let case = ctx.trained(profile, mult)?;
        let am = build_approx_model(&case.model, MathBackend::Blocked)?;
        let exact_sz = case.model.text_size_bytes();
        let approx_sz = am.text_size_bytes();
        let ratio = exact_sz as f64 / approx_sz as f64;
        rows.push(vec![
            format!("{} ({})", profile.name(), profile.mirrors()),
            format!("{}", case.model.dim()),
            format!("{}", case.model.n_sv()),
            human(exact_sz),
            human(approx_sz),
            format!("{ratio:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("profile", Json::str(profile.name())),
            ("d", Json::num(case.model.dim() as f64)),
            ("n_sv", Json::num(case.model.n_sv() as f64)),
            ("exact_bytes", Json::num(exact_sz as f64)),
            ("approx_bytes", Json::num(approx_sz as f64)),
            ("ratio", Json::num(ratio)),
        ]));
    }

    // LS-SVM ablation (§5): every training point is an SV, so the
    // exact model balloons while the approx model stays d².
    let (train, _) = {
        let (tr, te) = ctx.data(SynthProfile::ControlLike);
        // LS-SVM is dense O(n²); cap the ablation size.
        (tr.split_at(tr.len().min(1500)).0, te)
    };
    let gamma = crate::approx::bounds::gamma_max_for_data(&train) * 0.8;
    let ls = train_lssvm(&train, Kernel::Rbf { gamma }, LssvmParams::default())?;
    let ls_am = build_approx_model(&ls, MathBackend::Blocked)?;
    let (e, a) = (ls.text_size_bytes(), ls_am.text_size_bytes());
    rows.push(vec![
        "control-like LS-SVM".to_string(),
        format!("{}", ls.dim()),
        format!("{} (=n)", ls.n_sv()),
        human(e),
        human(a),
        format!("{:.2}", e as f64 / a as f64),
    ]);
    json_rows.push(Json::obj(vec![
        ("profile", Json::str("control-like-lssvm")),
        ("d", Json::num(ls.dim() as f64)),
        ("n_sv", Json::num(ls.n_sv() as f64)),
        ("exact_bytes", Json::num(e as f64)),
        ("approx_bytes", Json::num(a as f64)),
        ("ratio", Json::num(e as f64 / a as f64)),
    ]));

    let path = super::write_results_json("table3", &Json::Arr(json_rows))?;
    let mut out =
        String::from("## Table 3 — model sizes (text format)\n\n");
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!("\n(JSON: {path})\n"));
    Ok(out)
}
