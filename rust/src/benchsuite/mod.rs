//! Benchmark suite: regenerates every table and figure of the paper's
//! evaluation (§4) plus the ablations called out in DESIGN.md §6.
//!
//! Each `run_*` function returns a rendered markdown table (printed by
//! the CLI) and writes machine-readable JSON under `results/`.
//! `Scale::Quick` shrinks workloads for CI/tests; `Scale::Full` is the
//! EXPERIMENTS.md configuration.

#![forbid(unsafe_code)]

pub mod ablations;
pub mod ann;
pub mod context;
pub mod fig1;
pub mod table1;
pub mod table2;
pub mod table3;

pub use context::{BenchContext, Scale};

/// Ensure `results/` exists and write a JSON document into it.
pub fn write_results_json(
    name: &str,
    json: &crate::util::Json,
) -> crate::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.json");
    std::fs::write(&path, json.to_string_pretty())?;
    Ok(path)
}
