//! Ablations called out in DESIGN.md §6:
//!
//! * A1 — math-backend sweep for the two kernels that dominate the
//!   paper's costs (weighted SYRK for t_approx; batched quadratic form
//!   for t_pred) across a (d, n_SV) grid.
//! * A2 — routing-policy ablation: serve a traffic mix with a
//!   controllable out-of-bound fraction through the coordinator under
//!   each policy; report accuracy-vs-latency.

use std::time::Duration;

use crate::approx::builder::build_approx_model;
use crate::coordinator::{Coordinator, RoutePolicy};
use crate::data::synth;
use crate::linalg::{quadform, syrk, Mat, MathBackend};
use crate::svm::smo::{train_csvc, SmoParams};
use crate::svm::Kernel;
use crate::util::bench::{markdown_table, Bencher};
use crate::util::stats::accuracy;
use crate::util::{Json, Rng};
use crate::Result;

use super::context::BenchContext;

/// A1: backend sweep over (n, d) for SYRK and the quadratic form.
pub fn run_backends(ctx: &BenchContext) -> Result<String> {
    let grid: &[(usize, usize)] = match ctx.scale {
        super::Scale::Full => &[
            (1024, 32),
            (1024, 128),
            (4096, 128),
            (4096, 512),
            (8192, 128),
            (2048, 1024),
        ],
        super::Scale::Quick => &[(256, 32), (512, 64)],
    };
    let mut rng = Rng::new(ctx.seed);
    let cfg = ctx.scale.bench_config();
    let mut bench = Bencher::new(cfg);
    let mut rows = vec![vec![
        "n_SV".to_string(),
        "d".to_string(),
        "syrk loops (s)".to_string(),
        "syrk blocked (s)".to_string(),
        "speedup".to_string(),
        "quadform scalar (s/batch)".to_string(),
        "quadform blocked (s/batch)".to_string(),
        "speedup".to_string(),
    ]];
    let mut json_rows = Vec::new();
    for &(n, d) in grid {
        let x = Mat::from_vec(
            n,
            d,
            (0..n * d).map(|_| rng.normal() as f32).collect(),
        )?;
        let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let t_loops = bench
            .run(&format!("syrk/loops/{n}x{d}"), || {
                std::hint::black_box(syrk::syrk_weighted_loops(&x, &w));
            })
            .mean();
        let t_blocked = bench
            .run(&format!("syrk/blocked/{n}x{d}"), || {
                std::hint::black_box(syrk::syrk_weighted_blocked(&x, &w));
            })
            .mean();
        // Quadratic form over a 512-row batch.
        let m = syrk::syrk_weighted_blocked(&x, &w);
        let batch = Mat::from_vec(
            512,
            d,
            (0..512 * d).map(|_| rng.normal() as f32).collect(),
        )?;
        let t_qf_scalar = bench
            .run(&format!("quadform/scalar/{d}"), || {
                for r in 0..batch.rows() {
                    std::hint::black_box(quadform::quadform_scalar(
                        &m,
                        batch.row(r),
                    ));
                }
            })
            .mean();
        let t_qf_blocked = bench
            .run(&format!("quadform/blocked/{d}"), || {
                std::hint::black_box(quadform::quadform_batch(&m, &batch));
            })
            .mean();
        rows.push(vec![
            format!("{n}"),
            format!("{d}"),
            format!("{t_loops:.4}"),
            format!("{t_blocked:.4}"),
            format!("{:.1}", t_loops / t_blocked),
            format!("{t_qf_scalar:.5}"),
            format!("{t_qf_blocked:.5}"),
            format!("{:.1}", t_qf_scalar / t_qf_blocked),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("d", Json::num(d as f64)),
            ("syrk_loops_s", Json::num(t_loops)),
            ("syrk_blocked_s", Json::num(t_blocked)),
            ("quadform_scalar_s", Json::num(t_qf_scalar)),
            ("quadform_blocked_s", Json::num(t_qf_blocked)),
        ]));
    }
    let path =
        super::write_results_json("ablation_backends", &Json::Arr(json_rows))?;
    let mut out = String::from(
        "## Ablation A1 — math backends (SYRK = t_approx kernel; \
         quadform = t_pred kernel)\n\n",
    );
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!("\n(JSON: {path})\n"));
    Ok(out)
}

/// A2: routing policies under a traffic mix with out-of-bound instances.
pub fn run_routing(ctx: &BenchContext) -> Result<String> {
    // Unit-norm train data, γ slightly under γ_max ⇒ in-bound by design;
    // a fraction of the test traffic is scaled ×3 (pushed out of bound).
    let n = match ctx.scale {
        super::Scale::Full => 1500,
        super::Scale::Quick => 300,
    };
    let raw = synth::two_gaussians(ctx.seed ^ 0x0520, 2 * n, 16, 2.0);
    let scaled = crate::data::UnitNormScaler.apply_dataset(&raw);
    let (train, test) = scaled.split_at(n);
    let gamma = 0.2f32; // < γ_max = 0.25 on unit-norm data
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())?;
    let am = build_approx_model(&model, MathBackend::Blocked)?;

    let exact_pred = crate::svm::predict::ExactPredictor::new(
        &model,
        MathBackend::Blocked,
    )?;
    let mut rows = vec![vec![
        "out-of-bound traffic".to_string(),
        "policy".to_string(),
        "acc (%)".to_string(),
        "diff vs exact (%)".to_string(),
        "% approx route".to_string(),
        "mean latency (µs)".to_string(),
        "throughput (req/s)".to_string(),
    ]];
    let mut json_rows = Vec::new();
    let mut rng = Rng::new(ctx.seed ^ 0x2077);
    for oob_frac in [0.0f64, 0.1, 0.5] {
        // Build the traffic: scale a random subset of rows ×3 so that
        // ‖z‖² = 9 > budget while labels stay valid (RBF decisions for
        // these instances differ, which is exactly the hazard).
        let mut traffic = test.clone();
        let n_oob = (oob_frac * traffic.len() as f64) as usize;
        let idx = rng.sample_indices(traffic.len(), n_oob);
        for &r in &idx {
            for v in traffic.x.row_mut(r) {
                *v *= 3.0;
            }
        }
        for policy in [
            RoutePolicy::AlwaysApprox,
            RoutePolicy::AlwaysExact,
            RoutePolicy::Hybrid,
        ] {
            let coord = Coordinator::builder()
                .policy(policy)
                .max_wait(Duration::from_millis(1))
                .start(model.clone(), am.clone())?;
            let client = coord.client();
            let t0 = std::time::Instant::now();
            let responses = client.predict_all(&traffic.x)?;
            let wall = t0.elapsed().as_secs_f64();
            let labels: Vec<f32> =
                responses.iter().map(|r| r.label).collect();
            let acc = accuracy(&labels, &traffic.y);
            let exact_dec = exact_pred.decision_batch(&traffic.x)?;
            let diff = crate::util::stats::label_diff_fraction(
                &labels, &exact_dec,
            );
            let n_approx = responses
                .iter()
                .filter(|r| r.route == crate::coordinator::Route::Approx)
                .count();
            let mean_lat = responses
                .iter()
                .map(|r| r.latency.as_secs_f64())
                .sum::<f64>()
                / responses.len() as f64;
            rows.push(vec![
                format!("{:.0}%", oob_frac * 100.0),
                policy.name().to_string(),
                format!("{:.1}", acc * 100.0),
                format!("{:.2}", diff * 100.0),
                format!(
                    "{:.0}",
                    100.0 * n_approx as f64 / responses.len() as f64
                ),
                format!("{:.0}", mean_lat * 1e6),
                format!("{:.0}", responses.len() as f64 / wall),
            ]);
            json_rows.push(Json::obj(vec![
                ("oob_fraction", Json::num(oob_frac)),
                ("policy", Json::str(policy.name())),
                ("accuracy", Json::num(acc)),
                ("label_diff_vs_exact", Json::num(diff)),
                (
                    "approx_route_fraction",
                    Json::num(n_approx as f64 / responses.len() as f64),
                ),
                ("mean_latency_s", Json::num(mean_lat)),
                (
                    "throughput_rps",
                    Json::num(responses.len() as f64 / wall),
                ),
            ]));
            coord.shutdown()?;
        }
    }
    let path =
        super::write_results_json("ablation_routing", &Json::Arr(json_rows))?;
    let mut out = String::from(
        "## Ablation A2 — bound-aware hybrid routing under out-of-bound \
         traffic\n\n",
    );
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!("\n(JSON: {path})\n"));
    Ok(out)
}

/// Both ablations, concatenated (the `bench ablations` CLI target).
pub fn run(ctx: &BenchContext) -> Result<String> {
    let mut out = run_backends(ctx)?;
    out.push('\n');
    out.push_str(&run_routing(ctx)?);
    Ok(out)
}

