//! Table 2: prediction speed, exact vs approximated, across math
//! backends and SIMD configurations, plus approximation-build time.
//!
//! Mapping of the paper's axes onto this environment (DESIGN.md §4):
//!   LOOPS            → MathBackend::Loops (naive loops)
//!   BLAS / ATLAS     → MathBackend::Blocked (tiled + threaded + autovec)
//!   vendor library   → XLA/PJRT artifacts (when available)
//!   SIMD off / on    → scalar vs 8-lane evaluators
//!
//! Columns: t_approx (build), t_pred, ratio1 = t_exact/t_pred and
//! ratio2 = t_exact/(t_pred + t_approx) — the paper's last two columns.

use std::path::Path;

use crate::approx::builder::build_approx_model;
use crate::data::synth::ALL_PROFILES;
use crate::linalg::MathBackend;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::svm::predict::ExactPredictor;
use crate::util::bench::{markdown_table, Bencher};
use crate::util::Json;
use crate::Result;

use super::context::BenchContext;

pub fn run(ctx: &BenchContext, artifacts_dir: Option<&Path>) -> Result<String> {
    let mut rows = vec![vec![
        "data set".to_string(),
        "approach".to_string(),
        "math".to_string(),
        "t_approx (s)".to_string(),
        "SIMD".to_string(),
        "t_pred (s)".to_string(),
        "ratio 1".to_string(),
        "ratio 2".to_string(),
    ]];
    let mut json_rows = Vec::new();
    let cfg = ctx.scale.bench_config();
    // Engine is constructed once (single-threaded benches).
    #[cfg(feature = "pjrt")]
    let engine = match artifacts_dir {
        Some(dir) if dir.join("manifest.txt").exists() => {
            Some(Engine::load(dir)?)
        }
        _ => None,
    };
    #[cfg(not(feature = "pjrt"))]
    let _ = artifacts_dir;

    for profile in ALL_PROFILES {
        // γ at the paper's primary setting for the profile.
        let mult = super::context::gamma_multipliers(profile)[0];
        let case = ctx.trained(profile, mult)?;
        let test = &case.test;
        let mut bench = Bencher::new(cfg.clone());

        // ---- exact baseline (per paper: LIBSVM-style loops) ----
        let exact_loops = ExactPredictor::new(&case.model, MathBackend::Loops)?;
        let t_exact = bench
            .run(&format!("{}/exact/loops", profile.name()), || {
                std::hint::black_box(
                    exact_loops.decision_batch(&test.x).unwrap(),
                );
            })
            .mean();
        rows.push(vec![
            format!("{} ({})", profile.name(), profile.mirrors()),
            "exact".into(),
            "loops".into(),
            "/".into(),
            "/".into(),
            format!("{t_exact:.4}"),
            "1".into(),
            "1".into(),
        ]);
        // Exact with the blocked backend (how fast exact *can* be here).
        let exact_blocked =
            ExactPredictor::new(&case.model, MathBackend::Blocked)?;
        let t_exact_blocked = bench
            .run(&format!("{}/exact/blocked", profile.name()), || {
                std::hint::black_box(
                    exact_blocked.decision_batch(&test.x).unwrap(),
                );
            })
            .mean();
        rows.push(vec![
            String::new(),
            "exact".into(),
            "blocked".into(),
            "/".into(),
            "✓".into(),
            format!("{t_exact_blocked:.4}"),
            format!("{:.1}", t_exact / t_exact_blocked),
            "/".into(),
        ]);

        // ---- approximation build times (t_approx) per backend ----
        let t_build_loops = bench
            .run(&format!("{}/build/loops", profile.name()), || {
                std::hint::black_box(
                    build_approx_model(&case.model, MathBackend::Loops)
                        .unwrap(),
                );
            })
            .mean();
        let t_build_blocked = bench
            .run(&format!("{}/build/blocked", profile.name()), || {
                std::hint::black_box(
                    build_approx_model(&case.model, MathBackend::Blocked)
                        .unwrap(),
                );
            })
            .mean();
        #[cfg(not(feature = "pjrt"))]
        let t_build_xla: Option<f64> = None;
        #[cfg(feature = "pjrt")]
        let t_build_xla = match &engine {
            Some(e) => {
                // One warm call compiles; then steady-state timing.
                let t = bench
                    .run(&format!("{}/build/xla", profile.name()), || {
                        std::hint::black_box(
                            e.build_approx(&case.model).unwrap(),
                        );
                    })
                    .mean();
                Some(t)
            }
            None => None,
        };

        // ---- approx prediction (SIMD off/on, then XLA) ----
        let am = build_approx_model(&case.model, MathBackend::Blocked)?;
        let t_pred_scalar = bench
            .run(&format!("{}/approx/scalar", profile.name()), || {
                std::hint::black_box(
                    am.decision_batch(&test.x, MathBackend::Loops).unwrap(),
                );
            })
            .mean();
        let t_pred_simd = bench
            .run(&format!("{}/approx/blocked", profile.name()), || {
                std::hint::black_box(
                    am.decision_batch(&test.x, MathBackend::Blocked).unwrap(),
                );
            })
            .mean();
        #[cfg(not(feature = "pjrt"))]
        let t_pred_xla: Option<f64> = None;
        #[cfg(feature = "pjrt")]
        let t_pred_xla = match &engine {
            Some(e) => {
                // Bulk bucket (§Perf L3-P3): offline prediction.
                let prep = e.prepare_approx_bulk(&am, test.len())?;
                let t = bench
                    .run(&format!("{}/approx/xla", profile.name()), || {
                        std::hint::black_box(
                            e.approx_predict(&prep, &test.x).unwrap(),
                        );
                    })
                    .mean();
                Some(t)
            }
            None => None,
        };

        // Paper-style rows: approx with (build backend, SIMD flag).
        let fmt_ratio = |r: f64| {
            if r >= 10.0 {
                format!("{r:.0}")
            } else {
                format!("{r:.2}")
            }
        };
        let mut push_approx =
            |math: &str, t_build: f64, simd: &str, t_pred: f64| {
                rows.push(vec![
                    String::new(),
                    "approx".into(),
                    math.into(),
                    format!("{t_build:.4}"),
                    simd.into(),
                    format!("{t_pred:.4}"),
                    fmt_ratio(t_exact / t_pred),
                    fmt_ratio(t_exact / (t_pred + t_build)),
                ]);
            };
        push_approx("loops", t_build_loops, "×", t_pred_scalar);
        push_approx("blocked", t_build_blocked, "✓", t_pred_simd);
        if let (Some(tb), Some(tp)) = (t_build_xla, t_pred_xla) {
            push_approx("xla", tb, "✓", tp);
        }

        json_rows.push(Json::obj(vec![
            ("profile", Json::str(profile.name())),
            ("n_test", Json::num(test.len() as f64)),
            ("n_sv", Json::num(case.model.n_sv() as f64)),
            ("d", Json::num(test.dim() as f64)),
            ("t_exact_loops", Json::num(t_exact)),
            ("t_exact_blocked", Json::num(t_exact_blocked)),
            ("t_build_loops", Json::num(t_build_loops)),
            ("t_build_blocked", Json::num(t_build_blocked)),
            (
                "t_build_xla",
                t_build_xla.map(Json::num).unwrap_or(Json::Null),
            ),
            ("t_pred_scalar", Json::num(t_pred_scalar)),
            ("t_pred_simd", Json::num(t_pred_simd)),
            (
                "t_pred_xla",
                t_pred_xla.map(Json::num).unwrap_or(Json::Null),
            ),
            ("ratio1_best", Json::num(t_exact / t_pred_simd.min(t_pred_xla.unwrap_or(f64::INFINITY)))),
        ]));
    }
    let path = super::write_results_json("table2", &Json::Arr(json_rows))?;
    let mut out = String::from(
        "## Table 2 — prediction speed: exact vs approximated\n\n",
    );
    out.push_str(&markdown_table(&rows));
    out.push_str(&format!("\n(JSON: {path})\n"));
    Ok(out)
}
