//! Shared benchmark context: deterministic dataset generation, model
//! training with an on-disk cache (results/models/) so the five
//! profiles are trained once per (profile, γ) and reused across tables.

use std::path::PathBuf;

use crate::approx::bounds::gamma_max_for_data;
use crate::log_info;
use crate::data::{Dataset, SynthProfile};
use crate::svm::smo::{train_csvc, SmoParams};
use crate::svm::{Kernel, SvmModel};
use crate::util::bench::BenchConfig;
use crate::Result;

/// Workload scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// EXPERIMENTS.md configuration (default profile sizes).
    Full,
    /// Shrunk ~10× for tests / smoke runs.
    Quick,
}

impl Scale {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "full" => Ok(Scale::Full),
            "quick" => Ok(Scale::Quick),
            other => Err(crate::Error::InvalidArg(format!(
                "unknown scale '{other}' (full|quick)"
            ))),
        }
    }

    pub fn sizes(&self, profile: SynthProfile) -> (usize, usize) {
        let (tr, te) = profile.default_sizes();
        match self {
            Scale::Full => (tr, te),
            Scale::Quick => ((tr / 10).max(200), (te / 10).max(200)),
        }
    }

    pub fn bench_config(&self) -> BenchConfig {
        match self {
            Scale::Full => BenchConfig { warmup: 1, samples: 8, max_seconds: 25.0 },
            Scale::Quick => BenchConfig::quick(),
        }
    }
}

/// Per-profile γ multipliers (γ = mult · γ_MAX) mirroring the ratios the
/// paper's Table 1 actually used (e.g. a9a at 0.55×, 1.1×, 5.5× γ_MAX).
pub fn gamma_multipliers(profile: SynthProfile) -> &'static [f64] {
    match profile {
        SynthProfile::AdultLike => &[0.55, 1.1, 5.5],
        SynthProfile::DigitsLike => &[0.1],
        SynthProfile::ControlLike => &[0.78],
        SynthProfile::VehicleLike => &[1.2],
        SynthProfile::WideLike => &[1.4],
    }
}

/// A trained benchmark case.
pub struct BenchCase {
    pub profile: SynthProfile,
    pub gamma: f32,
    pub gamma_max: f32,
    pub model: SvmModel,
    pub train: Dataset,
    pub test: Dataset,
}

/// Context with a model cache.
pub struct BenchContext {
    pub scale: Scale,
    pub seed: u64,
    cache_dir: PathBuf,
}

impl BenchContext {
    pub fn new(scale: Scale, seed: u64) -> Self {
        BenchContext {
            scale,
            seed,
            cache_dir: PathBuf::from("results/models"),
        }
    }

    /// Deterministic (train, test) for a profile at this scale.
    pub fn data(&self, profile: SynthProfile) -> (Dataset, Dataset) {
        let (ntr, nte) = self.scale.sizes(profile);
        profile.generate(self.seed, ntr, nte)
    }

    /// Train (or load from results/models/) the exact model for
    /// (profile, γ-multiplier).
    pub fn trained(
        &self,
        profile: SynthProfile,
        gamma_mult: f64,
    ) -> Result<BenchCase> {
        let (train, test) = self.data(profile);
        let gamma_max = gamma_max_for_data(&train);
        let gamma = (f64::from(gamma_max) * gamma_mult) as f32;
        let tag = format!(
            "{}_s{}_{}_g{:.5}",
            profile.name(),
            self.seed,
            match self.scale {
                Scale::Full => "full",
                Scale::Quick => "quick",
            },
            gamma
        );
        let path = self.cache_dir.join(format!("{tag}.model"));
        let model = if path.exists() {
            SvmModel::load(&path)?
        } else {
            let t0 = std::time::Instant::now();
            let (model, stats) = train_csvc(
                &train,
                Kernel::Rbf { gamma },
                SmoParams {
                    c: profile.default_cost(),
                    ..Default::default()
                },
            )?;
            log_info!(
                "trained {tag}: n_sv={} iters={} in {:.1}s",
                stats.n_sv,
                stats.iterations,
                t0.elapsed().as_secs_f64()
            );
            std::fs::create_dir_all(&self.cache_dir)?;
            model.save(&path)?;
            model
        };
        Ok(BenchCase { profile, gamma, gamma_max, model, train, test })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sizes_shrink() {
        let p = SynthProfile::ControlLike;
        let (f, _) = Scale::Full.sizes(p);
        let (q, _) = Scale::Quick.sizes(p);
        assert!(q < f);
        assert!(q >= 200);
    }

    #[test]
    fn multipliers_cover_all_profiles() {
        for p in crate::data::synth::ALL_PROFILES {
            assert!(!gamma_multipliers(p).is_empty());
        }
    }

    #[test]
    fn context_data_deterministic() {
        let ctx = BenchContext::new(Scale::Quick, 42);
        let (a, _) = ctx.data(SynthProfile::ControlLike);
        let (b, _) = ctx.data(SynthProfile::ControlLike);
        assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
    }
}
