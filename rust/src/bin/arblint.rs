//! `arblint` — run the repo-native static-analysis pass from
//! `approxrbf::analysis` over the live tree.
//!
//! Usage:
//!
//! ```text
//! cargo run --bin arblint              # lint the containing repo
//! cargo run --bin arblint -- --root P  # lint the repo rooted at P
//! ```
//!
//! Prints one `file:line: rule: message` diagnostic per finding. Exit
//! status: 0 clean, 1 findings, 2 usage/io errors. Rule catalog and
//! allowance grammar: `docs/ANALYSIS.md`.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let mut root: Option<PathBuf> = None;
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("arblint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!(
                    "arblint: unknown argument `{other}` (usage: \
                     arblint [--root <repo>])"
                );
                return ExitCode::from(2);
            }
        }
    }
    // Default to the repo containing this crate: CARGO_MANIFEST_DIR
    // is `<repo>/rust` at compile time.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
    });

    match approxrbf::analysis::run_all(&root) {
        Ok(diags) if diags.is_empty() => {
            println!(
                "arblint: clean ({} files scanned)",
                approxrbf::analysis::scanned_file_count(&root)
            );
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!(
                "arblint: {} violation(s) — see docs/ANALYSIS.md for \
                 the rule catalog and the allowance grammar",
                diags.len()
            );
            ExitCode::from(1)
        }
        Err(e) => {
            eprintln!("arblint: {e}");
            ExitCode::from(2)
        }
    }
}
