//! Quadratic-form evaluators `zᵀMz` — the per-instance hot path of the
//! approximated model (paper §3.3 "Prediction Speed"). Scalar vs
//! chunked evaluators are the SIMD off/on axis; the batched variant
//! reuses the blocked GEMM for throughput serving.

#![forbid(unsafe_code)]

use super::gemm;
use super::matrix::Mat;
use super::vecops;

/// `zᵀMz` with naive scalar loops (SIMD off).
pub fn quadform_scalar(m: &Mat, z: &[f32]) -> f32 {
    let d = z.len();
    assert_eq!((m.rows(), m.cols()), (d, d));
    let mut acc = 0.0f32;
    for a in 0..d {
        let mut inner = 0.0f32;
        let row = m.row(a);
        for b in 0..d {
            inner += row[b] * z[b];
        }
        acc += z[a] * inner;
    }
    acc
}

/// `zᵀMz` with 8-lane autovectorized row dots (SIMD on).
pub fn quadform(m: &Mat, z: &[f32]) -> f32 {
    let d = z.len();
    assert_eq!((m.rows(), m.cols()), (d, d));
    let mut acc = 0.0f32;
    for a in 0..d {
        acc += z[a] * vecops::dot(m.row(a), z);
    }
    acc
}

/// `zᵀMz` exploiting symmetry: only the upper triangle is touched,
/// halving memory traffic: `zᵀMz = Σ_a M_aa z_a² + 2 Σ_{a<b} M_ab z_a z_b`.
pub fn quadform_symmetric(m: &Mat, z: &[f32]) -> f32 {
    let d = z.len();
    assert_eq!((m.rows(), m.cols()), (d, d));
    let mut diag = 0.0f32;
    let mut off = 0.0f32;
    for a in 0..d {
        let row = m.row(a);
        diag += row[a] * z[a] * z[a];
        off += z[a] * vecops::dot(&row[a + 1..], &z[a + 1..]);
    }
    diag + 2.0 * off
}

/// Batched quadratic forms for a row-major batch `Z (B × d)`:
/// returns `q_i = z_iᵀ M z_i` for every row. Uses the blocked GEMM for
/// `Z·M` (M symmetric ⇒ `Z·Mᵀ = Z·M`) then a fused row-dot, which is
/// exactly the shape the Pallas kernel uses on TPU (DESIGN.md §7).
pub fn quadform_batch(m: &Mat, z: &Mat) -> Vec<f32> {
    assert_eq!(z.cols(), m.rows());
    let zm = gemm::gemm_nt_blocked(z, m); // (B × d)
    (0..z.rows())
        .map(|i| vecops::dot(zm.row(i), z.row(i)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::util::Rng;

    fn random_sym(rng: &mut Rng, d: usize) -> Mat {
        let mut m = Mat::zeros(d, d);
        for a in 0..d {
            for b in a..d {
                let v = rng.normal() as f32;
                *m.at_mut(a, b) = v;
                *m.at_mut(b, a) = v;
            }
        }
        m
    }

    #[test]
    fn evaluators_agree() {
        let mut rng = Rng::new(7);
        for d in [1usize, 2, 7, 16, 33, 100] {
            let m = random_sym(&mut rng, d);
            let z: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let a = quadform_scalar(&m, &z);
            let b = quadform(&m, &z);
            let c = quadform_symmetric(&m, &z);
            let tol = 1e-3 * (1.0 + a.abs());
            assert!((a - b).abs() < tol, "d={d}");
            assert!((a - c).abs() < tol, "d={d}");
        }
    }

    #[test]
    fn identity_matrix_gives_norm() {
        let d = 9;
        let mut m = Mat::zeros(d, d);
        for a in 0..d {
            *m.at_mut(a, a) = 1.0;
        }
        let z: Vec<f32> = (1..=d).map(|x| x as f32).collect();
        let expect = vecops::norm_sq(&z);
        assert!((quadform(&m, &z) - expect).abs() < 1e-3);
        assert!((quadform_symmetric(&m, &z) - expect).abs() < 1e-3);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(8);
        let d = 24;
        let m = random_sym(&mut rng, d);
        let z = Mat::from_vec(
            10,
            d,
            (0..10 * d).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let batch = quadform_batch(&m, &z);
        for i in 0..10 {
            let single = quadform(&m, z.row(i));
            assert!(
                (batch[i] - single).abs() < 1e-3 * (1.0 + single.abs()),
                "row {i}"
            );
        }
    }

    #[test]
    fn property_psd_quadform_nonnegative() {
        // M = XᵀX is PSD, so zᵀMz >= 0 for every z.
        prop_cases!("quadform-psd", 8, |rng| {
            let n = 2 + rng.below(10);
            let d = 1 + rng.below(16);
            let x = Mat::from_vec(
                n,
                d,
                (0..n * d).map(|_| rng.normal() as f32).collect(),
            )
            .unwrap();
            let m = super::super::syrk::syrk_weighted_loops(
                &x,
                &vec![1.0; n],
            );
            let z: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            assert!(quadform_symmetric(&m, &z) >= -1e-3);
        });
    }
}
