//! Fused evaluation kernel for the random-Fourier-feature substrate.
//!
//! The RFF decision function is
//! `f(z) = bias + Σ_j w_j · cos(W_j·z + φ_j)` — a `D×d` GEMV, a fused
//! cosine, and a `D`-length dot, evaluated here as one pass over the
//! regenerated feature map (`W`, `φ` live in
//! [`crate::approx::rff::RffModel`]; the `√(2/D)` feature scale and the
//! folded dual weights are both baked into `w` at publish time).
//!
//! Dispatch mirrors [`super::quantblas`]: a scalar oracle arm plus a
//! portable blocked arm behind a process-wide choice
//! (`APPROXRBF_RFF_KERNEL=scalar|blocked`), with every kernel also
//! taking the arm explicitly for side-by-side tests. There is no
//! explicit-SIMD arm — the cosine dominates and `libm` cos does not
//! vectorize — so "blocked" means 4 interleaved row accumulators
//! (ILP across rows of `W`).
//!
//! ## Bit-identity across arms and shard counts
//!
//! Per row `j`, both arms accumulate `W_j·z` in the same strictly
//! sequential `k` order, and both add the `w_j·cos(…)` terms in the
//! same strictly sequential `j` order — the blocked arm only interleaves
//! *independent* row accumulators. Every arm therefore returns
//! bit-identical decisions, which the serving plane's shard-invariance
//! tests rely on (the feature map itself is bit-identical everywhere
//! because it regenerates from the stored seed).

#![forbid(unsafe_code)]

use std::sync::OnceLock;

use crate::{log_info, log_warn};
use crate::{Error, Result};

/// One implementation of the RFF decision kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RffArm {
    /// One row of `W` at a time; dispatch baseline and property-test
    /// oracle.
    Scalar,
    /// Four interleaved row accumulators per pass (each row's sum stays
    /// in scalar order, so decisions are bit-identical to `Scalar`).
    Blocked,
}

impl RffArm {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            RffArm::Scalar => "scalar",
            RffArm::Blocked => "blocked",
        }
    }
}

impl std::fmt::Display for RffArm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for RffArm {
    type Err = Error;

    fn from_str(s: &str) -> Result<RffArm> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(RffArm::Scalar),
            "blocked" => Ok(RffArm::Blocked),
            other => Err(Error::InvalidArg(format!(
                "unknown rff kernel arm '{other}' (scalar|blocked)"
            ))),
        }
    }
}

/// The arms this machine can execute, in dispatch-preference order
/// (both are portable).
pub fn rff_available_arms() -> Vec<RffArm> {
    vec![RffArm::Scalar, RffArm::Blocked]
}

/// The process-wide RFF kernel arm, chosen once on first use: the
/// `APPROXRBF_RFF_KERNEL` environment override (`scalar|blocked`,
/// logged), else `blocked`. Decisions are bit-identical across arms,
/// so the choice is a pure throughput knob.
pub fn active_rff_arm() -> RffArm {
    static ARM: OnceLock<RffArm> = OnceLock::new();
    *ARM.get_or_init(|| match std::env::var("APPROXRBF_RFF_KERNEL") {
        Ok(s) => match s.parse::<RffArm>() {
            Ok(arm) => {
                log_info!(
                    "rffmap: APPROXRBF_RFF_KERNEL pins the '{arm}' \
                     kernel arm"
                );
                arm
            }
            Err(e) => {
                log_warn!("rffmap: {e}; using the default arm");
                RffArm::Blocked
            }
        },
        Err(_) => RffArm::Blocked,
    })
}

/// Fused RFF decision for one instance:
/// `bias + Σ_j w[j]·cos(wmat[j·d..]·z + phase[j])`.
///
/// `wmat` is the `D×d` row-major feature map, `phase.len() == w.len()
/// == D`, `z.len() == d`. Both arms return bit-identical values (see
/// module docs).
pub fn rff_decision(
    arm: RffArm,
    wmat: &[f32],
    phase: &[f32],
    w: &[f32],
    d: usize,
    bias: f32,
    z: &[f32],
) -> f32 {
    let n_features = w.len();
    debug_assert_eq!(phase.len(), n_features);
    debug_assert_eq!(wmat.len(), n_features * d);
    debug_assert_eq!(z.len(), d);
    match arm {
        RffArm::Scalar => {
            let mut total = bias;
            for j in 0..n_features {
                let row = &wmat[j * d..(j + 1) * d];
                let mut acc = 0f32;
                for k in 0..d {
                    acc += row[k] * z[k];
                }
                total += w[j] * (acc + phase[j]).cos();
            }
            total
        }
        RffArm::Blocked => {
            let mut total = bias;
            let mut j = 0usize;
            while j + 4 <= n_features {
                let r0 = &wmat[j * d..(j + 1) * d];
                let r1 = &wmat[(j + 1) * d..(j + 2) * d];
                let r2 = &wmat[(j + 2) * d..(j + 3) * d];
                let r3 = &wmat[(j + 3) * d..(j + 4) * d];
                let (mut a0, mut a1, mut a2, mut a3) =
                    (0f32, 0f32, 0f32, 0f32);
                for k in 0..d {
                    let zk = z[k];
                    a0 += r0[k] * zk;
                    a1 += r1[k] * zk;
                    a2 += r2[k] * zk;
                    a3 += r3[k] * zk;
                }
                // Same j-order as the scalar arm: bit-identical totals.
                total += w[j] * (a0 + phase[j]).cos();
                total += w[j + 1] * (a1 + phase[j + 1]).cos();
                total += w[j + 2] * (a2 + phase[j + 2]).cos();
                total += w[j + 3] * (a3 + phase[j + 3]).cos();
                j += 4;
            }
            while j < n_features {
                let row = &wmat[j * d..(j + 1) * d];
                let mut acc = 0f32;
                for k in 0..d {
                    acc += row[k] * z[k];
                }
                total += w[j] * (acc + phase[j]).cos();
                j += 1;
            }
            total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn arm_parse_roundtrip() {
        for arm in rff_available_arms() {
            assert_eq!(arm.to_string().parse::<RffArm>().unwrap(), arm);
        }
        assert!("simd".parse::<RffArm>().is_err());
    }

    #[test]
    fn property_arms_bit_identical() {
        let mut rng = Rng::new(0x8FF0);
        for case in 0..64 {
            // Odd D values exercise the blocked arm's tail loop.
            let d = 1 + rng.below(17);
            let n_features = 1 + rng.below(37);
            let wmat: Vec<f32> =
                (0..n_features * d).map(|_| rng.normal() as f32).collect();
            let phase: Vec<f32> = (0..n_features)
                .map(|_| rng.range(0.0, std::f64::consts::TAU) as f32)
                .collect();
            let w: Vec<f32> =
                (0..n_features).map(|_| rng.normal() as f32).collect();
            let z: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let bias = rng.normal() as f32;
            let reference = rff_decision(
                RffArm::Scalar,
                &wmat,
                &phase,
                &w,
                d,
                bias,
                &z,
            );
            assert!(reference.is_finite());
            for arm in rff_available_arms() {
                let got =
                    rff_decision(arm, &wmat, &phase, &w, d, bias, &z);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "case {case} ({arm}): {got} vs {reference}"
                );
            }
        }
    }

    #[test]
    fn matches_manual_small_case() {
        // D=2, d=1: f(z) = bias + w0·cos(w00·z + φ0) + w1·cos(w10·z + φ1).
        let wmat = [0.5f32, -1.5];
        let phase = [0.25f32, 1.0];
        let w = [2.0f32, -0.5];
        let z = [0.8f32];
        let manual = 0.1
            + 2.0 * (0.5f32 * 0.8 + 0.25).cos()
            + -0.5 * (-1.5f32 * 0.8 + 1.0).cos();
        for arm in rff_available_arms() {
            let got = rff_decision(arm, &wmat, &phase, &w, 1, 0.1, &z);
            assert!((got - manual).abs() < 1e-6, "{arm}: {got} vs {manual}");
        }
    }
}
