//! GEMM kernels: `C = A · Bᵀ` with both matrices row-major.
//!
//! The `A·Bᵀ` shape is what every hot path here needs — the cross-term
//! `Z·Xᵀ` of the exact RBF kernel and `Z·M` of the quadratic form (M is
//! symmetric, so `Z·Mᵀ = Z·M`) — and it is the cache-friendliest layout
//! for row-major data: every inner product walks two contiguous rows.
//!
//! Two implementations mirror the paper's math axis:
//! * [`gemm_nt_loops`] — naive triple loop (paper: LOOPS).
//! * [`gemm_nt_blocked`] — row/col tiling + 8-lane dots + threads
//!   (paper: BLAS/ATLAS role).

#![forbid(unsafe_code)]

use super::matrix::Mat;
use super::vecops;

/// Naive `C = A · Bᵀ`: textbook triple loop with scalar accumulation.
pub fn gemm_nt_loops(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "inner dims");
    let (m, n, k) = (a.rows(), b.rows(), a.cols());
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.at(i, p) * b.at(j, p);
            }
            *c.at_mut(i, j) = acc;
        }
    }
    c
}

/// Blocked `C = A · Bᵀ`: tile rows/cols for L2 residency, 8-lane
/// autovectorized inner dots, and parallelize across row panels with
/// scoped threads.
pub fn gemm_nt_blocked(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "inner dims");
    let (m, n) = (a.rows(), b.rows());
    let mut c = Mat::zeros(m, n);
    let threads = effective_threads(m);
    const JB: usize = 64; // column tile (rows of B)

    // Split C into contiguous row panels, one per thread.
    let rows_per = m.div_ceil(threads);
    let c_cols = n;
    let panels: Vec<(usize, &mut [f32])> = {
        let mut out = Vec::new();
        let mut rest = c.as_mut_slice();
        let mut row0 = 0;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (head, tail) = rest.split_at_mut(take * c_cols);
            out.push((row0, head));
            rest = tail;
            row0 += take;
        }
        out
    };

    std::thread::scope(|scope| {
        for (row0, panel) in panels {
            scope.spawn(move || {
                let rows = panel.len() / c_cols;
                for j0 in (0..n).step_by(JB) {
                    let j1 = (j0 + JB).min(n);
                    for i in 0..rows {
                        let arow = a.row(row0 + i);
                        let crow = &mut panel[i * c_cols..(i + 1) * c_cols];
                        // Plain 8-lane dots: measured FASTER than a
                        // 1x4 multi-row micro-kernel here (register
                        // spills) — EXPERIMENTS.md §Perf L3-P2.
                        for j in j0..j1 {
                            crow[j] = vecops::dot(arow, b.row(j));
                        }
                    }
                }
            });
        }
    });
    c
}

/// Matrix–vector product `y = A·x` (row-major, autovectorized dots).
pub fn gemv(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| vecops::dot(a.row(i), x)).collect()
}

/// Choose a thread count: respect `APPROXRBF_THREADS`, default to
/// available parallelism, never more than one thread per 32 rows.
pub fn effective_threads(rows: usize) -> usize {
    let max = std::env::var("APPROXRBF_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    max.clamp(1, (rows / 32).max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::util::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(
            r,
            c,
            (0..r * c).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn blocked_matches_loops() {
        let mut rng = Rng::new(3);
        for (m, n, k) in [(5, 7, 3), (64, 64, 64), (130, 70, 33), (1, 1, 1)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, n, k);
            let c1 = gemm_nt_loops(&a, &b);
            let c2 = gemm_nt_blocked(&a, &b);
            assert!(
                c1.max_abs_diff(&c2) < 1e-3,
                "({m},{n},{k}): {}",
                c1.max_abs_diff(&c2)
            );
        }
    }

    #[test]
    fn known_product() {
        // A = [[1,2],[3,4]], B = [[1,0],[0,1]] => A·Bᵀ = A.
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Mat::from_vec(2, 2, vec![1., 0., 0., 1.]).unwrap();
        assert_eq!(gemm_nt_loops(&a, &b), a);
        assert_eq!(gemm_nt_blocked(&a, &b), a);
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Rng::new(4);
        let a = random_mat(&mut rng, 13, 9);
        let x: Vec<f32> = (0..9).map(|_| rng.normal() as f32).collect();
        let bx = Mat::from_vec(1, 9, x.clone()).unwrap();
        let via_gemm = gemm_nt_loops(&a, &bx);
        let via_gemv = gemv(&a, &x);
        for i in 0..13 {
            assert!((via_gemm.at(i, 0) - via_gemv[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn thread_heuristic_sane() {
        assert_eq!(effective_threads(1), 1);
        assert!(effective_threads(10_000) >= 1);
    }

    #[test]
    fn property_gemm_linearity() {
        // (A1 + A2)·Bᵀ == A1·Bᵀ + A2·Bᵀ
        prop_cases!("gemm-linearity", 8, |rng| {
            let m = 3 + rng.below(20);
            let n = 3 + rng.below(20);
            let k = 1 + rng.below(30);
            let mk: Vec<f32> =
                (0..m * k).map(|_| rng.normal() as f32).collect();
            let mk2: Vec<f32> =
                (0..m * k).map(|_| rng.normal() as f32).collect();
            let nk: Vec<f32> =
                (0..n * k).map(|_| rng.normal() as f32).collect();
            let a1 = Mat::from_vec(m, k, mk.clone()).unwrap();
            let a2 = Mat::from_vec(m, k, mk2.clone()).unwrap();
            let sum = Mat::from_vec(
                m,
                k,
                mk.iter().zip(&mk2).map(|(x, y)| x + y).collect(),
            )
            .unwrap();
            let b = Mat::from_vec(n, k, nk).unwrap();
            let lhs = gemm_nt_blocked(&sum, &b);
            let c1 = gemm_nt_blocked(&a1, &b);
            let c2 = gemm_nt_blocked(&a2, &b);
            for i in 0..m {
                for j in 0..n {
                    let rhs = c1.at(i, j) + c2.at(i, j);
                    assert!(
                        (lhs.at(i, j) - rhs).abs()
                            < 1e-3 * (1.0 + rhs.abs())
                    );
                }
            }
        });
    }
}
