//! Weighted symmetric rank-k update: `M = Xᵀ · diag(w) · X` for a row-
//! major `X (n × d)` — the dominant cost of the paper's approximation
//! stage (Table 2, t_approx; `M = X D Xᵀ` in the paper's column-major
//! notation). Loops and Blocked backends mirror the LOOPS vs BLAS axis.

#![forbid(unsafe_code)]

use super::matrix::Mat;

/// Naive: for every SV, rank-1 update of the full d×d matrix.
pub fn syrk_weighted_loops(x: &Mat, w: &[f32]) -> Mat {
    assert_eq!(x.rows(), w.len());
    let d = x.cols();
    let mut m = Mat::zeros(d, d);
    for i in 0..x.rows() {
        let xi = x.row(i);
        let wi = w[i];
        for a in 0..d {
            let s = wi * xi[a];
            for b in 0..d {
                *m.at_mut(a, b) += s * xi[b];
            }
        }
    }
    m
}

/// Blocked: compute only the upper triangle in column tiles with 8-lane
/// accumulation over SV panels, parallelized across row blocks of M,
/// then mirror. Arithmetic is reassociated (panel-major) so results can
/// differ from the naive order by f32 rounding only.
pub fn syrk_weighted_blocked(x: &Mat, w: &[f32]) -> Mat {
    assert_eq!(x.rows(), w.len());
    let d = x.cols();
    let n = x.rows();
    let mut m = Mat::zeros(d, d);
    const AB: usize = 32; // row block of M

    // Pre-scale panels: y = diag(w)·X, so M = Xᵀ·Y (one pass, then GEMM-
    // like tiling). Trades n·d extra memory for a clean inner kernel.
    let mut y = x.clone();
    for i in 0..n {
        let wi = w[i];
        for v in y.row_mut(i) {
            *v *= wi;
        }
    }

    let threads = super::gemm::effective_threads(d);
    let blocks: Vec<usize> = (0..d).step_by(AB).collect();
    let results: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for chunk in blocks.chunks(blocks.len().div_ceil(threads)) {
            let chunk = chunk.to_vec();
            let xr = &x;
            let yr = &y;
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                for a0 in chunk {
                    let a1 = (a0 + AB).min(d);
                    // Rows a0..a1 of M, columns a0..d (upper triangle).
                    let mut block = vec![0.0f32; (a1 - a0) * d];
                    for i in 0..n {
                        let xi = xr.row(i);
                        let yi = yr.row(i);
                        for a in a0..a1 {
                            let s = yi[a];
                            if s == 0.0 {
                                continue;
                            }
                            let row =
                                &mut block[(a - a0) * d + a..(a - a0) * d + d];
                            let xcol = &xi[a..];
                            for (o, xv) in row.iter_mut().zip(xcol) {
                                *o += s * xv;
                            }
                        }
                    }
                    out.push((a0, block));
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    for (a0, block) in results {
        let a1 = (a0 + AB).min(d);
        for a in a0..a1 {
            for b in a..d {
                let v = block[(a - a0) * d + b];
                *m.at_mut(a, b) = v;
                *m.at_mut(b, a) = v;
            }
        }
    }
    m
}

/// `v = Xᵀ · w` companion (gradient vector of the approximation).
pub fn xt_w(x: &Mat, w: &[f32]) -> Vec<f32> {
    assert_eq!(x.rows(), w.len());
    let d = x.cols();
    let mut v = vec![0.0f32; d];
    for i in 0..x.rows() {
        super::vecops::axpy(w[i], x.row(i), &mut v);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::util::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize) -> (Mat, Vec<f32>) {
        let x = Mat::from_vec(
            n,
            d,
            (0..n * d).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let w = (0..n).map(|_| rng.normal() as f32).collect();
        (x, w)
    }

    #[test]
    fn blocked_matches_loops() {
        let mut rng = Rng::new(5);
        for (n, d) in [(1, 1), (10, 3), (100, 17), (257, 64), (64, 130)] {
            let (x, w) = random(&mut rng, n, d);
            let a = syrk_weighted_loops(&x, &w);
            let b = syrk_weighted_blocked(&x, &w);
            let scale = a.fro_norm().max(1.0) as f32;
            assert!(
                a.max_abs_diff(&b) < 1e-4 * scale,
                "({n},{d}): {}",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn result_is_symmetric() {
        let mut rng = Rng::new(6);
        let (x, w) = random(&mut rng, 50, 20);
        // Blocked mirrors explicitly (bit-exact); loops is symmetric
        // up to f32 rounding (s = w·x_a is rounded before ·x_b).
        let blocked = syrk_weighted_blocked(&x, &w);
        let loops = syrk_weighted_loops(&x, &w);
        for a in 0..20 {
            for b in 0..20 {
                assert_eq!(blocked.at(a, b), blocked.at(b, a));
                assert!((loops.at(a, b) - loops.at(b, a)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn rank_one_case() {
        // Single row x, weight w: M = w · x xᵀ.
        let x = Mat::from_vec(1, 3, vec![1., 2., 3.]).unwrap();
        let m = syrk_weighted_loops(&x, &[2.0]);
        assert_eq!(m.at(0, 0), 2.0);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.at(2, 1), 12.0);
    }

    #[test]
    fn xt_w_matches_manual() {
        let x = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let v = xt_w(&x, &[1.0, -1.0]);
        assert_eq!(v, vec![-2.0, -2.0]);
    }

    #[test]
    fn property_zero_weights_are_noops() {
        prop_cases!("syrk-zero-weights", 6, |rng| {
            let n = 2 + rng.below(40);
            let d = 1 + rng.below(24);
            let x = Mat::from_vec(
                n,
                d,
                (0..n * d).map(|_| rng.normal() as f32).collect(),
            )
            .unwrap();
            let mut w: Vec<f32> =
                (0..n).map(|_| rng.normal() as f32).collect();
            // Zero half the weights; those rows must not contribute.
            let idx = rng.sample_indices(n, n / 2);
            for &i in &idx {
                w[i] = 0.0;
            }
            let keep: Vec<usize> =
                (0..n).filter(|i| !idx.contains(i)).collect();
            let xs = x.gather_rows(&keep);
            let ws: Vec<f32> = keep.iter().map(|&i| w[i]).collect();
            let full = syrk_weighted_blocked(&x, &w);
            let sub = syrk_weighted_blocked(&xs, &ws);
            let scale = full.fro_norm().max(1.0) as f32;
            assert!(full.max_abs_diff(&sub) < 1e-4 * scale);
        });
    }
}
