//! Blocked/SIMD evaluation kernels for quantized model storage.
//!
//! The paper's promise is prediction cost quadratic in the input
//! dimension; PR 4's f16/int8 `.arbf` payloads shrank resident models
//! 4–8× but evaluated them with scalar per-element loops, so quantized
//! tenants *lost* throughput to f32 (`BENCH_quant.json`). This module
//! closes that gap with cache-friendly kernels over the three hot
//! shapes — SV-matrix × z (exact path), packed-upper symmetric
//! quadratic form (approx path), and `v·z` (approx path) — behind a
//! runtime [`KernelArm`] dispatch:
//!
//! * [`KernelArm::Scalar`] — the PR-4 per-element loops, kept as the
//!   dispatch baseline and the property-test oracle;
//! * [`KernelArm::Blocked`] — portable unrolled 8-lane blocks that
//!   LLVM autovectorizes (always available);
//! * [`KernelArm::Simd`] — explicit `std::arch` x86-64 paths (AVX2
//!   integer `madd` for int8, F16C convert + FMA for f16), selected
//!   only when `is_x86_feature_detected!` proves support.
//!
//! The arm is chosen once per process: `APPROXRBF_QUANT_KERNEL=
//! scalar|blocked|simd` pins it for A/B testing, otherwise the best
//! available arm wins. Every kernel also takes the arm explicitly so
//! tests and benches can compare arms side by side in one process.
//!
//! ## int8: exact integer accumulation, bit-identical across arms
//!
//! int8 weights are dotted against a query quantized once per row to
//! **i16** ([`QuantZ`], scale `max|z|/32767`): every product
//! `i8 × i16` and the whole accumulation happen in exact integer
//! arithmetic (i32 lanes flushed to i64 well before overflow), and the
//! two per-output scales are applied in one canonical float sequence.
//! Integer addition is associative, so *every arm returns bit-identical
//! decisions no matter how it blocks or vectorizes the sum* — asserted
//! by the property tests here and relied on by the serving plane's
//! shard/arm invariance tests. The query-side quantization error is
//! tiny (relative [`Z16_REL_EPS`] ≈ 1.5e-5 per element, ~2⁸ below the
//! int8 weight error) and is folded into the advertised decision
//! bounds ([`crate::approx::bounds::QuantErrorBound::eps_z_rel`]).
//!
//! ## f16: block-dequantize then FMA, bound-level agreement
//!
//! f16 weights are expanded to f32 in registers/blocks and multiplied
//! against the f32 query. Float summation order differs between arms,
//! so f16 arms agree only to reordering error (~2⁻²⁴ relative) — far
//! inside the advertised f16 dequantization bound, which is what the
//! tests pin.
//!
//! The scalar f16 codec (`f32 ↔ binary16` bit transforms) lives here
//! too: it is a pure value transform the storage layer
//! ([`crate::registry::quant`]) re-exports.

use std::sync::OnceLock;

use crate::{log_info, log_warn};
use crate::{Error, Result};

// ---------------------------------------------------------------------
// f16 scalar codec (moved from registry::quant; re-exported there)
// ---------------------------------------------------------------------

/// Largest finite f16 magnitude; values beyond it are rejected on
/// quantize (saturating would break the advertised error bound).
pub const F16_MAX: f32 = 65504.0;
/// Relative half-ulp bound for normal-range f16 values: 2⁻¹¹.
pub const F16_REL_EPS: f32 = 4.8828125e-4;
/// Absolute rounding floor in the f16 subnormal range: 2⁻²⁵.
pub const F16_SUBNORMAL_EPS: f32 = 2.9802322e-8;

/// f32 → f16 bits, IEEE round-to-nearest-even. The input must be
/// finite with `|x| ≤` [`F16_MAX`] — quantize callers enforce that;
/// out-of-range values here produce ±inf bits, which the decoder
/// rejects as corrupt.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf/NaN (callers reject beforehand; keep the bits meaningful).
        return sign | 0x7c00 | u16::from(mant != 0) << 9;
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if e >= -14 {
        // Normal f16: keep 10 mantissa bits, round to nearest even.
        let kept = mant >> 13;
        let rest = mant & 0x1fff;
        let mut h = ((((e + 15) as u32) << 10) | kept) as u16;
        if rest > 0x1000 || (rest == 0x1000 && (kept & 1) == 1) {
            h += 1; // may carry into the exponent — correct rounding
        }
        return sign | h;
    }
    if e >= -25 {
        // Subnormal f16: value = q × 2⁻²⁴.
        let full = mant | 0x0080_0000; // implicit leading 1, 24 bits
        let shift = (13 + (-14 - e)) as u32;
        let mut q = (full >> shift) as u16;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rest > half || (rest == half && (q & 1) == 1) {
            q += 1; // may round up to the smallest normal — correct
        }
        return sign | q;
    }
    sign // underflow to (signed) zero
}

/// f16 bits → f32 (exact: every f16 value is representable in f32).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign_bit = (u32::from(h) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let mant = u32::from(h) & 0x3ff;
    match exp {
        0 => {
            // ±0 and subnormals: value = mant × 2⁻²⁴ (exact in f32).
            let unit = f32::from_bits(0x3380_0000); // 2⁻²⁴
            let v = (mant as f32) * unit;
            if sign_bit != 0 {
                -v
            } else {
                v
            }
        }
        0x1f => {
            if mant == 0 {
                f32::from_bits(sign_bit | 0x7f80_0000) // ±inf
            } else {
                f32::NAN
            }
        }
        e => f32::from_bits(
            sign_bit | ((u32::from(e) + 112) << 23) | (mant << 13),
        ),
    }
}

/// Per-element error bound of an f16 round trip, computed from the
/// *dequantized* value `x̂`: the original satisfied
/// `|x − x̂| ≤ |x̂|·2⁻¹¹ + 2⁻²⁵` (half-ulp in the normal range, the
/// additive term covering the subnormal range).
#[inline]
pub fn f16_eps(dequantized: f32) -> f32 {
    dequantized.abs() * F16_REL_EPS + F16_SUBNORMAL_EPS
}

// ---------------------------------------------------------------------
// kernel arm selection
// ---------------------------------------------------------------------

/// One implementation of the quantized evaluation kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelArm {
    /// Per-element loops (the PR-4 evaluators): serial i64 accumulation
    /// on int8, serial convert-multiply-add on f16. Dispatch baseline
    /// and property-test oracle.
    Scalar,
    /// Portable unrolled blocks: 8 independent accumulator lanes (i32
    /// with i64 flushes on int8), autovectorized by LLVM. Always
    /// available.
    Blocked,
    /// Explicit x86-64 `std::arch` kernels (AVX2 `madd_epi16` int8
    /// path, F16C+FMA f16 path). Requires [`simd_available`].
    Simd,
}

impl KernelArm {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            KernelArm::Scalar => "scalar",
            KernelArm::Blocked => "blocked",
            KernelArm::Simd => "simd",
        }
    }
}

impl std::fmt::Display for KernelArm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelArm {
    type Err = Error;

    fn from_str(s: &str) -> Result<KernelArm> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelArm::Scalar),
            "blocked" => Ok(KernelArm::Blocked),
            "simd" => Ok(KernelArm::Simd),
            other => Err(Error::InvalidArg(format!(
                "unknown kernel arm '{other}' (scalar|blocked|simd)"
            ))),
        }
    }
}

/// True when the explicit SIMD arm can run on this machine (x86-64
/// with AVX2 + FMA + F16C — one gate for both payload kinds; every
/// AVX2-era core has all three).
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The arms this machine can execute, in dispatch-preference order.
pub fn available_arms() -> Vec<KernelArm> {
    let mut arms = vec![KernelArm::Scalar, KernelArm::Blocked];
    if simd_available() {
        arms.push(KernelArm::Simd);
    }
    arms
}

fn best_arm() -> KernelArm {
    if simd_available() {
        KernelArm::Simd
    } else {
        KernelArm::Blocked
    }
}

/// The process-wide kernel arm, chosen once on first use: the
/// `APPROXRBF_QUANT_KERNEL` environment override (`scalar|blocked|
/// simd`, logged; `simd` falls back to `blocked` when unavailable),
/// else the best available arm. int8 decisions are bit-identical
/// across arms, so the choice is a pure throughput knob.
pub fn active_arm() -> KernelArm {
    static ARM: OnceLock<KernelArm> = OnceLock::new();
    *ARM.get_or_init(|| match std::env::var("APPROXRBF_QUANT_KERNEL") {
        Ok(s) => match s.parse::<KernelArm>() {
            Ok(KernelArm::Simd) if !simd_available() => {
                log_warn!(
                    "quantblas: APPROXRBF_QUANT_KERNEL=simd but this CPU \
                     lacks AVX2/FMA/F16C; using blocked"
                );
                KernelArm::Blocked
            }
            Ok(arm) => {
                log_info!(
                    "quantblas: APPROXRBF_QUANT_KERNEL pins the '{arm}' \
                     kernel arm"
                );
                arm
            }
            Err(e) => {
                log_warn!("quantblas: {e}; using the default arm");
                best_arm()
            }
        },
        Err(_) => best_arm(),
    })
}

// ---------------------------------------------------------------------
// query-side i16 quantization
// ---------------------------------------------------------------------

/// Relative per-element bound of the i16 query quantization:
/// `|Δz_i| ≤ 0.5001·scale ≤ Z16_REL_EPS·max|z| ≤ Z16_REL_EPS·‖z‖₂`
/// (half a step plus dequant float rounding, as in the int8 row
/// codec). ≈ 1.53e-5 — about 2⁸ below the int8 *weight* bound, so the
/// query term it adds to the advertised decision bounds is marginal.
pub const Z16_REL_EPS: f32 = 0.5001 / 32767.0;

/// A query row quantized once to i16 for the integer int8 kernels:
/// `ẑ_i = scale · q_i`, `scale = max|z|/32767`.
///
/// All-zero rows get `scale = 0` (exact zeros); a subnormal `max/32767`
/// falls back to `scale = max` (resolution collapses but the
/// [`Z16_REL_EPS`]-implied absolute bound still holds, and such rows
/// are ~1e-34 — far below every decision bound's floor). Non-finite
/// queries mark the row poisoned ([`QuantZ::finite`] false) and every
/// kernel returns NaN, matching the f32 evaluators.
#[derive(Clone, Debug)]
pub struct QuantZ {
    /// Dequantization scale (0 for all-zero rows, NaN when poisoned).
    pub scale: f32,
    /// i16 codes, one per input element.
    pub q: Vec<i16>,
    /// `‖ẑ‖²` of the quantized row (exact integer sum of squares,
    /// scaled back) — the norm the exact-path RBF kernel uses so its
    /// distance is exactly `‖x̂ − ẑ‖²`. NaN when poisoned.
    pub norm_sq: f32,
    /// False iff the input contained a non-finite value.
    pub finite: bool,
}

impl QuantZ {
    pub fn from_f32(z: &[f32]) -> QuantZ {
        let mut max = 0.0f32;
        let mut all_finite = true;
        for &x in z {
            // Explicit finiteness check: f32::max ignores NaN, so a
            // NaN element would otherwise slip through the max scan.
            all_finite &= x.is_finite();
            max = max.max(x.abs());
        }
        if !all_finite || !max.is_finite() {
            return QuantZ {
                scale: f32::NAN,
                q: vec![0; z.len()],
                norm_sq: f32::NAN,
                finite: false,
            };
        }
        if max == 0.0 {
            return QuantZ {
                scale: 0.0,
                q: vec![0; z.len()],
                norm_sq: 0.0,
                finite: true,
            };
        }
        let mut scale = max / 32767.0;
        if scale < f32::MIN_POSITIVE {
            scale = max; // subnormal scale: q collapses to {-1, 0, 1}
        }
        let q: Vec<i16> = z
            .iter()
            .map(|&x| (x / scale).round().clamp(-32767.0, 32767.0) as i16)
            .collect();
        let sum_sq: i64 = q.iter().map(|&qi| i64::from(qi).pow(2)).sum();
        // Canonical scale application order (shared with the kernels):
        // widen the exact integer, then one scale at a time.
        let norm_sq = ((sum_sq as f32) * scale) * scale;
        QuantZ { scale, q, norm_sq, finite: true }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

// ---------------------------------------------------------------------
// int8 integer kernels (exact i64 sums — identical across arms)
// ---------------------------------------------------------------------

/// `Σ w_i · qz_i` in exact integer arithmetic. Every arm returns the
/// same i64, so the float results built from it are bit-identical.
fn dot_i8i16(arm: KernelArm, w: &[i8], qz: &[i16]) -> i64 {
    debug_assert_eq!(w.len(), qz.len());
    match arm {
        KernelArm::Scalar => dot_i8i16_scalar(w, qz),
        KernelArm::Blocked => dot_i8i16_blocked(w, qz),
        KernelArm::Simd => {
            #[cfg(target_arch = "x86_64")]
            if simd_available() {
                // SAFETY: gated on runtime AVX2 detection.
                return unsafe { x86::dot_i8i16_avx2(w, qz) };
            }
            dot_i8i16_blocked(w, qz)
        }
    }
}

/// The PR-4 oracle: one serial i64 accumulator.
fn dot_i8i16_scalar(w: &[i8], qz: &[i16]) -> i64 {
    let mut total = 0i64;
    for i in 0..w.len() {
        total += i64::from(w[i]) * i64::from(qz[i]);
    }
    total
}

/// Portable blocked arm: 8 independent i32 lanes (products are ≤
/// 127·32767 ≈ 4.2e6, so 256 per lane stay < 2³¹), flushed to an i64
/// total before they can overflow. LLVM autovectorizes the lane loop.
fn dot_i8i16_blocked(w: &[i8], qz: &[i16]) -> i64 {
    const LANES: usize = 8;
    const FLUSH_ITERS: usize = 256;
    let mut total = 0i64;
    let mut lanes = [0i32; LANES];
    let chunks = w.len() / LANES;
    let mut since_flush = 0usize;
    for c in 0..chunks {
        let wc = &w[c * LANES..c * LANES + LANES];
        let zc = &qz[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            lanes[l] += i32::from(wc[l]) * i32::from(zc[l]);
        }
        since_flush += 1;
        if since_flush == FLUSH_ITERS {
            for lane in &mut lanes {
                total += i64::from(*lane);
                *lane = 0;
            }
            since_flush = 0;
        }
    }
    for lane in lanes {
        total += i64::from(lane);
    }
    for i in chunks * LANES..w.len() {
        total += i64::from(w[i]) * i64::from(qz[i]);
    }
    total
}

/// Canonical scale application shared by every arm: widen the exact
/// integer once, then apply the row scale, then the query scale. One
/// fixed float sequence ⇒ int8 bit-identity reduces to i64 equality.
#[inline]
fn finish_i8_dot(total: i64, row_scale: f32, z_scale: f32) -> f32 {
    ((total as f32) * row_scale) * z_scale
}

/// Dequantized dot of one int8 row with a pre-quantized query:
/// `row_scale · ẑᵀq`. NaN when the query is poisoned.
pub fn dot_i8(arm: KernelArm, w: &[i8], row_scale: f32, z: &QuantZ) -> f32 {
    if !z.finite {
        return f32::NAN;
    }
    finish_i8_dot(dot_i8i16(arm, w, &z.q), row_scale, z.scale)
}

/// GEMV over contiguous int8 rows (`rows × cols`, per-row scales):
/// `out[r] = scales[r]·(row_r·ẑ)` — the SV-matrix × z shape. The exact
/// predictor fuses this row loop with its per-row kernel evaluation
/// (`registry::quant::QuantSvmModel::decision_with_norms`) to avoid a
/// scratch vector per query; this standalone form serves callers that
/// want the raw cross terms, and the dispatch-parity tests.
pub fn gemv_i8(
    arm: KernelArm,
    w: &[i8],
    scales: &[f32],
    cols: usize,
    z: &QuantZ,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(w.len(), scales.len() * cols);
    debug_assert_eq!(z.len(), cols);
    out.clear();
    for (r, &s) in scales.iter().enumerate() {
        out.push(dot_i8(arm, &w[r * cols..(r + 1) * cols], s, z));
    }
}

/// Quadratic form `ẑᵀM̂ẑ` over an int8 packed upper triangle (packed
/// row `r` holds `M[r][r..d]`, per-packed-row scales):
/// `Σ_r s_r·ẑ_r·(M_rr·ẑ_r + 2·Σ_{c>r} M_rc·ẑ_c)`. Each row's inner
/// sum is exact integer work dispatched per arm; the per-row float
/// combine is one fixed serial sequence, so int8 bit-identity holds
/// across arms here too.
pub fn quadform_i8(
    arm: KernelArm,
    scales: &[f32],
    packed: &[i8],
    d: usize,
    z: &QuantZ,
) -> f32 {
    debug_assert_eq!(z.len(), d);
    debug_assert_eq!(scales.len(), d);
    if !z.finite {
        return f32::NAN;
    }
    let mut acc = 0.0f32;
    let mut off = 0usize;
    for r in 0..d {
        let len = d - r;
        let row = &packed[off..off + len];
        let qz_r = i64::from(z.q[r]);
        let diag = i64::from(row[0]) * qz_r;
        let tail = dot_i8i16(arm, &row[1..], &z.q[r + 1..]);
        // |u| ≤ 32767·4.2e6·(2d+1): exact in i64 up to d ~ 10⁷.
        let u = qz_r * (diag + 2 * tail);
        acc += (u as f32) * scales[r];
        off += len;
    }
    (acc * z.scale) * z.scale
}

// ---------------------------------------------------------------------
// f16 kernels (block-dequantize then multiply-accumulate)
// ---------------------------------------------------------------------

/// Dequantized dot of an f16 row with an f32 query. Arms agree to
/// float-reordering error (~2⁻²⁴ relative), far inside the advertised
/// f16 bound.
pub fn dot_f16(arm: KernelArm, h: &[u16], z: &[f32]) -> f32 {
    debug_assert_eq!(h.len(), z.len());
    match arm {
        KernelArm::Scalar => dot_f16_scalar(h, z),
        KernelArm::Blocked => dot_f16_blocked(h, z),
        KernelArm::Simd => {
            #[cfg(target_arch = "x86_64")]
            if simd_available() {
                // SAFETY: gated on runtime AVX2+FMA+F16C detection.
                return unsafe { x86::dot_f16_avx2(h, z) };
            }
            dot_f16_blocked(h, z)
        }
    }
}

/// The PR-4 oracle: serial convert-multiply-add.
fn dot_f16_scalar(h: &[u16], z: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..h.len() {
        acc += f16_bits_to_f32(h[i]) * z[i];
    }
    acc
}

/// Portable blocked arm: dequantize 8-element blocks into a register
/// buffer, accumulate in 8 independent lanes.
fn dot_f16_blocked(h: &[u16], z: &[f32]) -> f32 {
    const LANES: usize = 8;
    let mut lanes = [0.0f32; LANES];
    let mut buf = [0.0f32; LANES];
    let chunks = h.len() / LANES;
    for c in 0..chunks {
        let hc = &h[c * LANES..c * LANES + LANES];
        let zc = &z[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            buf[l] = f16_bits_to_f32(hc[l]);
        }
        for l in 0..LANES {
            lanes[l] += buf[l] * zc[l];
        }
    }
    let mut total = lanes.iter().sum::<f32>();
    for i in chunks * LANES..h.len() {
        total += f16_bits_to_f32(h[i]) * z[i];
    }
    total
}

/// GEMV over contiguous f16 rows (see [`gemv_i8`] on why the exact
/// predictor fuses this loop instead of calling it).
pub fn gemv_f16(
    arm: KernelArm,
    h: &[u16],
    cols: usize,
    z: &[f32],
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(h.len() % cols.max(1), 0);
    out.clear();
    let rows = if cols == 0 { 0 } else { h.len() / cols };
    for r in 0..rows {
        out.push(dot_f16(arm, &h[r * cols..(r + 1) * cols], z));
    }
}

/// Quadratic form `zᵀM̂z` over an f16 packed upper triangle.
pub fn quadform_f16(arm: KernelArm, packed: &[u16], d: usize, z: &[f32]) -> f32 {
    debug_assert_eq!(z.len(), d);
    let mut acc = 0.0f32;
    let mut off = 0usize;
    for r in 0..d {
        let len = d - r;
        let row = &packed[off..off + len];
        let diag = f16_bits_to_f32(row[0]) * z[r];
        let tail = dot_f16(arm, &row[1..], &z[r + 1..]);
        acc += z[r] * (diag + 2.0 * tail);
        off += len;
    }
    acc
}

// ---------------------------------------------------------------------
// explicit x86-64 SIMD arm
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Exact integer dot: 16 i8 weights widen to i16, `madd_epi16`
    /// pairs them against 16 i16 query codes into 8 i32 lanes (each
    /// pair ≤ 2·127·32767 ≈ 8.3e6), lanes flush to i64 every 128
    /// chunks (≤ 1.07e9 < 2³¹). Same i64 as the scalar oracle.
    ///
    /// # Safety
    /// Requires AVX2 (callers gate on [`super::simd_available`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8i16_avx2(w: &[i8], qz: &[i16]) -> i64 {
        const CHUNK: usize = 16;
        const FLUSH_CHUNKS: usize = 128;
        let chunks = w.len() / CHUNK;
        // SAFETY: the AVX2 intrinsics are safe to issue because the
        // caller proved AVX2 at runtime (fn-level contract above); the
        // unaligned loads stay in bounds because every pointer is
        // `base + c*CHUNK` with `c < chunks = len/CHUNK`, so the 16
        // lanes read end at `chunks*CHUNK <= w.len() == qz.len()`.
        unsafe {
            let mut acc32 = _mm256_setzero_si256();
            let mut acc64 = _mm256_setzero_si256();
            let mut pending = 0usize;
            for c in 0..chunks {
                let wp = w.as_ptr().add(c * CHUNK) as *const __m128i;
                let zp = qz.as_ptr().add(c * CHUNK) as *const __m256i;
                let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp));
                let zv = _mm256_loadu_si256(zp);
                acc32 = _mm256_add_epi32(acc32, _mm256_madd_epi16(wv, zv));
                pending += 1;
                if pending == FLUSH_CHUNKS {
                    acc64 = _mm256_add_epi64(acc64, widen_i32x8(acc32));
                    acc32 = _mm256_setzero_si256();
                    pending = 0;
                }
            }
            acc64 = _mm256_add_epi64(acc64, widen_i32x8(acc32));
            let mut lanes = [0i64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc64);
            let mut total: i64 = lanes.iter().sum();
            for i in chunks * CHUNK..w.len() {
                total += i64::from(w[i]) * i64::from(qz[i]);
            }
            total
        }
    }

    /// Sum 8 i32 lanes into 4 i64 lanes (exact sign extension).
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_i32x8(v: __m256i) -> __m256i {
        // SAFETY: register-only AVX2 intrinsics (no memory access);
        // the caller's AVX2 proof covers the instruction set.
        unsafe {
            let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(v));
            let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256(v, 1));
            _mm256_add_epi64(lo, hi)
        }
    }

    /// f16 dot: F16C converts 8 halves per cycle, FMA accumulates in 8
    /// f32 lanes.
    ///
    /// # Safety
    /// Requires AVX2 + FMA + F16C (callers gate on
    /// [`super::simd_available`]).
    #[target_feature(enable = "avx2,fma,f16c")]
    pub unsafe fn dot_f16_avx2(h: &[u16], z: &[f32]) -> f32 {
        const CHUNK: usize = 8;
        let chunks = h.len() / CHUNK;
        // SAFETY: the AVX2/FMA/F16C intrinsics are safe to issue
        // because the caller proved the features at runtime (fn-level
        // contract above); the unaligned loads stay in bounds because
        // every pointer is `base + c*CHUNK` with `c < chunks =
        // len/CHUNK`, so the 8 lanes read end at `chunks*CHUNK <=
        // h.len() == z.len()`.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            for c in 0..chunks {
                let hp = h.as_ptr().add(c * CHUNK) as *const __m128i;
                let hv = _mm256_cvtph_ps(_mm_loadu_si128(hp));
                let zv = _mm256_loadu_ps(z.as_ptr().add(c * CHUNK));
                acc = _mm256_fmadd_ps(hv, zv, acc);
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut total: f32 = lanes.iter().sum();
            for i in chunks * CHUNK..h.len() {
                total += super::f16_bits_to_f32(h[i]) * z[i];
            }
            total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::util::Rng;

    /// Lengths straddling every block boundary (8/16-wide chunks, the
    /// SIMD flush cadence) — tail handling is where blocked kernels rot.
    const RAGGED: [usize; 16] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 63, 64, 100, 129, 1000];

    fn random_row(rng: &mut Rng, n: usize, mag: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() as f32) * mag).collect()
    }

    fn quant_i8_row(rng: &mut Rng, n: usize) -> (f32, Vec<i8>) {
        let row = random_row(rng, n, 0.5);
        crate::registry::quant::int8_quantize_row(&row).unwrap()
    }

    #[test]
    fn arm_parse_display_roundtrip() {
        for arm in [KernelArm::Scalar, KernelArm::Blocked, KernelArm::Simd] {
            assert_eq!(arm.to_string().parse::<KernelArm>().unwrap(), arm);
        }
        assert!("avx512".parse::<KernelArm>().is_err());
        // Availability is monotone: scalar and blocked always present,
        // and the process-wide arm is always an available one (a simd
        // override falls back to blocked when undetected).
        let arms = available_arms();
        assert!(arms.contains(&KernelArm::Scalar));
        assert!(arms.contains(&KernelArm::Blocked));
        assert!(arms.contains(&active_arm()));
    }

    #[test]
    fn quantz_roundtrip_within_relative_bound() {
        prop_cases!("quantz bound", 48, |rng| {
            let n = 1 + rng.below(64);
            let mag = 10f64.powf(rng.range(-6.0, 4.0)) as f32;
            let z = random_row(rng, n, mag);
            let qz = QuantZ::from_f32(&z);
            assert!(qz.finite);
            let max = z.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let mut norm = 0.0f32;
            for (i, &x) in z.iter().enumerate() {
                let x_hat = qz.scale * f32::from(qz.q[i]);
                assert!(
                    (x - x_hat).abs() <= Z16_REL_EPS * max.max(1e-30),
                    "z[{i}]={x}: dequant {x_hat} (scale {})",
                    qz.scale
                );
                norm += x_hat * x_hat;
            }
            // The carried norm is the quantized row's norm.
            assert!((qz.norm_sq - norm).abs() <= 1e-3 * (1.0 + norm));
        });
    }

    #[test]
    fn quantz_edge_cases() {
        let zero = QuantZ::from_f32(&[0.0; 5]);
        assert_eq!(zero.scale, 0.0);
        assert_eq!(zero.norm_sq, 0.0);
        assert!(zero.q.iter().all(|&q| q == 0));
        let poisoned = QuantZ::from_f32(&[1.0, f32::NAN]);
        assert!(!poisoned.finite);
        assert!(poisoned.norm_sq.is_nan());
        let inf = QuantZ::from_f32(&[f32::INFINITY]);
        assert!(!inf.finite);
        // Subnormal scale fallback stays finite and bounded.
        let tiny = f32::from_bits(3);
        let qz = QuantZ::from_f32(&[tiny, -tiny]);
        assert!(qz.finite && qz.scale > 0.0);
        let empty = QuantZ::from_f32(&[]);
        assert!(empty.is_empty() && empty.finite);
    }

    #[test]
    fn property_int8_arms_bit_identical_on_ragged_sizes() {
        prop_cases!("int8 arms agree", 24, |rng| {
            for &n in &RAGGED {
                let (scale, w) = quant_i8_row(rng, n.max(1));
                let z = random_row(rng, w.len(), 1.0);
                let qz = QuantZ::from_f32(&z);
                let oracle = dot_i8(KernelArm::Scalar, &w, scale, &qz);
                for arm in available_arms() {
                    let got = dot_i8(arm, &w, scale, &qz);
                    assert_eq!(
                        got.to_bits(),
                        oracle.to_bits(),
                        "{arm} n={n}: {got} vs oracle {oracle}"
                    );
                }
            }
        });
    }

    #[test]
    fn property_int8_quadform_arms_bit_identical() {
        prop_cases!("int8 quadform arms agree", 16, |rng| {
            for &d in &[1usize, 2, 3, 5, 8, 13, 16, 17, 31, 40] {
                let mut scales = Vec::with_capacity(d);
                let mut packed = Vec::new();
                for r in 0..d {
                    let (s, row) = quant_i8_row(rng, d - r);
                    scales.push(s);
                    packed.extend_from_slice(&row);
                }
                let z = random_row(rng, d, 1.0);
                let qz = QuantZ::from_f32(&z);
                let oracle = quadform_i8(KernelArm::Scalar, &scales, &packed, d, &qz);
                for arm in available_arms() {
                    let got = quadform_i8(arm, &scales, &packed, d, &qz);
                    assert_eq!(got.to_bits(), oracle.to_bits(), "{arm} d={d}");
                }
            }
        });
    }

    #[test]
    fn int8_flush_cadence_is_exact_at_adversarial_length() {
        // Worst-case magnitudes at lengths past several flush windows:
        // every product is +127·32767, so any premature i32 overflow
        // would corrupt the total. 100_000 elements cover many 2048-
        // element SIMD windows and 2048-element blocked windows.
        let n = 100_000;
        let w = vec![127i8; n];
        let qz = QuantZ {
            scale: 1.0,
            q: vec![32767i16; n],
            norm_sq: 0.0,
            finite: true,
        };
        let want = 127i64 * 32767 * n as i64;
        for arm in available_arms() {
            let got = dot_i8(arm, &w, 1.0, &qz);
            assert_eq!(got, want as f32, "{arm}");
        }
        // And with alternating signs (partial cancellation).
        let mut q2 = vec![32767i16; n];
        for (i, q) in q2.iter_mut().enumerate() {
            if i % 2 == 1 {
                *q = -32767;
            }
        }
        let qz2 = QuantZ { q: q2, ..qz };
        let oracle = dot_i8(KernelArm::Scalar, &w, 1.0, &qz2);
        for arm in available_arms() {
            assert_eq!(dot_i8(arm, &w, 1.0, &qz2).to_bits(), oracle.to_bits(), "{arm}");
        }
    }

    #[test]
    fn int8_matches_f32_reference_within_query_bound() {
        // The integer path equals the dequantized-weights × dequantized-
        // query f32 dot up to float rounding of the final scales.
        prop_cases!("int8 vs f32 reference", 24, |rng| {
            let n = 1 + rng.below(300);
            let (scale, w) = quant_i8_row(rng, n);
            let z = random_row(rng, n, 2.0);
            let qz = QuantZ::from_f32(&z);
            let got = dot_i8(KernelArm::Blocked, &w, scale, &qz);
            let want: f64 = w
                .iter()
                .zip(&qz.q)
                .map(|(&wi, &qi)| {
                    f64::from(scale) * f64::from(wi) * f64::from(qz.scale) * f64::from(qi)
                })
                .sum();
            assert!(
                (f64::from(got) - want).abs() <= 1e-5 * (1.0 + want.abs()),
                "{got} vs {want}"
            );
        });
    }

    #[test]
    fn property_f16_arms_agree_within_reordering_error() {
        prop_cases!("f16 arms agree", 24, |rng| {
            for &n in &RAGGED {
                let row = random_row(rng, n, 0.5);
                let h: Vec<u16> =
                    row.iter().map(|&x| f32_to_f16_bits(x)).collect();
                let z = random_row(rng, n, 1.0);
                let oracle = dot_f16(KernelArm::Scalar, &h, &z);
                for arm in available_arms() {
                    let got = dot_f16(arm, &h, &z);
                    assert!(
                        (got - oracle).abs() <= 1e-4 * (1.0 + oracle.abs()),
                        "{arm} n={n}: {got} vs {oracle}"
                    );
                }
            }
        });
    }

    #[test]
    fn property_f16_quadform_arms_agree() {
        prop_cases!("f16 quadform arms agree", 16, |rng| {
            for &d in &[1usize, 2, 5, 9, 16, 17, 33] {
                let mut packed = Vec::new();
                for r in 0..d {
                    for x in random_row(rng, d - r, 0.5) {
                        packed.push(f32_to_f16_bits(x));
                    }
                }
                let z = random_row(rng, d, 1.0);
                let oracle = quadform_f16(KernelArm::Scalar, &packed, d, &z);
                for arm in available_arms() {
                    let got = quadform_f16(arm, &packed, d, &z);
                    assert!(
                        (got - oracle).abs() <= 1e-4 * (1.0 + oracle.abs()),
                        "{arm} d={d}"
                    );
                }
            }
        });
    }

    #[test]
    fn gemv_matches_per_row_dots() {
        let mut rng = Rng::new(7);
        let (cols, rows) = (37, 9);
        let mut w = Vec::new();
        let mut scales = Vec::new();
        for _ in 0..rows {
            let (s, r) = quant_i8_row(&mut rng, cols);
            scales.push(s);
            w.extend_from_slice(&r);
        }
        let z = random_row(&mut rng, cols, 1.0);
        let qz = QuantZ::from_f32(&z);
        let mut h = Vec::new();
        for &x in random_row(&mut rng, rows * cols, 0.5).iter() {
            h.push(f32_to_f16_bits(x));
        }
        for arm in available_arms() {
            let mut out = Vec::new();
            gemv_i8(arm, &w, &scales, cols, &qz, &mut out);
            assert_eq!(out.len(), rows);
            for (r, &got) in out.iter().enumerate() {
                let want = dot_i8(arm, &w[r * cols..(r + 1) * cols], scales[r], &qz);
                assert_eq!(got.to_bits(), want.to_bits(), "{arm} row {r}");
            }
            let mut fout = Vec::new();
            gemv_f16(arm, &h, cols, &z, &mut fout);
            assert_eq!(fout.len(), rows);
        }
    }

    #[test]
    fn poisoned_query_yields_nan_everywhere() {
        let qz = QuantZ::from_f32(&[1.0, f32::NAN, 2.0]);
        for arm in available_arms() {
            assert!(dot_i8(arm, &[1, 2, 3], 0.5, &qz).is_nan(), "{arm}");
            assert!(
                quadform_i8(arm, &[0.5, 0.5, 0.5], &[1, 2, 3, 4, 5, 6], 3, &qz).is_nan(),
                "{arm}"
            );
        }
    }
}
