//! Dense linear-algebra substrate.
//!
//! The paper benchmarks three math configurations (LOOPS / BLAS / ATLAS)
//! plus a SIMD on/off axis. This module provides the equivalents:
//!
//! * [`MathBackend::Loops`] — textbook triple loops, deliberately naive
//!   (the paper's LOOPS baseline).
//! * [`MathBackend::Blocked`] — cache-blocked, multi-threaded, 8-lane
//!   accumulator kernels that LLVM autovectorizes (the BLAS/ATLAS role).
//! * The XLA/PJRT path lives in [`crate::runtime`] and plays the role of
//!   a vendor library (fused, compiler-optimized).
//!
//! The SIMD axis maps to the scalar vs chunked dot/quadratic-form
//! evaluators in [`vecops`] / [`quadform`]. Quantized (f16/int8)
//! storage is evaluated by the blocked/SIMD kernels in [`quantblas`],
//! behind their own [`KernelArm`] dispatch
//! (`APPROXRBF_QUANT_KERNEL=scalar|blocked|simd`).

pub mod gemm;
pub mod matrix;
pub mod quadform;
pub mod quantblas;
pub mod rffmap;
pub mod syrk;
pub mod vecops;

pub use matrix::Mat;
pub use quantblas::KernelArm;
pub use rffmap::RffArm;

/// Math backend selector mirrored on the paper's LOOPS/BLAS/ATLAS axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MathBackend {
    /// Naive loops (paper: LOOPS).
    Loops,
    /// Cache-blocked + threaded + autovectorized (paper: BLAS/ATLAS).
    Blocked,
    /// AOT-compiled XLA executable via PJRT (vendor-library role).
    Xla,
}

impl MathBackend {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            MathBackend::Loops => "loops",
            MathBackend::Blocked => "blocked",
            MathBackend::Xla => "xla",
        }
    }
}

impl std::str::FromStr for MathBackend {
    type Err = crate::Error;

    fn from_str(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "loops" => Ok(MathBackend::Loops),
            "blocked" | "blas" => Ok(MathBackend::Blocked),
            "xla" => Ok(MathBackend::Xla),
            other => Err(crate::Error::InvalidArg(format!(
                "unknown backend '{other}' (loops|blocked|xla)"
            ))),
        }
    }
}

impl std::fmt::Display for MathBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_roundtrip() {
        for b in [MathBackend::Loops, MathBackend::Blocked, MathBackend::Xla] {
            assert_eq!(b.to_string().parse::<MathBackend>().unwrap(), b);
        }
        assert_eq!("BLAS".parse::<MathBackend>().unwrap(), MathBackend::Blocked);
        assert!("atlas9".parse::<MathBackend>().is_err());
    }
}
