//! Vector kernels. The paper's SIMD on/off axis maps to
//! [`dot_scalar`] (plain sequential accumulation, defeats vectorization
//! via a single serial dependency chain) vs [`dot`] (8 independent
//! accumulator lanes that LLVM turns into AVX code — the `-march`
//! compiled equivalent of the paper's hand-enabled vector instructions).

#![forbid(unsafe_code)]

/// Scalar dot product: one accumulator, serial dependency chain.
/// This is the "SIMD off" evaluator.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Vector-friendly dot product: 8 independent lanes, autovectorized.
/// This is the "SIMD on" evaluator. (Perf note: 1×4 multi-row
/// micro-kernels and 2×8 accumulator groups were both tried and
/// measured SLOWER than this form under LLVM's autovectorizer —
/// EXPERIMENTS.md §Perf L3-P2 records the A/B.)
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let ai = &a[i * 8..i * 8 + 8];
        let bi = &b[i * 8..i * 8 + 8];
        for l in 0..8 {
            lanes[l] += ai[l] * bi[l];
        }
    }
    let mut acc = lanes.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let ai = &a[i * 8..i * 8 + 8];
        let bi = &b[i * 8..i * 8 + 8];
        for l in 0..8 {
            let d = ai[l] - bi[l];
            lanes[l] += d * d;
        }
    }
    let mut acc = lanes.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x {
        *v *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dot_variants_agree() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 7, 8, 9, 64, 100, 1023] {
            let a: Vec<f32> =
                (0..len).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> =
                (0..len).map(|_| rng.normal() as f32).collect();
            let d1 = dot_scalar(&a, &b);
            let d2 = dot(&a, &b);
            assert!(
                (d1 - d2).abs() <= 1e-3 * (1.0 + d1.abs()),
                "len={len}: {d1} vs {d2}"
            );
        }
    }

    #[test]
    fn dot_known_value() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        assert_eq!(dot_scalar(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0f32, 1.0, 1.0];
        axpy(2.0, &[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn dist_sq_matches_expansion() {
        let mut rng = Rng::new(2);
        let a: Vec<f32> = (0..37).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..37).map(|_| rng.normal() as f32).collect();
        let expanded = norm_sq(&a) + norm_sq(&b) - 2.0 * dot(&a, &b);
        assert!((dist_sq(&a, &b) - expanded).abs() < 1e-3);
    }

    #[test]
    fn norm_and_scale() {
        let mut v = vec![3.0f32, 4.0];
        assert_eq!(norm_sq(&v), 25.0);
        scale(2.0, &mut v);
        assert_eq!(v, vec![6.0, 8.0]);
    }
}
