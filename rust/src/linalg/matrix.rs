//! Row-major dense f32 matrix. The single storage type shared by the
//! dataset, SVM and approximation layers — deliberately simple so the
//! hot paths in [`super::gemm`]/[`super::quadform`] can work on plain
//! slices.

#![forbid(unsafe_code)]

use crate::{Error, Result};

/// Row-major dense matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Mat> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "data len {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f32]]) -> Result<Mat> {
        if rows.is_empty() {
            return Ok(Mat::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                return Err(Error::Shape("ragged rows".into()));
            }
            data.extend_from_slice(r);
        }
        Ok(Mat { rows: rows.len(), cols, data })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Copy a contiguous block of rows.
    pub fn rows_slice(&self, start: usize, count: usize) -> Mat {
        assert!(start + count <= self.rows);
        Mat {
            rows: count,
            cols: self.cols,
            data: self.data
                [start * self.cols..(start + count) * self.cols]
                .to_vec(),
        }
    }

    /// Gather a subset of rows by index.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Pad to `(new_rows, new_cols)` with zeros (never shrinks).
    pub fn pad_to(&self, new_rows: usize, new_cols: usize) -> Mat {
        assert!(new_rows >= self.rows && new_cols >= self.cols);
        let mut out = Mat::zeros(new_rows, new_cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
        }
        out
    }

    /// Max absolute element-wise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Squared L2 norm of every row.
    pub fn row_norms_sq(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| super::vecops::dot(self.row(r), self.row(r)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn from_vec_shape_checked() {
        assert!(Mat::from_vec(2, 2, vec![1.0; 3]).is_err());
    }

    #[test]
    fn ragged_rows_rejected() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32];
        assert!(Mat::from_rows(&[&a, &b]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_vec(2, 3, (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(2, 1), m.at(1, 2));
    }

    #[test]
    fn pad_and_gather() {
        let m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let p = m.pad_to(3, 4);
        assert_eq!(p.at(0, 1), 2.0);
        assert_eq!(p.at(2, 3), 0.0);
        let g = m.gather_rows(&[1, 0, 1]);
        assert_eq!(g.row(0), &[3., 4.]);
        assert_eq!(g.row(2), &[3., 4.]);
    }

    #[test]
    fn row_norms() {
        let m = Mat::from_vec(2, 2, vec![3., 4., 0., 2.]).unwrap();
        assert_eq!(m.row_norms_sq(), vec![25.0, 4.0]);
    }
}
