//! The sharded serving plane: tenant placement and the per-shard
//! executor pool behind the [`super::Client`] ingress.
//!
//! A coordinator built with [`super::CoordinatorBuilder::shards`]`(n)`
//! owns a `ShardSet` of `n` independent shards. Each shard is a full
//! serving lane — its own bounded ingress queue, its own batcher thread
//! (per-model grouping, per-tenant flush policy), its own executor
//! thread (resident-model LRU, [`crate::predictor::Predictor`]
//! instances, swap polling + async generation prefetch) and its own
//! metrics sink. Nothing is shared between shards on the request path,
//! so lanes scale without a global lock.
//!
//! Tenants are placed by **rendezvous (highest-random-weight) hashing**
//! on the model id ([`assign`]): every batch of a model is served by
//! exactly one shard, which keeps per-model batching, generation
//! hot-swap ordering and the resident-model LRU local to one executor.
//! Rendezvous placement is *stable*: a tenant's shard depends only on
//! its id and the shard count — publishing or removing other tenants
//! never moves it, and republishing a bundle reloads it on the same
//! owning shard (rebalance-on-hot-swap is a no-op by construction, so
//! in-flight requests are never dropped by a republish).
//!
//! Completions fan back in on the submitting client's own channel (the
//! reply sender rides inside each request), so the sharded plane needs
//! no completion router: `n` executors may complete into one session
//! concurrently and [`super::Session::wait_all`] still returns
//! submission order.

use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::log_warn;
use crate::{Error, Result};

use super::batcher::{run_batcher, IngressQueue};
use super::metrics::Metrics;
use super::policy::PolicyTable;
use super::request::{WorkItem, DEFAULT_MODEL};
use super::server::CoordinatorConfig;
use super::worker::{ModelSource, WorkerParams};

/// FNV-1a over the model id, mixed with the shard index — deterministic
/// across processes and platforms (unlike `DefaultHasher`), so shard
/// ownership is reproducible in tests and across restarts.
fn weight(model: &str, shard: u64) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in model.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(PRIME);
    }
    for b in shard.to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Rendezvous placement: the shard that owns `model` among `n_shards`.
///
/// Deterministic, uniform in expectation, and stable under tenant
/// add/remove (a tenant's placement is a function of its id and the
/// shard count only). `n_shards == 0` is treated as 1.
pub fn assign(model: &str, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    // Explicit fold instead of max_by_key so the n_shards >= 2 range
    // needs no "non-empty" panic path (max_by_key returns an Option).
    // `>=` keeps max_by_key's last-max-wins tie behavior, matching the
    // independent HRW reimplementation the placement-parity test pins.
    let mut best = 0usize;
    let mut best_w = weight(model, 0);
    for s in 1..n_shards {
        let w = weight(model, s as u64);
        if w >= best_w {
            best = s;
            best_w = w;
        }
    }
    best
}

/// One serving lane: ingress + batcher thread + executor thread +
/// metrics sink.
pub(crate) struct Shard {
    pub ingress: Arc<IngressQueue>,
    pub metrics: Arc<Metrics>,
    batcher: Option<JoinHandle<()>>,
    worker: Option<JoinHandle<Result<()>>>,
}

/// The executor pool: `n` [`Shard`]s spawned from one configuration.
pub(crate) struct ShardSet {
    shards: Vec<Shard>,
}

impl ShardSet {
    /// Spawn `config.shards` lanes over clones of `source`. Each lane's
    /// executor gets a `max_resident_models / n` share of the plane-wide
    /// residency bound plus 25% headroom (never more than the plane
    /// bound itself): rendezvous ownership is binomial, not exact, so a
    /// shard owning slightly more than its share must not thrash its
    /// LRU while the plane as a whole is under budget.
    pub(crate) fn spawn(
        config: &CoordinatorConfig,
        source: &ModelSource,
        epoch: &Arc<AtomicU64>,
    ) -> Result<ShardSet> {
        let n = config.shards.max(1);
        let share = config.max_resident_models.div_ceil(n);
        // share + share/4, overflow-safe for "unbounded" configs.
        let per_shard_resident = config
            .max_resident_models
            .min(share.saturating_add(share / 4))
            .max(1);
        // A static plane has exactly one model on exactly one owning
        // lane; the others would clone the full SVM just to idle, so
        // they get an empty source instead (submit-side validation
        // guarantees no batch can ever reach them).
        let static_owner = match source {
            ModelSource::Static { .. } => Some(assign(DEFAULT_MODEL, n)),
            _ => None,
        };
        let mut set = ShardSet { shards: Vec::with_capacity(n) };
        for index in 0..n {
            let w_source = match static_owner {
                Some(owner) if owner != index => ModelSource::Empty,
                _ => source.clone(),
            };
            let lane = spawn_lane(
                config,
                w_source,
                epoch,
                index,
                n,
                per_shard_resident,
            );
            match lane {
                Ok(shard) => set.shards.push(shard),
                Err(e) => {
                    // A lane failed mid-spawn (thread limit, OOM):
                    // tear the already-running lanes down — otherwise
                    // their batcher/executor threads would outlive the
                    // failed builder call for the life of the process.
                    let _ = set.shutdown();
                    return Err(e);
                }
            }
        }
        Ok(set)
    }

    pub(crate) fn len(&self) -> usize {
        self.shards.len()
    }

    /// Ingress handles in shard order (index == [`assign`] output).
    pub(crate) fn ingresses(&self) -> Vec<Arc<IngressQueue>> {
        self.shards.iter().map(|s| s.ingress.clone()).collect()
    }

    /// Metrics sinks in shard order, for fan-in aggregation.
    pub(crate) fn metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.metrics.clone()).collect()
    }

    /// Close every ingress, then join every lane. Returns the first
    /// executor error (all lanes are joined regardless).
    pub(crate) fn shutdown(&mut self) -> Result<()> {
        for shard in &self.shards {
            shard.ingress.close();
        }
        for shard in &mut self.shards {
            if let Some(h) = shard.batcher.take() {
                let _ = h.join();
            }
        }
        let mut first_err: Option<Error> = None;
        for shard in &mut self.shards {
            if let Some(h) = shard.worker.take() {
                let failed = match h.join() {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e),
                    Err(_) => {
                        Some(Error::Other("executor panicked".into()))
                    }
                };
                if first_err.is_none() {
                    first_err = failed;
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Spawn one serving lane (ingress, executor thread, batcher thread).
/// On a batcher-spawn failure the lane's own executor self-terminates:
/// its `work_tx` is dropped with the failed closure, so the executor's
/// `recv()` loop ends on disconnect.
fn spawn_lane(
    config: &CoordinatorConfig,
    source: ModelSource,
    epoch: &Arc<AtomicU64>,
    index: usize,
    shard_count: usize,
    max_resident: usize,
) -> Result<Shard> {
    let ingress = Arc::new(IngressQueue::new(config.queue_capacity));
    let metrics = Arc::new(Metrics::new());
    let policies = Arc::new(PolicyTable::new());
    let (work_tx, work_rx): (Sender<WorkItem>, Receiver<WorkItem>) =
        mpsc::channel();

    // Executor thread (owns predictors / PJRT engine / the shard's
    // resident tenants).
    let spec = config.exec.clone();
    let w_metrics = metrics.clone();
    let w_epoch = epoch.clone();
    let params = WorkerParams {
        policy: config.policy,
        swap_poll: config.swap_poll,
        max_resident,
        policies: policies.clone(),
        shard: index,
        shard_count,
        warm_start: config.warm_start,
        quant_drift_tol: config.quant_drift_tol,
    };
    let worker = std::thread::Builder::new()
        .name(format!("approxrbf-executor-{index}"))
        .spawn(move || {
            let out = super::worker::run_worker(
                spec, source, params, w_epoch, work_rx, w_metrics,
            );
            if let Err(ref e) = out {
                log_warn!("executor shard {index} exited: {e}");
            }
            out
        })
        .map_err(|e| Error::Other(format!("spawn executor {index}: {e}")))?;

    // Batcher thread: drains this shard's ingress, groups by model id,
    // flushes each group on its tenant's max_batch/max_wait.
    let b_ingress = ingress.clone();
    let (max_batch, max_wait) = (config.max_batch, config.max_wait);
    let batcher = std::thread::Builder::new()
        .name(format!("approxrbf-batcher-{index}"))
        .spawn(move || {
            run_batcher(b_ingress, work_tx, policies, max_batch, max_wait)
        })
        .map_err(|e| Error::Other(format!("spawn batcher {index}: {e}")))?;

    Ok(Shard {
        ingress,
        metrics,
        batcher: Some(batcher),
        worker: Some(worker),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_is_deterministic_and_in_range() {
        for n in 1..=8usize {
            for id in ["default", "alpha", "bravo", "tenant-42", ""] {
                let s = assign(id, n);
                assert!(s < n, "assign('{id}', {n}) = {s}");
                assert_eq!(s, assign(id, n), "must be deterministic");
            }
        }
    }

    #[test]
    fn assign_single_shard_is_zero() {
        assert_eq!(assign("anything", 1), 0);
        assert_eq!(assign("anything", 0), 0);
    }

    #[test]
    fn assign_spreads_tenants() {
        // 64 ids over 4 shards: rendezvous hashing must not collapse
        // onto a single shard (a uniformity smoke test, not a bound).
        let n = 4;
        let mut counts = vec![0usize; n];
        for i in 0..64 {
            counts[assign(&format!("tenant-{i}"), n)] += 1;
        }
        assert!(
            counts.iter().all(|&c| c > 0),
            "some shard owns nothing: {counts:?}"
        );
    }

    #[test]
    fn assign_stable_under_tenant_add_remove() {
        // Placement is a pure function of (id, shard count): computing
        // it for any other tenant set cannot move an existing tenant.
        let before: Vec<usize> =
            (0..16).map(|i| assign(&format!("t{i}"), 8)).collect();
        // "Add" and "remove" tenants (i.e. evaluate a different set).
        let _ = assign("newcomer", 8);
        let after: Vec<usize> =
            (0..16).map(|i| assign(&format!("t{i}"), 8)).collect();
        assert_eq!(before, after);
    }
}
