//! Request/response types flowing through the coordinator.

use std::time::{Duration, Instant};

/// Which execution path served an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Route {
    /// O(d²) approximated model (Eq. 3.8).
    Approx,
    /// O(n_SV·d) exact model.
    Exact,
}

impl Route {
    pub fn name(&self) -> &'static str {
        match self {
            Route::Approx => "approx",
            Route::Exact => "exact",
        }
    }
}

/// An inference request (one instance).
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub id: u64,
    pub features: Vec<f32>,
    pub enqueued_at: Instant,
}

/// A served prediction.
#[derive(Clone, Debug)]
pub struct PredictResponse {
    pub id: u64,
    /// Decision value f(z) or f̂(z).
    pub decision: f32,
    /// sign(decision) as ±1.
    pub label: f32,
    pub route: Route,
    /// ‖z‖² (the bound-check quantity; free by-product).
    pub znorm_sq: f32,
    /// True iff Eq. (3.11) held for this instance.
    pub in_bound: bool,
    /// Queue + batch + execute latency.
    pub latency: Duration,
}

/// A routed batch handed to the executor.
#[derive(Debug)]
pub(crate) enum WorkItem {
    Batch { route: Route, requests: Vec<PredictRequest> },
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_names() {
        assert_eq!(Route::Approx.name(), "approx");
        assert_eq!(Route::Exact.name(), "exact");
    }
}
