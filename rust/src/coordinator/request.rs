//! Request/response types flowing through the coordinator.

use std::time::{Duration, Instant};

pub use crate::registry::ModelId;

/// Model id used by the single-model [`super::Coordinator::start`] path
/// and by [`super::Coordinator::submit`].
pub const DEFAULT_MODEL: &str = "default";

pub(crate) fn default_model_id() -> ModelId {
    std::sync::Arc::from(DEFAULT_MODEL)
}

/// Which execution path served an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Route {
    /// O(d²) approximated model (Eq. 3.8).
    Approx,
    /// O(n_SV·d) exact model.
    Exact,
}

impl Route {
    pub fn name(&self) -> &'static str {
        match self {
            Route::Approx => "approx",
            Route::Exact => "exact",
        }
    }
}

/// An inference request (one instance, addressed to one model).
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub id: u64,
    /// Which registered model serves this instance.
    pub model: ModelId,
    pub features: Vec<f32>,
    pub enqueued_at: Instant,
}

/// A served prediction.
#[derive(Clone, Debug)]
pub struct PredictResponse {
    pub id: u64,
    /// Model that served the request.
    pub model: ModelId,
    /// Publish generation of the model version that served it (0 for
    /// coordinators started from in-memory models).
    pub generation: u64,
    /// Decision value f(z) or f̂(z).
    pub decision: f32,
    /// sign(decision) as ±1.
    pub label: f32,
    pub route: Route,
    /// ‖z‖² (the bound-check quantity; free by-product).
    pub znorm_sq: f32,
    /// True iff Eq. (3.11) held for this instance.
    pub in_bound: bool,
    /// Queue + batch + execute latency.
    pub latency: Duration,
}

/// A batch handed to the executor: same model, not yet routed (the
/// executor routes with the model's own Eq. 3.11 budget).
#[derive(Debug)]
pub(crate) enum WorkItem {
    Batch { model: ModelId, requests: Vec<PredictRequest> },
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_names() {
        assert_eq!(Route::Approx.name(), "approx");
        assert_eq!(Route::Exact.name(), "exact");
    }

    #[test]
    fn model_ids_compare_by_content() {
        let a: ModelId = std::sync::Arc::from("tenant-1");
        let b: ModelId = std::sync::Arc::from(String::from("tenant-1"));
        assert_eq!(a, b);
        assert_eq!(default_model_id(), std::sync::Arc::from(DEFAULT_MODEL));
    }
}
