//! Request/response/completion types flowing through the coordinator.
//!
//! Every submitted request is answered with exactly one [`Completion`]:
//! `Ok(PredictResponse)` when it was served, `Err(PredictError)` when it
//! could not be — unknown model, dimension drift across a hot swap,
//! executor failure, or shutdown. Errors are delivered on the same
//! channel as successes, so callers fail fast instead of waiting out a
//! timeout (the pre-redesign behavior, where executor-side drops were
//! visible only as a `dropped_requests` metric).

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

pub use crate::registry::ModelId;

/// Model id used by the single-model
/// [`super::CoordinatorBuilder::start`] path and by
/// [`super::Client::submit`].
pub const DEFAULT_MODEL: &str = "default";

pub(crate) fn default_model_id() -> ModelId {
    std::sync::Arc::from(DEFAULT_MODEL)
}

/// Which execution path served an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Route {
    /// O(d²) approximated model (Eq. 3.8).
    Approx,
    /// O(n_SV·d) exact model.
    Exact,
}

impl Route {
    pub fn name(&self) -> &'static str {
        match self {
            Route::Approx => "approx",
            Route::Exact => "exact",
        }
    }
}

/// The one-per-request outcome: a served prediction or a typed failure.
pub type Completion = std::result::Result<PredictResponse, PredictError>;

/// Why a request failed. Carried inside [`PredictError`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PredictErrorKind {
    /// The model id could not be resolved — not served by this
    /// coordinator, not in the registry, or its bundle became
    /// unreadable between submit and execution.
    UnknownModel { detail: String },
    /// The instance's feature dimension disagrees with the model's.
    DimMismatch { got: usize, want: usize },
    /// The executor failed to evaluate the batch (e.g. a failing swap
    /// left unusable state, or an XLA artifact was missing).
    Exec { detail: String },
    /// The coordinator shut down before the request completed.
    Shutdown,
}

impl std::fmt::Display for PredictErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictErrorKind::UnknownModel { detail } => {
                write!(f, "unknown model: {detail}")
            }
            PredictErrorKind::DimMismatch { got, want } => {
                write!(f, "dimension mismatch: instance dim {got} vs model dim {want}")
            }
            PredictErrorKind::Exec { detail } => {
                write!(f, "execution failed: {detail}")
            }
            PredictErrorKind::Shutdown => {
                write!(f, "coordinator is shut down")
            }
        }
    }
}

/// A request that could not be served, attributed to the request id and
/// model that failed so callers can correlate it with their submission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PredictError {
    /// Id the failed request was assigned at submit.
    pub id: u64,
    /// Model the request addressed.
    pub model: ModelId,
    pub kind: PredictErrorKind,
}

impl PredictError {
    pub(crate) fn new(
        id: u64,
        model: ModelId,
        kind: PredictErrorKind,
    ) -> PredictError {
        PredictError { id, model, kind }
    }
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} for model '{}': {}", self.id, self.model, self.kind)
    }
}

impl std::error::Error for PredictError {}

/// Lossy conversion for legacy call sites that return [`crate::Error`]:
/// the error class is preserved (`InvalidArg` / `Shape` / `Other`) but
/// the typed kind is flattened into the message.
impl From<PredictError> for crate::Error {
    fn from(e: PredictError) -> crate::Error {
        let msg = e.to_string();
        match e.kind {
            PredictErrorKind::UnknownModel { .. } => {
                crate::Error::InvalidArg(msg)
            }
            PredictErrorKind::DimMismatch { .. } => crate::Error::Shape(msg),
            PredictErrorKind::Exec { .. } | PredictErrorKind::Shutdown => {
                crate::Error::Other(msg)
            }
        }
    }
}

/// An inference request (one instance, addressed to one model),
/// carrying the reply handle its completion is delivered on.
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub id: u64,
    /// Which registered model serves this instance.
    pub model: ModelId,
    pub features: Vec<f32>,
    pub enqueued_at: Instant,
    /// Where this request's [`Completion`] goes (the submitting
    /// [`super::Client`]'s or [`super::Session`]'s channel).
    pub(crate) reply: Sender<Completion>,
}

impl PredictRequest {
    /// Deliver a failure completion for this request (consumes it).
    pub(crate) fn fail(self, kind: PredictErrorKind) {
        let err = PredictError::new(self.id, self.model.clone(), kind);
        let _ = self.reply.send(Err(err));
    }
}

/// A served prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictResponse {
    pub id: u64,
    /// Model that served the request.
    pub model: ModelId,
    /// Publish generation of the model version that served it (0 for
    /// coordinators started from in-memory models).
    pub generation: u64,
    /// Decision value f(z) or f̂(z).
    pub decision: f32,
    /// sign(decision) as ±1.
    pub label: f32,
    pub route: Route,
    /// ‖z‖² (the bound-check quantity; free by-product).
    pub znorm_sq: f32,
    /// True iff Eq. (3.11) held for this instance.
    pub in_bound: bool,
    /// Queue + batch + execute latency.
    pub latency: Duration,
}

/// A batch handed to the executor: same model, not yet routed (the
/// executor routes with the model's own Eq. 3.11 budget).
#[derive(Debug)]
pub(crate) enum WorkItem {
    Batch { model: ModelId, requests: Vec<PredictRequest> },
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_names() {
        assert_eq!(Route::Approx.name(), "approx");
        assert_eq!(Route::Exact.name(), "exact");
    }

    #[test]
    fn model_ids_compare_by_content() {
        let a: ModelId = std::sync::Arc::from("tenant-1");
        let b: ModelId = std::sync::Arc::from(String::from("tenant-1"));
        assert_eq!(a, b);
        assert_eq!(default_model_id(), std::sync::Arc::from(DEFAULT_MODEL));
    }

    #[test]
    fn predict_error_display_names_request_and_model() {
        let e = PredictError::new(
            7,
            std::sync::Arc::from("alpha"),
            PredictErrorKind::DimMismatch { got: 3, want: 8 },
        );
        let s = e.to_string();
        assert!(s.contains("request 7"), "{s}");
        assert!(s.contains("alpha"), "{s}");
        assert!(s.contains("dim 3"), "{s}");
    }

    #[test]
    fn predict_error_maps_onto_legacy_error_classes() {
        let mid: ModelId = std::sync::Arc::from("m");
        let cases: [(PredictErrorKind, fn(&crate::Error) -> bool); 4] = [
            (
                PredictErrorKind::UnknownModel { detail: "x".into() },
                |e| matches!(e, crate::Error::InvalidArg(_)),
            ),
            (
                PredictErrorKind::DimMismatch { got: 1, want: 2 },
                |e| matches!(e, crate::Error::Shape(_)),
            ),
            (
                PredictErrorKind::Exec { detail: "boom".into() },
                |e| matches!(e, crate::Error::Other(_)),
            ),
            (PredictErrorKind::Shutdown, |e| {
                matches!(e, crate::Error::Other(_))
            }),
        ];
        for (kind, check) in cases {
            let legacy: crate::Error =
                PredictError::new(0, mid.clone(), kind).into();
            assert!(check(&legacy), "{legacy}");
        }
    }

    #[test]
    fn fail_delivers_error_completion() {
        let (tx, rx) = std::sync::mpsc::channel();
        let req = PredictRequest {
            id: 3,
            model: default_model_id(),
            features: vec![0.0],
            enqueued_at: Instant::now(),
            reply: tx,
        };
        req.fail(PredictErrorKind::Shutdown);
        match rx.recv().unwrap() {
            Err(e) => {
                assert_eq!(e.id, 3);
                assert_eq!(e.kind, PredictErrorKind::Shutdown);
            }
            Ok(_) => panic!("expected an error completion"),
        }
    }
}
