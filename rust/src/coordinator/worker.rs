//! Executor thread: owns the predictors (native Rust backends or the
//! PJRT engine — the engine is `!Send`, so it is constructed *inside*
//! the thread), resolves per-model state and [`TenantPolicy`] through
//! the registry, routes each batch with that model's Eq. 3.11 budget
//! and route policy, and completes every request exactly once — either
//! `Ok(PredictResponse)` or a fail-fast `Err(PredictError)` (unknown
//! model, dimension drift, execution failure).
//!
//! Every evaluation goes through the engine-agnostic
//! [`crate::predictor::Predictor`] trait, so the executor is the same
//! code for the exact evaluator, the approximated model and the XLA
//! engine.
//!
//! Hot-swap protocol: for registry-backed coordinators the worker
//! revalidates a model's on-disk generation when the coordinator's
//! refresh epoch ticks, or at most every `swap_poll` otherwise (a
//! 32-byte header read). An epoch tick (an explicit
//! [`super::Coordinator::refresh`]) reloads synchronously — the caller
//! asked for the new generation *now*. A steady-state poll that detects
//! a moved generation instead hands the `.arbf` decode to this shard's
//! `Prefetcher` thread and keeps serving the resident generation; the
//! decoded entry is swapped in atomically on a later batch, so hot-swap
//! latency on the request path no longer includes the decode. Requests
//! already in flight finish on whichever generation they resolved. If a
//! reload fails, the worker keeps serving the generation it has
//! (availability beats freshness for a serving node).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::linalg::{Mat, MathBackend};
use crate::log_warn;
use crate::predictor::{
    ApproxPredictor, PredictOutput, Predictor, QuantApproxPredictor,
    QuantExactPredictor, RffPredictor,
};
use crate::registry::{
    ModelEntry, ModelStore, PayloadKind, TenantModels,
};
use crate::svm::predict::ExactPredictor;
use crate::svm::SvmModel;
use crate::util::sync::lock_unpoisoned;
use crate::Result;

use super::metrics::Metrics;
use super::policy::{PolicyTable, TenantPolicy};
use super::request::{
    default_model_id, ModelId, PredictErrorKind, PredictRequest,
    PredictResponse, Route, WorkItem,
};
use super::router::Router;

/// Which execution substrate the worker uses.
#[derive(Clone, Debug)]
pub enum ExecSpec {
    /// Pure-Rust predictors with the given math backend.
    Native(MathBackend),
    /// PJRT engine over AOT artifacts (`make artifacts`). Requires the
    /// `pjrt` feature (and a real `xla` crate underneath it).
    #[cfg(feature = "pjrt")]
    Xla { artifacts_dir: std::path::PathBuf },
}

/// Where the worker gets model state from. Clone is cheap for the
/// registry variant (an `Arc`); the static variant clones the models
/// once per shard at spawn.
#[derive(Clone)]
pub(crate) enum ModelSource {
    /// One fixed (exact, approx) pair under [`super::request::DEFAULT_MODEL`].
    Static { exact: SvmModel, approx: ApproxModel },
    /// Lazy per-id resolution through a shared registry.
    Registry { store: Arc<ModelStore> },
    /// No local model: a lane of a static-model plane that rendezvous
    /// placement can never route to (placement is validated at submit,
    /// so such a lane never sees a batch — it just must not pay for a
    /// clone of models it cannot serve).
    Empty,
}

#[cfg(feature = "pjrt")]
struct PreparedPair {
    approx: crate::runtime::PreparedApprox,
    exact: crate::runtime::PreparedExact,
}

/// Tuning knobs forwarded from [`super::server::CoordinatorConfig`].
pub(crate) struct WorkerParams {
    /// Default route policy (a tenant's [`TenantPolicy`] overrides it).
    pub policy: super::router::RoutePolicy,
    pub swap_poll: Duration,
    /// LRU bound on fully resident tenants in this executor.
    pub max_resident: usize,
    /// Shared per-tenant policy table the executor populates for the
    /// batcher as it decodes bundles.
    pub policies: Arc<PolicyTable>,
    /// This executor's shard index (diagnostics + placement-aware warm).
    pub shard: usize,
    /// Total shards in the plane (placement-aware warm).
    pub shard_count: usize,
    /// Registry mode: pre-decode this shard's owned tenants at startup.
    pub warm_start: bool,
    /// Max absolute decision drift quantization may add before a
    /// quantized tenant's Hybrid router escorts the instance to the
    /// exact path (folded into the Eq. 3.11 budget per model; see
    /// [`crate::registry::ModelEntry::znorm_sq_budget_with`]). A
    /// tenant whose bundle policy pins its own tolerance intersects it
    /// with this plane-wide floor (`min`) at tenant load/swap time.
    pub quant_drift_tol: f32,
}

/// Substrate column this tenant reports to metrics: what its fast
/// path actually is — `"exact"` when the bundle policy pins
/// AlwaysExact (the approximation never runs), else the storage the
/// Approx route evaluates on.
fn substrate_label(entry: &ModelEntry) -> &'static str {
    use super::router::RoutePolicy;
    if entry.policy.and_then(|p| p.route) == Some(RoutePolicy::AlwaysExact) {
        return "exact";
    }
    match &entry.models {
        TenantModels::F32 { .. } => "maclaurin",
        TenantModels::Rff { .. } => "rff",
        TenantModels::Quantized { .. } => match entry.payload() {
            PayloadKind::F16 => "f16",
            _ => "int8",
        },
    }
}

/// Per-model serving state resident in the executor.
struct Tenant {
    entry: Arc<ModelEntry>,
    /// SV norms of the exact model, cached per generation so the
    /// native exact path skips the O(n_SV·d) precompute per batch.
    sv_norms: Vec<f32>,
    /// The Eq. 3.11 budget with this entry's quantization drift folded
    /// in — constant per generation, cached so the per-batch path does
    /// not rescan the quantized payload (the f16 eps is an O(d²) scan).
    znorm_sq_budget: f32,
    /// Metrics substrate column (see [`substrate_label`]), constant
    /// per generation.
    substrate: &'static str,
    /// Heap/mapped split of the entry's resident footprint, constant
    /// per generation (a v2 entry served over a memory map charges
    /// only its scalar residue as heap); reported to the per-model
    /// metrics gauge so operators see actual heap, not payload size.
    heap_bytes: usize,
    mapped_bytes: usize,
    /// Refresh epoch this tenant last revalidated against.
    epoch_seen: u64,
    last_check: Instant,
    /// Monotone use counter for LRU eviction.
    last_used: u64,
    /// Lazily (re)built per generation on the XLA path.
    #[cfg(feature = "pjrt")]
    prepared: Option<PreparedPair>,
}

impl Tenant {
    /// Effective drift tolerance for `entry`: its bundle policy's pin
    /// intersected with the plane-wide default (`min` — a tenant
    /// tightens, never loosens; see
    /// [`TenantPolicy::quant_drift_tol_or`]).
    fn effective_drift_tol(entry: &ModelEntry, plane_default: f32) -> f32 {
        entry
            .policy
            .unwrap_or_default()
            .quant_drift_tol_or(plane_default)
    }

    fn new(entry: Arc<ModelEntry>, epoch: u64, quant_drift_tol: f32) -> Tenant {
        let sv_norms = entry.sv_row_norms_sq();
        let tol = Tenant::effective_drift_tol(&entry, quant_drift_tol);
        let znorm_sq_budget = entry.znorm_sq_budget_with(tol);
        let substrate = substrate_label(&entry);
        let (heap_bytes, mapped_bytes) =
            (entry.heap_bytes(), entry.mapped_bytes());
        Tenant {
            entry,
            sv_norms,
            znorm_sq_budget,
            substrate,
            heap_bytes,
            mapped_bytes,
            epoch_seen: epoch,
            last_check: Instant::now(),
            last_used: 0,
            #[cfg(feature = "pjrt")]
            prepared: None,
        }
    }

    fn swap(&mut self, entry: Arc<ModelEntry>, quant_drift_tol: f32) {
        self.sv_norms = entry.sv_row_norms_sq();
        let tol = Tenant::effective_drift_tol(&entry, quant_drift_tol);
        self.znorm_sq_budget = entry.znorm_sq_budget_with(tol);
        self.substrate = substrate_label(&entry);
        self.heap_bytes = entry.heap_bytes();
        self.mapped_bytes = entry.mapped_bytes();
        self.entry = entry;
        #[cfg(feature = "pjrt")]
        {
            self.prepared = None;
        }
    }

    /// Policy declared in the tenant's bundle (default when absent).
    fn policy(&self) -> TenantPolicy {
        self.entry.policy.unwrap_or_default()
    }
}

enum Exec {
    Native(MathBackend),
    #[cfg(feature = "pjrt")]
    Xla(crate::runtime::Engine),
}

/// Per-shard decode-ahead thread: the executor hands it model ids whose
/// on-disk generation moved; it decodes them through the store (which
/// seeds the shared entry cache) and parks the decoded `Arc<ModelEntry>`
/// in `ready` for the executor to swap in between batches. This keeps
/// the `.arbf` decode — the expensive part of a hot swap — off the
/// request path.
struct Prefetcher {
    tx: Option<Sender<ModelId>>,
    ready: Arc<Mutex<HashMap<ModelId, Arc<ModelEntry>>>>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    fn spawn(store: Arc<ModelStore>, shard: usize) -> Result<Prefetcher> {
        let (tx, rx) = mpsc::channel::<ModelId>();
        let ready: Arc<Mutex<HashMap<ModelId, Arc<ModelEntry>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let out = ready.clone();
        let handle = std::thread::Builder::new()
            .name(format!("approxrbf-prefetch-{shard}"))
            .spawn(move || {
                // Bound on parked decode results. A decode can land
                // after its tenant was LRU-evicted (nobody will take()
                // it); clearing the map when it overflows keeps memory
                // bounded, and any still-wanted entry is simply
                // re-requested by its owner's next swap poll.
                const READY_CAP: usize = 64;
                while let Ok(id) = rx.recv() {
                    match store.load(&id) {
                        Ok(entry) => {
                            let mut ready = lock_unpoisoned(&out);
                            if ready.len() >= READY_CAP
                                && !ready.contains_key(&id)
                            {
                                log_warn!(
                                    "prefetch: dropping {} stale parked \
                                     result(s)",
                                    ready.len()
                                );
                                ready.clear();
                            }
                            ready.insert(id, entry);
                        }
                        // The next swap poll re-requests; nothing to do
                        // here beyond surfacing the failure.
                        Err(e) => log_warn!(
                            "prefetch: decode of '{id}' failed: {e}"
                        ),
                    }
                }
            })
            .map_err(|e| {
                crate::Error::Other(format!("spawn prefetcher: {e}"))
            })?;
        Ok(Prefetcher { tx: Some(tx), ready, handle: Some(handle) })
    }

    /// Queue a decode (non-blocking; duplicates are cheap cache hits).
    fn request(&self, id: &ModelId) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(id.clone());
        }
    }

    /// Take a decoded entry, if the prefetch completed.
    fn take(&self, id: &ModelId) -> Option<Arc<ModelEntry>> {
        lock_unpoisoned(&self.ready).remove(id)
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Disconnect the channel so the thread's recv() loop ends.
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Run the executor loop until a `Shutdown` item arrives.
/// Called on a dedicated thread by [`super::server::Coordinator`].
pub(crate) fn run_worker(
    spec: ExecSpec,
    source: ModelSource,
    params: WorkerParams,
    epoch: Arc<AtomicU64>,
    work_rx: Receiver<WorkItem>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    // The XLA engine must be created on this thread (PJRT handles are
    // not Send).
    let exec = match spec {
        ExecSpec::Native(backend) => Exec::Native(backend),
        #[cfg(feature = "pjrt")]
        ExecSpec::Xla { artifacts_dir } => {
            Exec::Xla(crate::runtime::Engine::load(&artifacts_dir)?)
        }
    };
    let mut tenants: HashMap<ModelId, Tenant> = HashMap::new();
    let store = match source {
        ModelSource::Static { exact, approx } => {
            let id = default_model_id();
            let entry = Arc::new(ModelEntry {
                id: id.clone(),
                generation: 0,
                models: TenantModels::F32 { exact, approx },
                policy: None,
            });
            tenants.insert(
                id,
                Tenant::new(
                    entry,
                    epoch.load(Ordering::Acquire),
                    params.quant_drift_tol,
                ),
            );
            None
        }
        ModelSource::Registry { store } => Some(store),
        ModelSource::Empty => None,
    };
    let prefetcher = match &store {
        Some(store) => {
            if params.warm_start {
                // Placement-aware warm: pre-decode only the tenants
                // rendezvous hashing assigns to this shard, so `n`
                // shards warming in parallel each decode 1/n of the
                // registry instead of all of it n times.
                if let Err(e) = store.warm_where(|id| {
                    super::shard::assign(id, params.shard_count)
                        == params.shard
                }) {
                    log_warn!(
                        "executor shard {}: warm failed: {e}",
                        params.shard
                    );
                }
            }
            Some(Prefetcher::spawn(store.clone(), params.shard)?)
        }
        None => None,
    };

    let mut tick: u64 = 0;
    while let Ok(item) = work_rx.recv() {
        let (model, requests) = match item {
            WorkItem::Shutdown => break,
            WorkItem::Batch { model, requests } => (model, requests),
        };
        if requests.is_empty() {
            continue;
        }
        let now_epoch = epoch.load(Ordering::Acquire);
        tick += 1;
        let tenant = match resolve(
            &mut tenants,
            store.as_deref(),
            prefetcher.as_ref(),
            &model,
            &params,
            now_epoch,
            tick,
        ) {
            Ok(t) => t,
            Err(detail) => {
                // Unresolvable model (deleted or corrupted between
                // submit and execution): fail the batch fast — every
                // caller gets a typed completion instead of waiting out
                // its timeout — and keep serving other tenants.
                metrics.record_dropped(&model, requests.len());
                log_warn!(
                    "executor: failing {} request(s) for unresolvable \
                     model '{model}': {detail}",
                    requests.len()
                );
                for req in requests {
                    req.fail(PredictErrorKind::UnknownModel {
                        detail: detail.clone(),
                    });
                }
                continue;
            }
        };
        let generation = tenant.entry.generation;
        // Per-model resident-bytes gauge, constant per generation and
        // cached on the tenant; re-set per batch so a hot swap (or a
        // v1→v2 migration that moves the payload off the heap) updates
        // the row without extra bookkeeping.
        metrics.set_model_bytes(&model, tenant.heap_bytes, tenant.mapped_bytes);
        // The Eq. 3.11 budget with this tenant's quantization drift
        // folded in — cached per generation on the tenant (an f32
        // entry serves the raw Maclaurin budget).
        let budget = tenant.znorm_sq_budget;
        let route_policy = tenant.policy().route_or(params.policy);
        let router = Router { policy: route_policy, znorm_sq_budget: budget };
        // Submit-side dimension checks can go stale across an
        // out-of-band republish; anything that no longer matches the
        // resolved model's dimension fails fast here.
        let want_dim = tenant.entry.dim();
        // Routing already computes each ‖z‖²; keep it alongside the
        // request so no path pays a second O(batch·d) norm pass.
        let mut approx_reqs = Vec::new();
        let mut approx_norms = Vec::new();
        let mut exact_reqs = Vec::new();
        let mut exact_norms = Vec::new();
        let mut mismatched = 0usize;
        for req in requests {
            if req.features.len() != want_dim {
                mismatched += 1;
                let got = req.features.len();
                req.fail(PredictErrorKind::DimMismatch {
                    got,
                    want: want_dim,
                });
                continue;
            }
            let (route, zn, _) = router.route(&req.features);
            match route {
                Route::Approx => {
                    approx_reqs.push(req);
                    approx_norms.push(zn);
                }
                Route::Exact => {
                    exact_reqs.push(req);
                    exact_norms.push(zn);
                }
            }
        }
        if mismatched > 0 {
            metrics.record_dropped(&model, mismatched);
            log_warn!(
                "executor: failed {mismatched} request(s) for '{model}' \
                 (dim != {want_dim})"
            );
        }
        for (route, reqs, routed_norms) in [
            (Route::Approx, approx_reqs, approx_norms),
            (Route::Exact, exact_reqs, exact_norms),
        ] {
            if reqs.is_empty() {
                continue;
            }
            let z = batch_matrix(&reqs);
            let out = match execute(&exec, tenant, route, &z) {
                Ok(out) => out,
                Err(e) => {
                    // A per-batch failure (shape drift across a swap,
                    // artifact gaps on the XLA path) must not take the
                    // executor down for every other tenant — but the
                    // callers hear about it immediately.
                    metrics.record_dropped(&model, reqs.len());
                    log_warn!(
                        "executor: failing {} request(s) for '{model}' \
                         ({route:?}): {e}",
                        reqs.len()
                    );
                    let detail = e.to_string();
                    for req in reqs {
                        req.fail(PredictErrorKind::Exec {
                            detail: detail.clone(),
                        });
                    }
                    continue;
                }
            };
            // Recorded only after a successful execute so served counts
            // and throughput never include failed work.
            metrics.record_batch(&model, route, reqs.len(), tenant.substrate);
            let norms = out.znorms_sq.unwrap_or(routed_norms);
            for (i, req) in reqs.into_iter().enumerate() {
                let in_bound = norms[i] < budget;
                let latency = req.enqueued_at.elapsed();
                metrics.record_response(&model, latency, in_bound);
                let resp = PredictResponse {
                    id: req.id,
                    model: req.model,
                    generation,
                    decision: out.decisions[i],
                    label: if out.decisions[i] >= 0.0 { 1.0 } else { -1.0 },
                    route,
                    znorm_sq: norms[i],
                    in_bound,
                    latency,
                };
                // A send failure only means this client/session went
                // away; other requests in the batch still complete.
                let _ = req.reply.send(Ok(resp));
            }
        }
    }
    Ok(())
}

/// Fetch (and, when due, revalidate) the tenant state for `model`,
/// or a human-readable reason it cannot be resolved.
/// Resident tenants are LRU-bounded by `params.max_resident` (tenants
/// with a higher `max_resident_hint` are evicted last): evicted ones
/// reload through the store (which has its own bounded cache) on their
/// next batch, so executor memory tracks the hot set, not every id
/// ever served.
fn resolve<'t>(
    tenants: &'t mut HashMap<ModelId, Tenant>,
    store: Option<&ModelStore>,
    prefetcher: Option<&Prefetcher>,
    model: &ModelId,
    params: &WorkerParams,
    now_epoch: u64,
    tick: u64,
) -> std::result::Result<&'t mut Tenant, String> {
    if !tenants.contains_key(model) {
        let Some(store) = store else {
            return Err(format!(
                "'{model}' is not served by this coordinator"
            ));
        };
        match store.load(model) {
            Ok(entry) => {
                if tenants.len() >= params.max_resident.max(1) {
                    if let Some(victim) = tenants
                        .iter()
                        .min_by_key(|(_, t)| {
                            (t.policy().max_resident_hint, t.last_used)
                        })
                        .map(|(k, _)| k.clone())
                    {
                        tenants.remove(&victim);
                        // Keep the shared policy table bounded by the
                        // resident set; a reload re-registers it.
                        params.policies.remove(&victim);
                        // Drop any in-flight prefetch result too: an
                        // evicted tenant may never see another batch,
                        // and resolve() is the only consumer of the
                        // ready map — without this the decoded entry
                        // would be pinned for the worker's lifetime.
                        if let Some(pf) = prefetcher {
                            let _ = pf.take(&victim);
                        }
                    }
                }
                params.policies.set(
                    model.clone(),
                    entry.policy.unwrap_or_default(),
                );
                tenants.insert(
                    model.clone(),
                    Tenant::new(entry, now_epoch, params.quant_drift_tol),
                );
            }
            Err(e) => {
                log_warn!("executor: cannot load '{model}': {e}");
                return Err(e.to_string());
            }
        }
    }
    // Resident by construction (inserted above when absent); the typed
    // error keeps this path panic-free if that invariant ever breaks.
    let Some(tenant) = tenants.get_mut(model) else {
        return Err(format!("tenant '{model}' not resident after load"));
    };
    tenant.last_used = tick;
    if let Some(store) = store {
        // A completed prefetch swaps in first — atomic from the request
        // path's point of view: one Arc exchange between batches, no
        // decode on this thread.
        if let Some(pf) = prefetcher {
            if let Some(entry) = pf.take(model) {
                // Swap only if the parked decode still matches what is
                // on disk (a 32-byte header peek, paid only when a
                // prefetch actually completed). This discards results
                // staled by an explicit refresh() that already loaded a
                // newer generation, AND parked pre-remove entries that
                // would otherwise roll the tenant back after a
                // non-monotone out-of-band remove()+republish.
                let current = store.peek(model).ok();
                let disk_gen = current.as_ref().map(|i| i.generation);
                if disk_gen == Some(entry.generation)
                    && entry.generation != tenant.entry.generation
                {
                    if entry.dim() == tenant.entry.dim() {
                        params.policies.set(
                            model.clone(),
                            entry.policy.unwrap_or_default(),
                        );
                        tenant.swap(entry, params.quant_drift_tol);
                    } else {
                        log_warn!(
                            "executor: discarding prefetched '{model}' \
                             generation {} (dim {} vs serving dim {})",
                            entry.generation,
                            entry.dim(),
                            tenant.entry.dim()
                        );
                    }
                }
            }
        }
        let epoch_due = tenant.epoch_seen != now_epoch;
        let poll_due = tenant.last_check.elapsed() >= params.swap_poll;
        if epoch_due || poll_due {
            tenant.epoch_seen = now_epoch;
            tenant.last_check = Instant::now();
            // Header-only peek (~32 bytes of I/O) so the steady-state
            // poll never re-decodes an unchanged bundle; the full load
            // happens only when the generation actually moved.
            match store.peek(model) {
                Ok(info) if info.generation != tenant.entry.generation => {
                    if info.dim != tenant.entry.dim() {
                        // Submit-side dim checks may be cached in other
                        // processes; never swap across a dim change
                        // (publish() refuses it in-process, but an
                        // out-of-band remove()+republish can do this).
                        log_warn!(
                            "executor: refusing to hot-swap '{model}' to \
                             generation {} with dim {} (serving dim {}); \
                             keeping generation {}",
                            info.generation,
                            info.dim,
                            tenant.entry.dim(),
                            tenant.entry.generation
                        );
                    } else if let (false, true, Some(pf)) = (
                        epoch_due,
                        info.generation > tenant.entry.generation,
                        prefetcher,
                    ) {
                        // Steady-state detection of a newer generation:
                        // decode off the hot path; the swap lands on a
                        // later batch. A duplicate request (swap-poll
                        // re-fires before the decode finishes) is a
                        // cheap cache hit.
                        pf.request(model);
                    } else {
                        // Explicit refresh() — the caller asked for the
                        // new generation now — or a non-monotone
                        // generation (out-of-band remove + republish
                        // restarts at 1): reload synchronously so the
                        // very next batch serves it.
                        match store.load(model) {
                            Ok(entry) => {
                                params.policies.set(
                                    model.clone(),
                                    entry.policy.unwrap_or_default(),
                                );
                                tenant.swap(entry, params.quant_drift_tol);
                            }
                            Err(e) => log_warn!(
                                "executor: keeping '{model}' generation {} \
                                 (reload failed: {e})",
                                tenant.entry.generation
                            ),
                        }
                    }
                }
                Ok(_) => {}
                Err(e) => log_warn!(
                    "executor: keeping '{model}' generation {} \
                     (revalidation failed: {e})",
                    tenant.entry.generation
                ),
            }
        }
    }
    Ok(tenant)
}

/// Execute one routed sub-batch through the [`Predictor`] trait on the
/// selected substrate. Quantized tenants are evaluated directly on
/// their native f16/int8 storage — nothing f32-sized is materialized
/// on the request path.
fn execute(
    exec: &Exec,
    tenant: &mut Tenant,
    route: Route,
    z: &Mat,
) -> Result<PredictOutput> {
    match exec {
        Exec::Native(backend) => {
            match (&tenant.entry.models, route) {
                (TenantModels::F32 { approx, .. }, Route::Approx) => {
                    ApproxPredictor::new(approx, *backend)?.predict_batch(z)
                }
                (TenantModels::F32 { exact, .. }, Route::Exact) => {
                    // Norms are cached per generation on the tenant; the
                    // clone is an O(n_SV) memcpy, noise next to the
                    // O(batch·n_SV·d) evaluation.
                    ExactPredictor::with_norms(
                        exact,
                        tenant.sv_norms.clone(),
                        *backend,
                    )?
                    .predict_batch(z)
                }
                (
                    TenantModels::Quantized { approx, .. },
                    Route::Approx,
                ) => QuantApproxPredictor::new(approx).predict_batch(z),
                (TenantModels::Quantized { exact, .. }, Route::Exact) => {
                    QuantExactPredictor::with_norms(
                        exact,
                        tenant.sv_norms.clone(),
                    )?
                    .predict_batch(z)
                }
                // The rff substrate rides the Approx route (its stored
                // error estimate gated the budget); the Maclaurin twin
                // in the bundle is tooling-only and never serves.
                (TenantModels::Rff { rff, .. }, Route::Approx) => {
                    RffPredictor::new(rff).predict_batch(z)
                }
                (TenantModels::Rff { exact, .. }, Route::Exact) => {
                    ExactPredictor::with_norms(
                        exact,
                        tenant.sv_norms.clone(),
                        *backend,
                    )?
                    .predict_batch(z)
                }
            }
        }
        #[cfg(feature = "pjrt")]
        Exec::Xla(engine) => {
            if tenant.prepared.is_none() {
                // The engine uploads f32 device buffers, so a quantized
                // tenant dequantizes transiently at prepare time (once
                // per generation; the temps drop after upload).
                let prepared = match &tenant.entry.models {
                    TenantModels::F32 { exact, approx } => PreparedPair {
                        approx: engine.prepare_approx(approx)?,
                        exact: engine.prepare_exact(exact)?,
                    },
                    TenantModels::Quantized { exact, approx } => {
                        let a = approx.dequantize();
                        let e = exact.dequantize();
                        PreparedPair {
                            approx: engine.prepare_approx(&a)?,
                            exact: engine.prepare_exact(&e)?,
                        }
                    }
                    // No AOT artifact computes cos(Wx+b) features, and
                    // silently substituting the Maclaurin twin would
                    // serve outside the budget the rff estimate gated.
                    TenantModels::Rff { .. } => {
                        return Err(crate::Error::InvalidArg(
                            "rff tenants have no AOT artifacts; serve \
                             them on a native backend"
                                .into(),
                        ));
                    }
                };
                tenant.prepared = Some(prepared);
            }
            // Populated just above when absent; typed error instead of
            // a panic path if the invariant ever breaks.
            let Some(prep) = tenant.prepared.as_ref() else {
                return Err(crate::Error::Other(
                    "engine buffers missing after prepare".into(),
                ));
            };
            match route {
                Route::Approx => {
                    crate::runtime::EngineApproxPredictor::new(
                        engine,
                        &prep.approx,
                    )
                    .predict_batch(z)
                }
                Route::Exact => crate::runtime::EngineExactPredictor::new(
                    engine,
                    &prep.exact,
                )
                .predict_batch(z),
            }
        }
    }
}

fn batch_matrix(requests: &[PredictRequest]) -> Mat {
    let d = requests[0].features.len();
    let mut z = Mat::zeros(requests.len(), d);
    for (r, req) in requests.iter().enumerate() {
        z.row_mut(r).copy_from_slice(&req.features);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, features: Vec<f32>) -> PredictRequest {
        let (reply, _rx) = std::sync::mpsc::channel();
        PredictRequest {
            id,
            model: default_model_id(),
            features,
            enqueued_at: Instant::now(),
            reply,
        }
    }

    #[test]
    fn batch_matrix_layout() {
        let reqs =
            vec![req(1, vec![1.0, 2.0]), req(2, vec![3.0, 4.0])];
        let m = batch_matrix(&reqs);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }
}
