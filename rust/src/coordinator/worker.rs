//! Executor thread: owns the predictors (native Rust backends or the
//! PJRT engine — the engine is `!Send`, so it is constructed *inside*
//! the thread), resolves per-model state through the registry, routes
//! each batch with that model's Eq. 3.11 budget, and turns routed
//! sub-batches into responses.
//!
//! Hot-swap protocol: for registry-backed coordinators the worker
//! revalidates a model's on-disk generation when the coordinator's
//! refresh epoch ticks, or at most every `swap_poll` otherwise (a
//! 32-byte header read). A republished bundle swaps the resident
//! `Arc<ModelEntry>` between batches; requests already in flight finish
//! on whichever generation they resolved — nothing errors, nothing is
//! dropped. If a reload fails, the worker keeps serving the generation
//! it has (availability beats freshness for a serving node).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::linalg::{Mat, MathBackend};
use crate::log_warn;
use crate::registry::{ModelEntry, ModelStore};
use crate::svm::predict::ExactPredictor;
use crate::svm::SvmModel;
use crate::Result;

use super::metrics::Metrics;
use super::request::{
    default_model_id, ModelId, PredictRequest, PredictResponse, Route,
    WorkItem,
};
use super::router::{RoutePolicy, Router};

/// Which execution substrate the worker uses.
#[derive(Clone, Debug)]
pub enum ExecSpec {
    /// Pure-Rust predictors with the given math backend.
    Native(MathBackend),
    /// PJRT engine over AOT artifacts (`make artifacts`). Requires the
    /// `pjrt` feature (and a real `xla` crate underneath it).
    #[cfg(feature = "pjrt")]
    Xla { artifacts_dir: std::path::PathBuf },
}

/// Where the worker gets model state from.
pub(crate) enum ModelSource {
    /// One fixed (exact, approx) pair under [`super::request::DEFAULT_MODEL`].
    Static { exact: SvmModel, approx: ApproxModel },
    /// Lazy per-id resolution through a shared registry.
    Registry { store: Arc<ModelStore> },
}

#[cfg(feature = "pjrt")]
struct PreparedPair {
    approx: crate::runtime::PreparedApprox,
    exact: crate::runtime::PreparedExact,
}

/// Tuning knobs forwarded from [`super::server::CoordinatorConfig`].
pub(crate) struct WorkerParams {
    pub policy: RoutePolicy,
    pub swap_poll: Duration,
    /// LRU bound on fully resident tenants in this executor.
    pub max_resident: usize,
}

/// Per-model serving state resident in the executor.
struct Tenant {
    entry: Arc<ModelEntry>,
    /// SV norms of the exact model, cached per generation so the
    /// native exact path skips the O(n_SV·d) precompute per batch.
    sv_norms: Vec<f32>,
    /// Refresh epoch this tenant last revalidated against.
    epoch_seen: u64,
    last_check: Instant,
    /// Monotone use counter for LRU eviction.
    last_used: u64,
    /// Lazily (re)built per generation on the XLA path.
    #[cfg(feature = "pjrt")]
    prepared: Option<PreparedPair>,
}

impl Tenant {
    fn new(entry: Arc<ModelEntry>, epoch: u64) -> Tenant {
        let sv_norms = entry.exact.sv.row_norms_sq();
        Tenant {
            entry,
            sv_norms,
            epoch_seen: epoch,
            last_check: Instant::now(),
            last_used: 0,
            #[cfg(feature = "pjrt")]
            prepared: None,
        }
    }

    fn swap(&mut self, entry: Arc<ModelEntry>) {
        self.sv_norms = entry.exact.sv.row_norms_sq();
        self.entry = entry;
        #[cfg(feature = "pjrt")]
        {
            self.prepared = None;
        }
    }
}

enum Exec {
    Native(MathBackend),
    #[cfg(feature = "pjrt")]
    Xla(crate::runtime::Engine),
}

/// Run the executor loop until a `Shutdown` item arrives.
/// Called on a dedicated thread by [`super::server::Coordinator`].
pub(crate) fn run_worker(
    spec: ExecSpec,
    source: ModelSource,
    params: WorkerParams,
    epoch: Arc<AtomicU64>,
    work_rx: Receiver<WorkItem>,
    resp_tx: Sender<PredictResponse>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    // The XLA engine must be created on this thread (PJRT handles are
    // not Send).
    let exec = match spec {
        ExecSpec::Native(backend) => Exec::Native(backend),
        #[cfg(feature = "pjrt")]
        ExecSpec::Xla { artifacts_dir } => {
            Exec::Xla(crate::runtime::Engine::load(&artifacts_dir)?)
        }
    };
    let mut tenants: HashMap<ModelId, Tenant> = HashMap::new();
    let store = match source {
        ModelSource::Static { exact, approx } => {
            let id = default_model_id();
            let entry = Arc::new(ModelEntry {
                id: id.clone(),
                generation: 0,
                exact,
                approx,
            });
            tenants.insert(
                id,
                Tenant::new(entry, epoch.load(Ordering::Acquire)),
            );
            None
        }
        ModelSource::Registry { store } => Some(store),
    };

    let mut tick: u64 = 0;
    while let Ok(item) = work_rx.recv() {
        let (model, requests) = match item {
            WorkItem::Shutdown => break,
            WorkItem::Batch { model, requests } => (model, requests),
        };
        if requests.is_empty() {
            continue;
        }
        let now_epoch = epoch.load(Ordering::Acquire);
        tick += 1;
        let Some(tenant) = resolve(
            &mut tenants,
            store.as_deref(),
            &model,
            &params,
            now_epoch,
            tick,
        ) else {
            // Unresolvable model (deleted between submit and execution):
            // drop the batch with a warning rather than killing every
            // other tenant on this executor.
            metrics.record_dropped(&model, requests.len());
            log_warn!(
                "executor: dropping {} request(s) for unresolvable model \
                 '{model}'",
                requests.len()
            );
            continue;
        };
        let generation = tenant.entry.generation;
        let budget = tenant.entry.approx.znorm_sq_budget();
        let router = Router { policy: params.policy, znorm_sq_budget: budget };
        // Routing already computes each ‖z‖²; keep it alongside the
        // request so no path pays a second O(batch·d) norm pass.
        let mut approx_reqs = Vec::new();
        let mut approx_norms = Vec::new();
        let mut exact_reqs = Vec::new();
        let mut exact_norms = Vec::new();
        for req in requests {
            let (route, zn, _) = router.route(&req.features);
            match route {
                Route::Approx => {
                    approx_reqs.push(req);
                    approx_norms.push(zn);
                }
                Route::Exact => {
                    exact_reqs.push(req);
                    exact_norms.push(zn);
                }
            }
        }
        for (route, reqs, routed_norms) in [
            (Route::Approx, approx_reqs, approx_norms),
            (Route::Exact, exact_reqs, exact_norms),
        ] {
            if reqs.is_empty() {
                continue;
            }
            let z = batch_matrix(&reqs);
            let (decisions, norms) = match execute(&exec, tenant, route, &z) {
                Ok(out) => out,
                Err(e) => {
                    // A per-batch failure (shape drift across a swap,
                    // artifact gaps on the XLA path) must not take the
                    // executor down for every other tenant.
                    metrics.record_dropped(&model, reqs.len());
                    log_warn!(
                        "executor: dropping {} request(s) for '{model}' \
                         ({route:?}): {e}",
                        reqs.len()
                    );
                    continue;
                }
            };
            // Recorded only after a successful execute so served counts
            // and throughput never include dropped work.
            metrics.record_batch(&model, route, reqs.len());
            let norms = norms.unwrap_or(routed_norms);
            for (i, req) in reqs.into_iter().enumerate() {
                let in_bound = norms[i] < budget;
                let latency = req.enqueued_at.elapsed();
                metrics.record_response(&model, latency, in_bound);
                let resp = PredictResponse {
                    id: req.id,
                    model: req.model,
                    generation,
                    decision: decisions[i],
                    label: if decisions[i] >= 0.0 { 1.0 } else { -1.0 },
                    route,
                    znorm_sq: norms[i],
                    in_bound,
                    latency,
                };
                if resp_tx.send(resp).is_err() {
                    // Receiver dropped: coordinator is shutting down.
                    return Ok(());
                }
            }
        }
    }
    Ok(())
}

/// Fetch (and, when due, revalidate) the tenant state for `model`.
/// Resident tenants are LRU-bounded by `params.max_resident`: evicted
/// ones reload through the store (which has its own bounded cache) on
/// their next batch, so executor memory tracks the hot set, not every
/// id ever served.
fn resolve<'t>(
    tenants: &'t mut HashMap<ModelId, Tenant>,
    store: Option<&ModelStore>,
    model: &ModelId,
    params: &WorkerParams,
    now_epoch: u64,
    tick: u64,
) -> Option<&'t mut Tenant> {
    if !tenants.contains_key(model) {
        let store = store?;
        match store.load(model) {
            Ok(entry) => {
                if tenants.len() >= params.max_resident.max(1) {
                    if let Some(victim) = tenants
                        .iter()
                        .min_by_key(|(_, t)| t.last_used)
                        .map(|(k, _)| k.clone())
                    {
                        tenants.remove(&victim);
                    }
                }
                tenants.insert(model.clone(), Tenant::new(entry, now_epoch));
            }
            Err(e) => {
                log_warn!("executor: cannot load '{model}': {e}");
                return None;
            }
        }
    }
    let tenant = tenants.get_mut(model).expect("resident by construction");
    tenant.last_used = tick;
    if let Some(store) = store {
        let due = tenant.epoch_seen != now_epoch
            || tenant.last_check.elapsed() >= params.swap_poll;
        if due {
            tenant.epoch_seen = now_epoch;
            tenant.last_check = Instant::now();
            // Header-only peek (~32 bytes of I/O) so the steady-state
            // poll never re-decodes an unchanged bundle; the full load
            // happens only when the generation actually moved.
            match store.peek(model) {
                Ok(info) if info.generation != tenant.entry.generation => {
                    if info.dim != tenant.entry.dim() {
                        // Submit-side dim checks may be cached in other
                        // processes; never swap across a dim change
                        // (publish() refuses it in-process, but an
                        // out-of-band remove()+republish can do this).
                        log_warn!(
                            "executor: refusing to hot-swap '{model}' to \
                             generation {} with dim {} (serving dim {}); \
                             keeping generation {}",
                            info.generation,
                            info.dim,
                            tenant.entry.dim(),
                            tenant.entry.generation
                        );
                    } else {
                        match store.load(model) {
                            Ok(entry) => tenant.swap(entry),
                            Err(e) => log_warn!(
                                "executor: keeping '{model}' generation {} \
                                 (reload failed: {e})",
                                tenant.entry.generation
                            ),
                        }
                    }
                }
                Ok(_) => {}
                Err(e) => log_warn!(
                    "executor: keeping '{model}' generation {} \
                     (revalidation failed: {e})",
                    tenant.entry.generation
                ),
            }
        }
    }
    Some(tenant)
}

/// Execute one routed sub-batch on the selected substrate.
fn execute(
    exec: &Exec,
    tenant: &mut Tenant,
    route: Route,
    z: &Mat,
) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
    match exec {
        Exec::Native(backend) => match route {
            Route::Approx => tenant
                .entry
                .approx
                .decision_batch(z, *backend)
                .map(|(d, n)| (d, Some(n))),
            Route::Exact => {
                // Norms are cached per generation on the tenant; the
                // clone is an O(n_SV) memcpy, noise next to the
                // O(batch·n_SV·d) evaluation.
                let pred = ExactPredictor::with_norms(
                    &tenant.entry.exact,
                    tenant.sv_norms.clone(),
                    *backend,
                )?;
                pred.decision_batch(z).map(|d| (d, None))
            }
        },
        #[cfg(feature = "pjrt")]
        Exec::Xla(engine) => {
            if tenant.prepared.is_none() {
                tenant.prepared = Some(PreparedPair {
                    approx: engine.prepare_approx(&tenant.entry.approx)?,
                    exact: engine.prepare_exact(&tenant.entry.exact)?,
                });
            }
            let prep = tenant.prepared.as_ref().unwrap();
            match route {
                Route::Approx => engine
                    .approx_predict(&prep.approx, z)
                    .map(|(d, n)| (d, Some(n))),
                Route::Exact => {
                    engine.exact_predict(&prep.exact, z).map(|d| (d, None))
                }
            }
        }
    }
}

fn batch_matrix(requests: &[PredictRequest]) -> Mat {
    let d = requests[0].features.len();
    let mut z = Mat::zeros(requests.len(), d);
    for (r, req) in requests.iter().enumerate() {
        z.row_mut(r).copy_from_slice(&req.features);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn batch_matrix_layout() {
        let reqs = vec![
            PredictRequest {
                id: 1,
                model: default_model_id(),
                features: vec![1.0, 2.0],
                enqueued_at: Instant::now(),
            },
            PredictRequest {
                id: 2,
                model: default_model_id(),
                features: vec![3.0, 4.0],
                enqueued_at: Instant::now(),
            },
        ];
        let m = batch_matrix(&reqs);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }
}
