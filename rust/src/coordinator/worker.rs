//! Executor thread: owns the predictors (native Rust backends or the
//! PJRT engine — the engine is `!Send`, so it is constructed *inside*
//! the thread) and turns routed batches into responses.

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::approx::ApproxModel;
use crate::linalg::{vecops, Mat, MathBackend};
use crate::svm::predict::ExactPredictor;
use crate::svm::SvmModel;
use crate::Result;

use super::metrics::Metrics;
use super::request::{PredictRequest, PredictResponse, Route, WorkItem};

/// Which execution substrate the worker uses.
#[derive(Clone, Debug)]
pub enum ExecSpec {
    /// Pure-Rust predictors with the given math backend.
    Native(MathBackend),
    /// PJRT engine over AOT artifacts (`make artifacts`).
    Xla { artifacts_dir: PathBuf },
}

/// Run the executor loop until a `Shutdown` item arrives.
/// Called on a dedicated thread by [`super::server::Coordinator`].
pub(crate) fn run_worker(
    spec: ExecSpec,
    exact_model: SvmModel,
    approx_model: ApproxModel,
    work_rx: Receiver<WorkItem>,
    resp_tx: Sender<PredictResponse>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    let budget = approx_model.znorm_sq_budget();
    // Executor closures per route. The XLA engine must be created on
    // this thread (PJRT handles are not Send).
    match spec {
        ExecSpec::Native(backend) => {
            let exact_pred = ExactPredictor::new(&exact_model, backend)?;
            serve_loop(
                work_rx,
                resp_tx,
                metrics,
                budget,
                |z| approx_model.decision_batch(z, backend).map(|(d, n)| (d, Some(n))),
                |z| exact_pred.decision_batch(z),
            )
        }
        ExecSpec::Xla { artifacts_dir } => {
            let engine = crate::runtime::Engine::load(&artifacts_dir)?;
            let prep_a = engine.prepare_approx(&approx_model)?;
            let prep_e = engine.prepare_exact(&exact_model)?;
            serve_loop(
                work_rx,
                resp_tx,
                metrics,
                budget,
                |z| engine.approx_predict(&prep_a, z).map(|(d, n)| (d, Some(n))),
                |z| engine.exact_predict(&prep_e, z),
            )
        }
    }
}

fn serve_loop<FA, FE>(
    work_rx: Receiver<WorkItem>,
    resp_tx: Sender<PredictResponse>,
    metrics: Arc<Metrics>,
    znorm_sq_budget: f32,
    approx_fn: FA,
    exact_fn: FE,
) -> Result<()>
where
    FA: Fn(&Mat) -> Result<(Vec<f32>, Option<Vec<f32>>)>,
    FE: Fn(&Mat) -> Result<Vec<f32>>,
{
    while let Ok(item) = work_rx.recv() {
        let (route, requests) = match item {
            WorkItem::Shutdown => break,
            WorkItem::Batch { route, requests } => (route, requests),
        };
        if requests.is_empty() {
            continue;
        }
        metrics.record_batch(route, requests.len());
        let z = batch_matrix(&requests);
        let (decisions, norms) = match route {
            Route::Approx => {
                let (d, n) = approx_fn(&z)?;
                (d, n)
            }
            Route::Exact => (exact_fn(&z)?, None),
        };
        let norms = norms.unwrap_or_else(|| {
            (0..z.rows()).map(|r| vecops::norm_sq(z.row(r))).collect()
        });
        for (i, req) in requests.into_iter().enumerate() {
            let in_bound = norms[i] < znorm_sq_budget;
            let latency = req.enqueued_at.elapsed();
            metrics.record_response(latency, in_bound);
            let resp = PredictResponse {
                id: req.id,
                decision: decisions[i],
                label: if decisions[i] >= 0.0 { 1.0 } else { -1.0 },
                route,
                znorm_sq: norms[i],
                in_bound,
                latency,
            };
            if resp_tx.send(resp).is_err() {
                // Receiver dropped: coordinator is shutting down.
                return Ok(());
            }
        }
    }
    Ok(())
}

fn batch_matrix(requests: &[PredictRequest]) -> Mat {
    let d = requests[0].features.len();
    let mut z = Mat::zeros(requests.len(), d);
    for (r, req) in requests.iter().enumerate() {
        z.row_mut(r).copy_from_slice(&req.features);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn batch_matrix_layout() {
        let reqs = vec![
            PredictRequest {
                id: 1,
                features: vec![1.0, 2.0],
                enqueued_at: Instant::now(),
            },
            PredictRequest {
                id: 2,
                features: vec![3.0, 4.0],
                enqueued_at: Instant::now(),
            },
        ];
        let m = batch_matrix(&reqs);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }
}
