//! L3 coordinator: the serving system around the paper's approximation.
//!
//! Architecture (vLLM-router-like, std-only threads):
//!
//! ```text
//!  submit_to(id) ──▶ bounded ingress queue ──▶ batcher thread
//!                                                │ (dynamic batching:
//!                                                │  max_batch / max_wait;
//!                                                │  groups by model id)
//!                                                ▼
//!                                executor thread (owns the predictors —
//!                                native Loops/Blocked or the PJRT
//!                                engine — resolves per-model state via
//!                                the registry, applies each model's
//!                                Eq. 3.11 budget, splits approx/exact)
//!                                                │
//!                                                ▼
//!                                 response channel ──▶ recv() / wait_all()
//! ```
//!
//! The router turns the paper's run-time validity check (§3.1: "this
//! bound can be verified during prediction at no extra cost") into an
//! operational guarantee: with [`RoutePolicy::Hybrid`], instances whose
//! ‖z‖² violates Eq. (3.11) are escorted to the exact model, so served
//! accuracy never silently degrades outside the approximation's
//! validity region.
//!
//! Multi-tenant serving: [`Coordinator::start_registry`] serves every
//! model published in a [`crate::registry::ModelStore`]. Requests carry
//! a model id, metrics are broken down per model, and republishing a
//! bundle hot-swaps the served version between batches without dropping
//! in-flight requests (see [`crate::registry`]).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use metrics::{Metrics, MetricsSnapshot, ModelMetricsSnapshot};
pub use request::{
    ModelId, PredictRequest, PredictResponse, Route, DEFAULT_MODEL,
};
pub use router::RoutePolicy;
pub use server::{Coordinator, CoordinatorConfig, ExecSpec};
