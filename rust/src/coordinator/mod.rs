//! L3 coordinator: the serving system around the paper's approximation.
//!
//! Architecture (vLLM-router-like, std-only threads):
//!
//! ```text
//!  Client/Session ──▶ bounded ingress queue ──▶ batcher thread
//!  (typed submit,                                │ (groups by model id;
//!   per-client                                   │  flushes each tenant on
//!   completion                                   │  ITS max_batch/max_wait —
//!   channels)                                    │  TenantPolicy or default)
//!                                                ▼
//!                                executor thread (drives every substrate
//!                                through the Predictor trait — native
//!                                Loops/Blocked or the PJRT engine —
//!                                resolves per-model state + policy via
//!                                the registry, applies each model's
//!                                Eq. 3.11 budget, splits approx/exact)
//!                                                │
//!                                                ▼
//!                          per-request Completion: Ok(PredictResponse)
//!                          or fail-fast Err(PredictError)
//! ```
//!
//! The router turns the paper's run-time validity check (§3.1: "this
//! bound can be verified during prediction at no extra cost") into an
//! operational guarantee: with [`RoutePolicy::Hybrid`], instances whose
//! ‖z‖² violates Eq. (3.11) are escorted to the exact model, so served
//! accuracy never silently degrades outside the approximation's
//! validity region.
//!
//! Multi-tenant serving: [`CoordinatorBuilder::start_registry`] serves
//! every model published in a [`crate::registry::ModelStore`]. Requests
//! carry a model id, metrics are broken down per model, each tenant can
//! carry its own [`TenantPolicy`] (route pin, batch shape, residency
//! hint) inside its `.arbf` bundle, and republishing a bundle hot-swaps
//! the served version — weights and policy — between batches without
//! dropping in-flight requests (see [`crate::registry`]).
//!
//! Error model: every submitted request is answered with exactly one
//! [`Completion`]. Executor-side failures (unknown model, dimension
//! drift across an out-of-band republish, a failing batch, shutdown)
//! are delivered as typed [`PredictError`]s on the submitting client's
//! channel — synchronous callers fail fast instead of waiting out a
//! timeout.

pub mod batcher;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use metrics::{Metrics, MetricsSnapshot, ModelMetricsSnapshot};
pub use policy::TenantPolicy;
pub use request::{
    Completion, ModelId, PredictError, PredictErrorKind, PredictRequest,
    PredictResponse, Route, DEFAULT_MODEL,
};
pub use router::RoutePolicy;
pub use server::{
    Client, Coordinator, CoordinatorBuilder, CoordinatorConfig, ExecSpec,
    Session,
};
