//! L3 coordinator: the serving system around the paper's approximation.
//!
//! Architecture (vLLM-router-like, std-only threads):
//!
//! ```text
//!  submit() ──▶ bounded ingress queue ──▶ batcher thread
//!                                           │  (dynamic batching:
//!                                           │   max_batch / max_wait)
//!                                           │  per-instance ‖z‖² +
//!                                           │  Eq. 3.11 bound check
//!                                           ▼
//!                             ┌─── approx batch ───┐ ┌── exact batch ──┐
//!                             ▼                    ▼ ▼                 ▼
//!                          executor thread (owns the predictors:
//!                          native Loops/Blocked or the PJRT engine)
//!                                           │
//!                                           ▼
//!                                response channel ──▶ recv() / wait_all()
//! ```
//!
//! The router turns the paper's run-time validity check (§3.1: "this
//! bound can be verified during prediction at no extra cost") into an
//! operational guarantee: with [`RoutePolicy::Hybrid`], instances whose
//! ‖z‖² violates Eq. (3.11) are escorted to the exact model, so served
//! accuracy never silently degrades outside the approximation's
//! validity region.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{PredictRequest, PredictResponse, Route};
pub use router::RoutePolicy;
pub use server::{Coordinator, CoordinatorConfig, ExecSpec};
