//! L3 coordinator: the sharded serving plane around the paper's
//! approximation.
//!
//! Architecture (vLLM-router-like, std-only threads):
//!
//! ```text
//!  Client/Session ──▶ rendezvous placement on model id (shard::assign)
//!  (typed submit,        │
//!   per-client           ├─▶ shard 0: ingress ─▶ batcher ─▶ executor
//!   completion           ├─▶ shard 1: ingress ─▶ batcher ─▶ executor
//!   channels)            └─▶ shard n: ingress ─▶ batcher ─▶ executor
//!                             (each lane: per-model grouping, tenant
//!                              max_batch/max_wait flush, resident-model
//!                              LRU, swap-poll + async generation
//!                              prefetch, its own metrics sink)
//!                                                │
//!                                                ▼
//!                          fan-in on the submitting client's channel:
//!                          per-request Completion — Ok(PredictResponse)
//!                          or fail-fast Err(PredictError)
//! ```
//!
//! Every executor drives every substrate through the
//! [`crate::predictor::Predictor`] trait (native Loops/Blocked or the
//! PJRT engine), resolves per-model state + [`TenantPolicy`] via the
//! registry, and applies each model's Eq. 3.11 budget. Because a model's
//! batches all land on its one owning shard, an `n`-shard plane returns
//! decisions identical to a single-shard one — sharding changes *where*
//! a tenant is served, never *what* it is served.
//!
//! The router turns the paper's run-time validity check (§3.1: "this
//! bound can be verified during prediction at no extra cost") into an
//! operational guarantee: with [`RoutePolicy::Hybrid`], instances whose
//! ‖z‖² violates Eq. (3.11) are escorted to the exact model, so served
//! accuracy never silently degrades outside the approximation's
//! validity region.
//!
//! Multi-tenant serving: [`CoordinatorBuilder::start_registry`] serves
//! every model published in a [`crate::registry::ModelStore`]. Requests
//! carry a model id, metrics are broken down per model (with the owning
//! shard), each tenant can carry its own [`TenantPolicy`] (route pin,
//! batch shape, residency hint) inside its `.arbf` bundle, and
//! republishing a bundle hot-swaps the served version — weights and
//! policy — on the owning shard without dropping in-flight requests;
//! the `.arbf` decode happens on a prefetch thread, off the request
//! path (see [`crate::registry`]).
//!
//! Network serving: [`crate::net`] fronts this same plane over TCP — a
//! shard server wraps one coordinator behind the `ARBW` wire protocol,
//! and a router places tenants over shard *processes* with the same
//! [`shard::assign`] rendezvous function, so a remote plane serves
//! decisions bit-identical to a local one. The in-process path stays
//! the default and is untouched by the network tier.
//!
//! Error model: every submitted request is answered with exactly one
//! [`Completion`]. Executor-side failures (unknown model, dimension
//! drift across an out-of-band republish, a failing batch, shutdown)
//! are delivered as typed [`PredictError`]s on the submitting client's
//! channel — synchronous callers fail fast instead of waiting out a
//! timeout.

#![forbid(unsafe_code)]

pub mod batcher;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod router;
pub mod server;
pub mod shard;
pub mod worker;

pub use metrics::{
    Metrics, MetricsSnapshot, MetricsState, ModelMetricsSnapshot,
    ModelMetricsState, ShardHealth, WelfordState,
};
pub use policy::TenantPolicy;
pub use request::{
    Completion, ModelId, PredictError, PredictErrorKind, PredictRequest,
    PredictResponse, Route, DEFAULT_MODEL,
};
pub use router::RoutePolicy;
pub use server::{
    Client, Coordinator, CoordinatorBuilder, CoordinatorConfig, ExecSpec,
    Session,
};
