//! Dynamic batcher: a bounded ingress queue drained by a batching loop
//! that groups requests per model and flushes each tenant's group on
//! *its* `max_batch` / `max_wait` (from the tenant's
//! [`super::policy::TenantPolicy`], falling back to the coordinator
//! defaults) — the standard latency/throughput knob of serving systems,
//! made per-tenant. Backpressure is a hard queue cap: `submit` blocks
//! until space frees (admission control rather than unbounded memory
//! growth).

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::policy::PolicyTable;
use super::request::{ModelId, PredictErrorKind, PredictRequest, WorkItem};
use crate::util::sync::{
    lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned,
};

/// Bounded MPMC ingress queue (Mutex + Condvar; std-only).
pub struct IngressQueue {
    q: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<PredictRequest>,
    closed: bool,
}

impl IngressQueue {
    pub fn new(capacity: usize) -> Self {
        IngressQueue {
            q: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking push (backpressure). Returns false if the queue closed.
    pub fn push(&self, req: PredictRequest) -> bool {
        let mut g = lock_unpoisoned(&self.q);
        while g.items.len() >= self.capacity && !g.closed {
            g = wait_unpoisoned(&self.not_full, g);
        }
        if g.closed {
            return false;
        }
        g.items.push_back(req);
        self.not_empty.notify_one();
        true
    }

    /// Pop up to `max` items, waiting up to `max_wait` for the *first*
    /// item and then collecting whatever arrived. Returns `None` when
    /// closed and drained.
    pub fn pop_batch(
        &self,
        max: usize,
        max_wait: Duration,
    ) -> Option<Vec<PredictRequest>> {
        let mut g = lock_unpoisoned(&self.q);
        let deadline = Instant::now() + max_wait;
        while g.items.is_empty() && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new()); // timed out: empty batch
            }
            let (guard, _) =
                wait_timeout_unpoisoned(&self.not_empty, g, deadline - now);
            g = guard;
        }
        if g.items.is_empty() && g.closed {
            return None;
        }
        // First item arrived; linger briefly to fill the batch (half the
        // remaining wait), then take up to `max`.
        let linger_deadline =
            (Instant::now() + max_wait / 2).min(deadline);
        while g.items.len() < max && !g.closed {
            let now = Instant::now();
            if now >= linger_deadline {
                break;
            }
            let (guard, timeout) = wait_timeout_unpoisoned(
                &self.not_empty,
                g,
                linger_deadline - now,
            );
            g = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = g.items.len().min(max);
        let batch: Vec<PredictRequest> = g.items.drain(..take).collect();
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: pushes fail, pops drain then return None.
    pub fn close(&self) {
        let mut g = lock_unpoisoned(&self.q);
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.q).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A tenant's requests waiting for their batch to fill.
struct PendingGroup {
    model: ModelId,
    reqs: Vec<PredictRequest>,
}

impl PendingGroup {
    /// Age of the oldest waiting request (drives the max_wait flush).
    fn oldest_age(&self) -> Duration {
        self.reqs
            .first()
            .map(|r| r.enqueued_at.elapsed())
            .unwrap_or(Duration::ZERO)
    }
}

/// The batcher loop: drain the ingress queue, group by model id, and
/// flush each group when it reaches the tenant's `max_batch` or its
/// oldest request has waited the tenant's `max_wait` — per-tenant
/// limits come from `policies` (populated by the executor from each
/// bundle's policy record), defaults from the coordinator config.
///
/// Runs on a dedicated thread until the ingress queue closes; then it
/// flushes everything pending and forwards `Shutdown`.
pub(crate) fn run_batcher(
    ingress: Arc<IngressQueue>,
    work_tx: Sender<WorkItem>,
    policies: Arc<PolicyTable>,
    default_max_batch: usize,
    default_max_wait: Duration,
) {
    let mut pending: Vec<PendingGroup> = Vec::new();
    loop {
        // Wake for whichever pending group's max_wait expires first
        // (or max_wait from idle, matching the pre-policy batcher).
        let wait = pending
            .iter()
            .map(|g| {
                policies
                    .get(&g.model)
                    .max_wait_or(default_max_wait)
                    .saturating_sub(g.oldest_age())
            })
            .min()
            .unwrap_or(default_max_wait)
            .min(default_max_wait);
        let popped = ingress.pop_batch(default_max_batch, wait);
        let closed = popped.is_none();
        if let Some(batch) = popped {
            for req in batch {
                match pending.iter_mut().find(|g| g.model == req.model) {
                    Some(g) => g.reqs.push(req),
                    None => pending.push(PendingGroup {
                        model: req.model.clone(),
                        reqs: vec![req],
                    }),
                }
            }
        }
        let mut executor_gone = false;
        let mut i = 0;
        'flush: while i < pending.len() {
            let policy = policies.get(&pending[i].model);
            let max_batch = policy.max_batch_or(default_max_batch);
            let max_wait = policy.max_wait_or(default_max_wait);
            // Flush full chunks, then the remainder once it has aged
            // out (or unconditionally on shutdown).
            while pending[i].reqs.len() >= max_batch
                || (!pending[i].reqs.is_empty()
                    && (closed || pending[i].oldest_age() >= max_wait))
            {
                let take = pending[i].reqs.len().min(max_batch);
                let chunk: Vec<PredictRequest> =
                    pending[i].reqs.drain(..take).collect();
                let item = WorkItem::Batch {
                    model: pending[i].model.clone(),
                    requests: chunk,
                };
                if work_tx.send(item).is_err() {
                    executor_gone = true;
                    break 'flush;
                }
            }
            if pending[i].reqs.is_empty() {
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if executor_gone {
            fail_everything(&ingress, pending);
            return;
        }
        if closed {
            let _ = work_tx.send(WorkItem::Shutdown);
            return;
        }
    }
}

/// The executor is gone (its work channel disconnected): close the
/// ingress so producers stop blocking on a queue nobody drains, and
/// fail every request still reachable — pending groups and anything
/// left in the queue — with a [`Shutdown`](PredictErrorKind::Shutdown)
/// completion so no caller hangs.
fn fail_everything(ingress: &IngressQueue, pending: Vec<PendingGroup>) {
    ingress.close();
    for group in pending {
        for req in group.reqs {
            req.fail(PredictErrorKind::Shutdown);
        }
    }
    while let Some(batch) = ingress.pop_batch(usize::MAX, Duration::ZERO) {
        for req in batch {
            req.fail(PredictErrorKind::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> PredictRequest {
        req_for(id, super::super::request::default_model_id())
    }

    fn req_for(id: u64, model: ModelId) -> PredictRequest {
        let (reply, _rx) = std::sync::mpsc::channel();
        PredictRequest {
            id,
            model,
            features: vec![0.0],
            enqueued_at: Instant::now(),
            reply,
        }
    }

    #[test]
    fn push_pop_roundtrip() {
        let q = IngressQueue::new(10);
        assert!(q.push(req(1)));
        assert!(q.push(req(2)));
        let batch = q.pop_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn batch_size_cap_respected() {
        let q = IngressQueue::new(100);
        for i in 0..10 {
            q.push(req(i));
        }
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = IngressQueue::new(4);
        let t0 = Instant::now();
        let batch = q.pop_batch(4, Duration::from_millis(20)).unwrap();
        assert!(batch.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn close_drains_then_none() {
        let q = IngressQueue::new(4);
        q.push(req(1));
        q.close();
        assert!(!q.push(req(2)), "push after close must fail");
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.pop_batch(4, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let q = Arc::new(IngressQueue::new(2));
        q.push(req(1));
        q.push(req(2));
        let q2 = q.clone();
        let handle = std::thread::spawn(move || {
            let t0 = Instant::now();
            assert!(q2.push(req(3))); // blocks until a pop frees space
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        let batch = q.pop_batch(1, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        let blocked_for = handle.join().unwrap();
        assert!(blocked_for >= Duration::from_millis(25), "{blocked_for:?}");
    }

    #[test]
    fn run_batcher_groups_by_model_and_respects_policy_max_batch() {
        use super::super::policy::TenantPolicy;
        let ingress = Arc::new(IngressQueue::new(64));
        let policies = Arc::new(PolicyTable::new());
        let small: ModelId = Arc::from("small-batches");
        policies.set(
            small.clone(),
            TenantPolicy { max_batch: Some(2), ..Default::default() },
        );
        let other: ModelId = Arc::from("default-batches");
        for i in 0..6 {
            ingress.push(req_for(i, small.clone()));
        }
        for i in 6..10 {
            ingress.push(req_for(i, other.clone()));
        }
        let (work_tx, work_rx) = std::sync::mpsc::channel();
        let b_ingress = ingress.clone();
        let b_policies = policies.clone();
        let handle = std::thread::spawn(move || {
            run_batcher(
                b_ingress,
                work_tx,
                b_policies,
                256,
                Duration::from_millis(5),
            )
        });
        ingress.close();
        let mut small_batches = Vec::new();
        let mut other_batches = Vec::new();
        loop {
            match work_rx.recv().unwrap() {
                WorkItem::Shutdown => break,
                WorkItem::Batch { model, requests } => {
                    assert!(
                        requests.iter().all(|r| r.model == model),
                        "mixed-model batch"
                    );
                    if model == small {
                        small_batches.push(requests.len());
                    } else {
                        other_batches.push(requests.len());
                    }
                }
            }
        }
        handle.join().unwrap();
        // The policy capped the small tenant at 2 per batch; the other
        // tenant flushed at the default (one batch of 4 on shutdown).
        assert_eq!(small_batches, vec![2, 2, 2]);
        assert_eq!(other_batches, vec![4]);
    }

    #[test]
    fn run_batcher_flushes_all_pending_on_close() {
        let ingress = Arc::new(IngressQueue::new(16));
        let policies = Arc::new(PolicyTable::new());
        for i in 0..3 {
            ingress.push(req(i));
        }
        let (work_tx, work_rx) = std::sync::mpsc::channel();
        let b = ingress.clone();
        let handle = std::thread::spawn(move || {
            run_batcher(b, work_tx, policies, 256, Duration::from_secs(5))
        });
        // Even with a huge max_wait, closing must flush what's pending.
        std::thread::sleep(Duration::from_millis(20));
        ingress.close();
        let mut total = 0;
        loop {
            match work_rx.recv().unwrap() {
                WorkItem::Shutdown => break,
                WorkItem::Batch { requests, .. } => total += requests.len(),
            }
        }
        handle.join().unwrap();
        assert_eq!(total, 3);
    }

    #[test]
    fn concurrent_producers_all_delivered() {
        let q = Arc::new(IngressQueue::new(64));
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    q.push(req(t * 100 + i));
                }
            }));
        }
        let mut got = 0;
        while got < 200 {
            got += q
                .pop_batch(32, Duration::from_millis(50))
                .unwrap()
                .len();
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got, 200);
    }
}
