//! Dynamic batcher: a bounded ingress queue drained by a batching loop
//! that flushes on `max_batch` or `max_wait`, whichever first — the
//! standard latency/throughput knob of serving systems. Backpressure is
//! a hard queue cap: `submit` blocks until space frees (admission
//! control rather than unbounded memory growth).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::PredictRequest;

/// Bounded MPMC ingress queue (Mutex + Condvar; std-only).
pub struct IngressQueue {
    q: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<PredictRequest>,
    closed: bool,
}

impl IngressQueue {
    pub fn new(capacity: usize) -> Self {
        IngressQueue {
            q: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking push (backpressure). Returns false if the queue closed.
    pub fn push(&self, req: PredictRequest) -> bool {
        let mut g = self.q.lock().unwrap();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.items.push_back(req);
        self.not_empty.notify_one();
        true
    }

    /// Pop up to `max` items, waiting up to `max_wait` for the *first*
    /// item and then collecting whatever arrived. Returns `None` when
    /// closed and drained.
    pub fn pop_batch(
        &self,
        max: usize,
        max_wait: Duration,
    ) -> Option<Vec<PredictRequest>> {
        let mut g = self.q.lock().unwrap();
        let deadline = Instant::now() + max_wait;
        while g.items.is_empty() && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new()); // timed out: empty batch
            }
            let (guard, _) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
        if g.items.is_empty() && g.closed {
            return None;
        }
        // First item arrived; linger briefly to fill the batch (half the
        // remaining wait), then take up to `max`.
        let linger_deadline =
            (Instant::now() + max_wait / 2).min(deadline);
        while g.items.len() < max && !g.closed {
            let now = Instant::now();
            if now >= linger_deadline {
                break;
            }
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(g, linger_deadline - now)
                .unwrap();
            g = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = g.items.len().min(max);
        let batch: Vec<PredictRequest> = g.items.drain(..take).collect();
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: pushes fail, pops drain then return None.
    pub fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> PredictRequest {
        PredictRequest {
            id,
            model: super::super::request::default_model_id(),
            features: vec![0.0],
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn push_pop_roundtrip() {
        let q = IngressQueue::new(10);
        assert!(q.push(req(1)));
        assert!(q.push(req(2)));
        let batch = q.pop_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn batch_size_cap_respected() {
        let q = IngressQueue::new(100);
        for i in 0..10 {
            q.push(req(i));
        }
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = IngressQueue::new(4);
        let t0 = Instant::now();
        let batch = q.pop_batch(4, Duration::from_millis(20)).unwrap();
        assert!(batch.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn close_drains_then_none() {
        let q = IngressQueue::new(4);
        q.push(req(1));
        q.close();
        assert!(!q.push(req(2)), "push after close must fail");
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.pop_batch(4, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let q = Arc::new(IngressQueue::new(2));
        q.push(req(1));
        q.push(req(2));
        let q2 = q.clone();
        let handle = std::thread::spawn(move || {
            let t0 = Instant::now();
            assert!(q2.push(req(3))); // blocks until a pop frees space
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        let batch = q.pop_batch(1, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        let blocked_for = handle.join().unwrap();
        assert!(blocked_for >= Duration::from_millis(25), "{blocked_for:?}");
    }

    #[test]
    fn concurrent_producers_all_delivered() {
        let q = Arc::new(IngressQueue::new(64));
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    q.push(req(t * 100 + i));
                }
            }));
        }
        let mut got = 0;
        while got < 200 {
            got += q
                .pop_batch(32, Duration::from_millis(50))
                .unwrap()
                .len();
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got, 200);
    }
}
