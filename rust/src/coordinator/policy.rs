//! Per-tenant serving policies.
//!
//! A [`TenantPolicy`] travels *with the model*: it is persisted as a
//! kind-3 record inside the tenant's `.arbf` bundle (see
//! `docs/FORMATS.md`), published via
//! [`crate::registry::ModelStore::publish_with`] (or `registry publish
//! --route …` on the CLI), resolved by the executor when it loads the
//! tenant, and applied by both the batcher (batch shape) and the router
//! (route choice). Republishing a bundle hot-swaps its policy exactly
//! like it hot-swaps its weights.
//!
//! Every field is optional: an unset field falls back to the
//! coordinator-wide default from
//! [`crate::coordinator::CoordinatorConfig`], so a bundle with no
//! policy record serves exactly as before.

use std::collections::HashMap;
use std::sync::RwLock;
use std::time::Duration;

use super::request::ModelId;
use super::router::RoutePolicy;
use crate::util::sync::{read_unpoisoned, write_unpoisoned};

/// Per-model serving knobs, persisted in the model's `.arbf` bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantPolicy {
    /// Route override (`None` → the coordinator's policy). E.g. a tenant
    /// that must never lose the exactness guarantee pins `AlwaysExact`.
    pub route: Option<RoutePolicy>,
    /// Max instances per executed batch for this tenant (`None` → the
    /// coordinator's `max_batch`).
    pub max_batch: Option<usize>,
    /// Max time this tenant's requests wait for a batch to fill
    /// (`None` → the coordinator's `max_wait`). Lower = lower latency,
    /// smaller batches.
    pub max_wait: Option<Duration>,
    /// Executor-residency priority hint: when the executor's resident
    /// set overflows `max_resident_models`, tenants with a *lower* hint
    /// are evicted first (ties broken least-recently-used). 0 = default.
    pub max_resident_hint: u32,
    /// Per-tenant quantization drift tolerance in decision units
    /// (`None` → the coordinator's `quant_drift_tol`). The executor
    /// *intersects* this with the plane-wide knob — `min(tenant,
    /// plane)` — so a margin-critical tenant can pin a tighter bound
    /// than its neighbors but never loosen the operator's floor.
    /// Must be finite and ≥ 0; a no-op for f32 payloads.
    pub quant_drift_tol: Option<f32>,
}

impl TenantPolicy {
    /// True iff every field is unset (serving behavior identical to a
    /// bundle with no policy record).
    pub fn is_default(&self) -> bool {
        *self == TenantPolicy::default()
    }

    pub fn route_or(&self, default: RoutePolicy) -> RoutePolicy {
        self.route.unwrap_or(default)
    }

    pub fn max_batch_or(&self, default: usize) -> usize {
        self.max_batch.unwrap_or(default).max(1)
    }

    pub fn max_wait_or(&self, default: Duration) -> Duration {
        self.max_wait.unwrap_or(default)
    }

    /// Effective drift tolerance: the tenant's pin intersected with the
    /// plane-wide default (`min` — a tenant tightens, never loosens).
    pub fn quant_drift_tol_or(&self, default: f32) -> f32 {
        match self.quant_drift_tol {
            Some(t) => t.min(default),
            None => default,
        }
    }
}

/// Shared policy registry: written by the executor (the component that
/// actually decodes bundles) when it loads or hot-swaps a tenant, read
/// by the batcher on every flush decision. Absent ids resolve to the
/// default policy, so the batcher never blocks on a tenant it has not
/// seen decoded state for yet — the first batch of a fresh tenant is
/// shaped by the coordinator-wide defaults, every later one by the
/// tenant's own policy.
#[derive(Debug, Default)]
pub(crate) struct PolicyTable {
    map: RwLock<HashMap<ModelId, TenantPolicy>>,
}

impl PolicyTable {
    pub(crate) fn new() -> PolicyTable {
        PolicyTable::default()
    }

    pub(crate) fn get(&self, model: &ModelId) -> TenantPolicy {
        read_unpoisoned(&self.map).get(model).copied().unwrap_or_default()
    }

    pub(crate) fn set(&self, model: ModelId, policy: TenantPolicy) {
        write_unpoisoned(&self.map).insert(model, policy);
    }

    /// Drop a tenant's entry (called when the executor evicts it, so
    /// the table stays bounded by the resident set — a reloaded tenant
    /// re-registers its policy on its next batch).
    pub(crate) fn remove(&self, model: &ModelId) {
        write_unpoisoned(&self.map).remove(model);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_fall_through() {
        let p = TenantPolicy::default();
        assert!(p.is_default());
        assert_eq!(p.route_or(RoutePolicy::Hybrid), RoutePolicy::Hybrid);
        assert_eq!(p.max_batch_or(256), 256);
        assert_eq!(p.max_wait_or(Duration::from_millis(2)), Duration::from_millis(2));
    }

    #[test]
    fn overrides_win() {
        let p = TenantPolicy {
            route: Some(RoutePolicy::AlwaysExact),
            max_batch: Some(8),
            max_wait: Some(Duration::from_micros(100)),
            max_resident_hint: 3,
            quant_drift_tol: Some(0.125),
        };
        assert!(!p.is_default());
        assert_eq!(p.route_or(RoutePolicy::Hybrid), RoutePolicy::AlwaysExact);
        assert_eq!(p.max_batch_or(256), 8);
        assert_eq!(p.max_wait_or(Duration::from_millis(2)), Duration::from_micros(100));
        assert_eq!(p.quant_drift_tol_or(0.25), 0.125);
    }

    #[test]
    fn drift_tol_intersects_never_loosens() {
        let unset = TenantPolicy::default();
        assert_eq!(unset.quant_drift_tol_or(0.25), 0.25);
        let loose = TenantPolicy {
            quant_drift_tol: Some(2.0),
            ..Default::default()
        };
        // A tenant cannot raise the plane-wide floor.
        assert_eq!(loose.quant_drift_tol_or(0.25), 0.25);
        let tight = TenantPolicy {
            quant_drift_tol: Some(0.0),
            ..Default::default()
        };
        assert_eq!(tight.quant_drift_tol_or(0.25), 0.0);
    }

    #[test]
    fn max_batch_floor_is_one() {
        let p = TenantPolicy { max_batch: Some(0), ..Default::default() };
        assert_eq!(p.max_batch_or(256), 1);
    }

    #[test]
    fn table_absent_is_default() {
        let t = PolicyTable::new();
        let id: ModelId = std::sync::Arc::from("ghost");
        assert!(t.get(&id).is_default());
        t.set(
            id.clone(),
            TenantPolicy { max_batch: Some(4), ..Default::default() },
        );
        assert_eq!(t.get(&id).max_batch, Some(4));
    }
}
