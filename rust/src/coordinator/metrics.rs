//! Serving metrics: per-route counters, latency distribution (log-scale
//! histogram + Welford moments), bound-violation counts, throughput —
//! globally and broken down per model id, so multi-tenant operators can
//! see each tenant's route mix and latency.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Welford;

use super::request::{ModelId, Route};

/// Log-scale latency histogram: bucket i covers [10^(i/4 - 7), …) s,
/// i.e. 100ns … ~100s in quarter-decade steps.
const BUCKETS: usize = 40;

#[derive(Debug)]
struct PerModel {
    served_approx: u64,
    served_exact: u64,
    out_of_bound: u64,
    dropped: u64,
    latency: Welford,
}

impl PerModel {
    fn new() -> Self {
        PerModel {
            served_approx: 0,
            served_exact: 0,
            out_of_bound: 0,
            dropped: 0,
            latency: Welford::new(),
        }
    }
}

#[derive(Debug)]
struct Inner {
    started: Option<Instant>,
    served_approx: u64,
    served_exact: u64,
    out_of_bound: u64,
    dropped: u64,
    batches: u64,
    batch_sizes: Welford,
    latency: Welford,
    histogram: [u64; BUCKETS],
    per_model: HashMap<ModelId, PerModel>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            started: None,
            served_approx: 0,
            served_exact: 0,
            out_of_bound: 0,
            dropped: 0,
            batches: 0,
            batch_sizes: Welford::new(),
            latency: Welford::new(),
            histogram: [0; BUCKETS],
            per_model: HashMap::new(),
        }
    }
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Per-model slice of a snapshot.
#[derive(Clone, Debug)]
pub struct ModelMetricsSnapshot {
    pub id: String,
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    /// Requests the executor could not serve (unresolvable model,
    /// dimension drift, per-batch execution failure). Each one was
    /// completed with a fail-fast `Err(PredictError)` on its client's
    /// channel; this counter is the operational aggregate.
    pub dropped: u64,
    pub mean_latency_s: f64,
}

impl ModelMetricsSnapshot {
    pub fn served_total(&self) -> u64 {
        self.served_approx + self.served_exact
    }

    /// Fraction of this model's traffic that took the O(d²) fast path.
    pub fn approx_fraction(&self) -> f64 {
        let total = self.served_total();
        if total == 0 {
            0.0
        } else {
            self.served_approx as f64 / total as f64
        }
    }
}

/// Point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    /// Requests failed fast with an `Err(PredictError)` completion
    /// (see [`ModelMetricsSnapshot::dropped`]) instead of being served.
    pub dropped: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub mean_latency_s: f64,
    pub p_latency_s: Vec<(f64, f64)>,
    pub throughput_rps: f64,
    /// Breakdown keyed by model id, sorted by id.
    pub per_model: Vec<ModelMetricsSnapshot>,
}

fn bucket_of(lat: Duration) -> usize {
    let s = lat.as_secs_f64().max(1e-9);
    let idx = (s.log10() + 7.0) * 4.0;
    (idx.max(0.0) as usize).min(BUCKETS - 1)
}

fn bucket_lo(i: usize) -> f64 {
    10f64.powf(i as f64 / 4.0 - 7.0)
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_batch(&self, model: &ModelId, route: Route, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(Instant::now);
        g.batches += 1;
        g.batch_sizes.push(n as f64);
        match route {
            Route::Approx => g.served_approx += n as u64,
            Route::Exact => g.served_exact += n as u64,
        }
        let pm = g
            .per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new);
        match route {
            Route::Approx => pm.served_approx += n as u64,
            Route::Exact => pm.served_exact += n as u64,
        }
    }

    /// Account for requests completed with a fail-fast error instead
    /// of a served prediction.
    pub fn record_dropped(&self, model: &ModelId, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.dropped += n as u64;
        g.per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new)
            .dropped += n as u64;
    }

    pub fn record_response(
        &self,
        model: &ModelId,
        latency: Duration,
        in_bound: bool,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.latency.push(latency.as_secs_f64());
        g.histogram[bucket_of(latency)] += 1;
        if !in_bound {
            g.out_of_bound += 1;
        }
        let pm = g
            .per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new);
        pm.latency.push(latency.as_secs_f64());
        if !in_bound {
            pm.out_of_bound += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = g
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let total = g.served_approx + g.served_exact;
        // Percentiles from the histogram (bucket lower edges).
        let mut p_latency = Vec::new();
        let served = g.latency.count();
        if served > 0 {
            for target in [50.0f64, 95.0, 99.0] {
                let want = (target / 100.0 * served as f64).ceil() as u64;
                let mut acc = 0u64;
                let mut val = bucket_lo(BUCKETS - 1);
                for (i, &h) in g.histogram.iter().enumerate() {
                    acc += h;
                    if acc >= want {
                        val = bucket_lo(i);
                        break;
                    }
                }
                p_latency.push((target, val));
            }
        }
        let mut per_model: Vec<ModelMetricsSnapshot> = g
            .per_model
            .iter()
            .map(|(id, pm)| ModelMetricsSnapshot {
                id: id.to_string(),
                served_approx: pm.served_approx,
                served_exact: pm.served_exact,
                out_of_bound: pm.out_of_bound,
                dropped: pm.dropped,
                mean_latency_s: pm.latency.mean(),
            })
            .collect();
        per_model.sort_by(|a, b| a.id.cmp(&b.id));
        MetricsSnapshot {
            served_approx: g.served_approx,
            served_exact: g.served_exact,
            out_of_bound: g.out_of_bound,
            dropped: g.dropped,
            batches: g.batches,
            mean_batch_size: g.batch_sizes.mean(),
            mean_latency_s: g.latency.mean(),
            p_latency_s: p_latency,
            throughput_rps: total as f64 / elapsed,
            per_model,
        }
    }
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let models: BTreeMap<String, Json> = self
            .per_model
            .iter()
            .map(|m| {
                (
                    m.id.clone(),
                    Json::obj(vec![
                        ("served_approx", Json::num(m.served_approx as f64)),
                        ("served_exact", Json::num(m.served_exact as f64)),
                        ("out_of_bound", Json::num(m.out_of_bound as f64)),
                        ("dropped", Json::num(m.dropped as f64)),
                        ("approx_fraction", Json::num(m.approx_fraction())),
                        ("mean_latency_s", Json::num(m.mean_latency_s)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("served_approx", Json::num(self.served_approx as f64)),
            ("served_exact", Json::num(self.served_exact as f64)),
            ("out_of_bound", Json::num(self.out_of_bound as f64)),
            ("dropped_requests", Json::num(self.dropped as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            (
                "latency_percentiles",
                Json::Arr(
                    self.p_latency_s
                        .iter()
                        .map(|&(p, v)| {
                            Json::obj(vec![
                                ("p", Json::num(p)),
                                ("seconds", Json::num(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("models", Json::Obj(models)),
        ])
    }

    /// Render the per-model breakdown as an aligned text table (used by
    /// the CLI, `serving_bench` and the multi-tenant example).
    pub fn per_model_table(&self) -> String {
        let mut out = String::from(
            "model                     served   approx    exact  oob drop \
             mean lat\n",
        );
        for m in &self.per_model {
            out.push_str(&format!(
                "{:<24} {:>7} {:>8} {:>8} {:>4} {:>4} {:>9.1} µs\n",
                m.id,
                m.served_total(),
                m.served_approx,
                m.served_exact,
                m.out_of_bound,
                m.dropped,
                m.mean_latency_s * 1e6
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mid(s: &str) -> ModelId {
        std::sync::Arc::from(s)
    }

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        let a = mid("default");
        m.record_batch(&a, Route::Approx, 10);
        m.record_batch(&a, Route::Exact, 3);
        m.record_response(&a, Duration::from_micros(50), true);
        m.record_response(&a, Duration::from_micros(150), false);
        m.record_dropped(&a, 4);
        let s = m.snapshot();
        assert_eq!(s.served_approx, 10);
        assert_eq!(s.served_exact, 3);
        assert_eq!(s.out_of_bound, 1);
        assert_eq!(s.dropped, 4);
        assert_eq!(s.per_model[0].dropped, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 6.5).abs() < 1e-9);
        assert!(s.mean_latency_s > 0.0);
    }

    #[test]
    fn per_model_breakdown_separates_tenants() {
        let m = Metrics::new();
        let (a, b) = (mid("alpha"), mid("bravo"));
        m.record_batch(&a, Route::Approx, 5);
        m.record_batch(&b, Route::Exact, 2);
        m.record_response(&a, Duration::from_micros(10), true);
        m.record_response(&b, Duration::from_micros(20), false);
        let s = m.snapshot();
        assert_eq!(s.per_model.len(), 2);
        assert_eq!(s.per_model[0].id, "alpha");
        assert_eq!(s.per_model[0].served_approx, 5);
        assert_eq!(s.per_model[0].served_exact, 0);
        assert!((s.per_model[0].approx_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(s.per_model[1].id, "bravo");
        assert_eq!(s.per_model[1].served_exact, 2);
        assert_eq!(s.per_model[1].out_of_bound, 1);
        let table = s.per_model_table();
        assert!(table.contains("alpha") && table.contains("bravo"));
    }

    #[test]
    fn histogram_buckets_monotone() {
        assert!(bucket_of(Duration::from_nanos(100)) <= bucket_of(Duration::from_micros(1)));
        assert!(bucket_of(Duration::from_micros(1)) < bucket_of(Duration::from_millis(1)));
        assert!(bucket_of(Duration::from_millis(1)) < bucket_of(Duration::from_secs(1)));
        assert_eq!(bucket_of(Duration::from_secs(10_000)), BUCKETS - 1);
    }

    #[test]
    fn snapshot_json_has_fields() {
        let m = Metrics::new();
        m.record_batch(&mid("default"), Route::Approx, 1);
        m.record_response(&mid("default"), Duration::from_micros(10), true);
        let j = m.snapshot().to_json().to_string_compact();
        assert!(j.contains("served_approx"));
        assert!(j.contains("latency_percentiles"));
        assert!(j.contains("\"models\""));
        assert!(j.contains("\"default\""));
    }
}
