//! Serving metrics: per-route counters, latency distribution (log-scale
//! histogram + Welford moments), bound-violation counts, throughput —
//! globally and broken down per model id, so multi-tenant operators can
//! see each tenant's route mix and latency.
//!
//! Sharded coordinators give every shard its *own* [`Metrics`] sink (no
//! cross-shard lock contention on the record path) and fan the sinks in
//! at snapshot time with [`Metrics::aggregate`]: counters and
//! histograms sum, Welford moments merge exactly, and per-model rows
//! reported by several shards **sum** rather than overwrite — each row
//! also lists the shard indices that served the model.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Welford;
use crate::util::sync::lock_unpoisoned;

use super::request::{ModelId, Route};

/// Log-scale latency histogram: bucket i covers [10^(i/4 - 7), …) s,
/// i.e. 100ns … ~100s in quarter-decade steps.
const BUCKETS: usize = 40;

#[derive(Debug)]
struct PerModel {
    served_approx: u64,
    served_exact: u64,
    out_of_bound: u64,
    dropped: u64,
    latency: Welford,
    /// Serving substrate label the executor reported for this model
    /// (`exact`/`maclaurin`/`rff`/`f16`/`int8`); empty until the first
    /// served batch (e.g. rows created by `record_dropped` alone).
    substrate: String,
    /// Resident-bytes *gauge* for this model's decoded entry, split
    /// heap vs mapped (a format-v2 entry served over a memory map
    /// charges only its scalar residue as heap). Set by the executor
    /// at batch time ([`Metrics::set_model_bytes`]); 0 until then.
    heap_bytes: u64,
    mapped_bytes: u64,
}

impl PerModel {
    fn new() -> Self {
        PerModel {
            served_approx: 0,
            served_exact: 0,
            out_of_bound: 0,
            dropped: 0,
            latency: Welford::new(),
            substrate: String::new(),
            heap_bytes: 0,
            mapped_bytes: 0,
        }
    }

    /// Fan-in: sum counters, merge moments (never overwrite). The
    /// substrate label is not a counter: any non-empty report wins
    /// (across a hot swap the newest generation's label sticks). The
    /// byte gauges **sum** — a model resident on several shards really
    /// does hold one copy per shard.
    fn absorb(&mut self, other: &PerModel) {
        self.served_approx += other.served_approx;
        self.served_exact += other.served_exact;
        self.out_of_bound += other.out_of_bound;
        self.dropped += other.dropped;
        self.latency.merge(&other.latency);
        if !other.substrate.is_empty() {
            self.substrate = other.substrate.clone();
        }
        self.heap_bytes += other.heap_bytes;
        self.mapped_bytes += other.mapped_bytes;
    }
}

#[derive(Debug)]
struct Inner {
    started: Option<Instant>,
    served_approx: u64,
    served_exact: u64,
    out_of_bound: u64,
    dropped: u64,
    batches: u64,
    /// Ingress-queue depth *gauge*: last value sampled by the
    /// coordinator at snapshot time (not a counter — it can go down).
    queue_depth: u64,
    batch_sizes: Welford,
    latency: Welford,
    histogram: [u64; BUCKETS],
    per_model: HashMap<ModelId, PerModel>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            started: None,
            served_approx: 0,
            served_exact: 0,
            out_of_bound: 0,
            dropped: 0,
            batches: 0,
            queue_depth: 0,
            batch_sizes: Welford::new(),
            latency: Welford::new(),
            histogram: [0; BUCKETS],
            per_model: HashMap::new(),
        }
    }
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Per-model slice of a snapshot.
#[derive(Clone, Debug)]
pub struct ModelMetricsSnapshot {
    pub id: String,
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    /// Requests the executor could not serve (unresolvable model,
    /// dimension drift, per-batch execution failure). Each one was
    /// completed with a fail-fast `Err(PredictError)` on its client's
    /// channel; this counter is the operational aggregate.
    pub dropped: u64,
    pub mean_latency_s: f64,
    /// Shard indices that reported traffic for this model, ascending.
    /// Rendezvous placement keeps this a single shard in steady state;
    /// aggregation still sums correctly if several shards report the
    /// same id (e.g. across a shard-count change).
    pub shards: Vec<usize>,
    /// Serving substrate the executor reported
    /// (`exact`/`maclaurin`/`rff`/`f16`/`int8`; empty before any
    /// served batch).
    pub substrate: String,
    /// Actual heap bytes of this model's decoded entry (summed across
    /// the shards listed in `shards`). A format-v2 entry served
    /// zero-copy from a memory map reports only its scalar residue
    /// here — the payload shows up in `mapped_bytes` instead. 0 before
    /// any served batch.
    pub heap_bytes: u64,
    /// Bytes this model serves as views over mapped bundle files
    /// (summed across shards; 0 for v1 heap-decoded entries).
    pub mapped_bytes: u64,
}

impl ModelMetricsSnapshot {
    pub fn served_total(&self) -> u64 {
        self.served_approx + self.served_exact
    }

    /// Fraction of this model's traffic that took the O(d²) fast path.
    pub fn approx_fraction(&self) -> f64 {
        let total = self.served_total();
        if total == 0 {
            0.0
        } else {
            self.served_approx as f64 / total as f64
        }
    }
}

/// Point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    /// Requests failed fast with an `Err(PredictError)` completion
    /// (see [`ModelMetricsSnapshot::dropped`]) instead of being served.
    pub dropped: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub mean_latency_s: f64,
    pub p_latency_s: Vec<(f64, f64)>,
    pub throughput_rps: f64,
    /// Ingress-queue depth gauge at snapshot time, **summed** across
    /// shard sinks (each sink reports its own backlog; the plane's
    /// backlog is their total). Router health checks poll this.
    pub queue_depth: u64,
    /// Seconds since the earliest fanned-in sink first served traffic
    /// (i.e. the **max** uptime across shards — one slow-starting lane
    /// never under-reports the plane's serving window). 0.0 before any
    /// traffic.
    pub uptime_s: f64,
    /// How many shard sinks were fanned into this snapshot (1 for an
    /// unsharded coordinator).
    pub shard_count: usize,
    /// Per-shard connection-lifecycle health, sorted by shard index.
    /// Empty for an in-process plane (there are no connections to
    /// lose); a network `Router` fills `reconnects` from its link
    /// ledgers and a `serve-plane` supervisor merges `restarts` via
    /// [`MetricsSnapshot::record_restarts`]. Operators see flapping
    /// here without digging through logs.
    pub shard_health: Vec<ShardHealth>,
    /// Breakdown keyed by model id, sorted by id.
    pub per_model: Vec<ModelMetricsSnapshot>,
}

/// Connection/process lifecycle counters for one shard of a plane.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardHealth {
    /// Shard index (placement order).
    pub shard: usize,
    /// Times the router re-established this shard's connection after
    /// losing it (0 on a plane that never flapped).
    pub reconnects: u64,
    /// Times a supervisor restarted this shard's process.
    pub restarts: u64,
}

fn bucket_of(lat: Duration) -> usize {
    let s = lat.as_secs_f64().max(1e-9);
    let idx = (s.log10() + 7.0) * 4.0;
    (idx.max(0.0) as usize).min(BUCKETS - 1)
}

fn bucket_lo(i: usize) -> f64 {
    10f64.powf(i as f64 / 4.0 - 7.0)
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one served sub-batch. `substrate` is the tenant's serving
    /// substrate label (see [`ModelMetricsSnapshot::substrate`]); the
    /// latest non-empty report wins, so a hot swap that changes the
    /// substrate updates the row.
    pub fn record_batch(
        &self,
        model: &ModelId,
        route: Route,
        n: usize,
        substrate: &str,
    ) {
        let mut g = lock_unpoisoned(&self.inner);
        g.started.get_or_insert_with(Instant::now);
        g.batches += 1;
        g.batch_sizes.push(n as f64);
        match route {
            Route::Approx => g.served_approx += n as u64,
            Route::Exact => g.served_exact += n as u64,
        }
        let pm = g
            .per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new);
        match route {
            Route::Approx => pm.served_approx += n as u64,
            Route::Exact => pm.served_exact += n as u64,
        }
        if !substrate.is_empty() && pm.substrate != substrate {
            pm.substrate = substrate.to_string();
        }
    }

    /// Set the ingress queue-depth gauge. Sampled by the coordinator
    /// (and the shard server) right before a snapshot; a *gauge*, so a
    /// later sample overwrites — [`Metrics::aggregate`] **sums** the
    /// last-set values across shard sinks.
    pub fn set_queue_depth(&self, n: usize) {
        lock_unpoisoned(&self.inner).queue_depth = n as u64;
    }

    /// Set the per-model resident-bytes gauge, split heap vs mapped.
    /// Reported by the executor at batch time from the tenant's cached
    /// per-generation footprint; a *gauge*, so a later report (hot
    /// swap, migration) overwrites — [`Metrics::aggregate`] **sums**
    /// the last-set values across shard sinks, since each shard holds
    /// its own copy of the entry.
    pub fn set_model_bytes(
        &self,
        model: &ModelId,
        heap: usize,
        mapped: usize,
    ) {
        let mut g = lock_unpoisoned(&self.inner);
        let pm = g
            .per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new);
        pm.heap_bytes = heap as u64;
        pm.mapped_bytes = mapped as u64;
    }

    /// Account for requests completed with a fail-fast error instead
    /// of a served prediction.
    pub fn record_dropped(&self, model: &ModelId, n: usize) {
        let mut g = lock_unpoisoned(&self.inner);
        g.dropped += n as u64;
        g.per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new)
            .dropped += n as u64;
    }

    pub fn record_response(
        &self,
        model: &ModelId,
        latency: Duration,
        in_bound: bool,
    ) {
        let mut g = lock_unpoisoned(&self.inner);
        g.latency.push(latency.as_secs_f64());
        g.histogram[bucket_of(latency)] += 1;
        if !in_bound {
            g.out_of_bound += 1;
        }
        let pm = g
            .per_model
            .entry(model.clone())
            .or_insert_with(PerModel::new);
        pm.latency.push(latency.as_secs_f64());
        if !in_bound {
            pm.out_of_bound += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        Metrics::aggregate(&[self])
    }

    /// Fan shard sinks into one snapshot. Slice order defines the shard
    /// index reported in [`ModelMetricsSnapshot::shards`]. Counters,
    /// histograms and the queue-depth gauge **sum**, Welford moments
    /// merge exactly, and per-model rows reported by several sinks are
    /// **summed**, never overwritten; `started` is the earliest sink's,
    /// so `uptime_s` is the **max** uptime across shards and throughput
    /// is measured over the whole plane's serving window.
    pub fn aggregate(shards: &[&Metrics]) -> MetricsSnapshot {
        let mut merged = Inner::default();
        let mut model_shards: HashMap<ModelId, Vec<usize>> = HashMap::new();
        for (index, sink) in shards.iter().enumerate() {
            let g = lock_unpoisoned(&sink.inner);
            merged.started = match (merged.started, g.started) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            merged.served_approx += g.served_approx;
            merged.served_exact += g.served_exact;
            merged.out_of_bound += g.out_of_bound;
            merged.dropped += g.dropped;
            merged.batches += g.batches;
            merged.queue_depth += g.queue_depth;
            merged.batch_sizes.merge(&g.batch_sizes);
            merged.latency.merge(&g.latency);
            for (bucket, &h) in g.histogram.iter().enumerate() {
                merged.histogram[bucket] += h;
            }
            for (id, pm) in &g.per_model {
                merged
                    .per_model
                    .entry(id.clone())
                    .or_insert_with(PerModel::new)
                    .absorb(pm);
                model_shards.entry(id.clone()).or_default().push(index);
            }
        }
        let uptime_s = merged
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        let elapsed = uptime_s.max(1e-9);
        let total = merged.served_approx + merged.served_exact;
        // Percentiles from the histogram (bucket lower edges).
        let mut p_latency = Vec::new();
        let served = merged.latency.count();
        if served > 0 {
            for target in [50.0f64, 95.0, 99.0] {
                let want = (target / 100.0 * served as f64).ceil() as u64;
                let mut acc = 0u64;
                let mut val = bucket_lo(BUCKETS - 1);
                for (i, &h) in merged.histogram.iter().enumerate() {
                    acc += h;
                    if acc >= want {
                        val = bucket_lo(i);
                        break;
                    }
                }
                p_latency.push((target, val));
            }
        }
        let mut per_model: Vec<ModelMetricsSnapshot> = merged
            .per_model
            .iter()
            .map(|(id, pm)| ModelMetricsSnapshot {
                id: id.to_string(),
                served_approx: pm.served_approx,
                served_exact: pm.served_exact,
                out_of_bound: pm.out_of_bound,
                dropped: pm.dropped,
                mean_latency_s: pm.latency.mean(),
                shards: model_shards.get(id).cloned().unwrap_or_default(),
                substrate: pm.substrate.clone(),
                heap_bytes: pm.heap_bytes,
                mapped_bytes: pm.mapped_bytes,
            })
            .collect();
        per_model.sort_by(|a, b| a.id.cmp(&b.id));
        MetricsSnapshot {
            served_approx: merged.served_approx,
            served_exact: merged.served_exact,
            out_of_bound: merged.out_of_bound,
            dropped: merged.dropped,
            batches: merged.batches,
            mean_batch_size: merged.batch_sizes.mean(),
            mean_latency_s: merged.latency.mean(),
            p_latency_s: p_latency,
            throughput_rps: total as f64 / elapsed,
            queue_depth: merged.queue_depth,
            uptime_s,
            shard_count: shards.len().max(1),
            // Sinks carry no lifecycle info; the router/supervisor
            // layer fills this in after aggregation.
            shard_health: Vec::new(),
            per_model,
        }
    }

    /// Export this sink's raw accumulator state for transport (the
    /// shard server answers a metrics pull with this; the router
    /// rebuilds a sink per shard with [`Metrics::from_state`] and fans
    /// them in through the ordinary [`Metrics::aggregate`], so remote
    /// planes aggregate *exactly* like local ones — moments merge, they
    /// are never re-derived from pre-averaged numbers).
    pub fn export_state(&self) -> MetricsState {
        let g = lock_unpoisoned(&self.inner);
        MetricsState {
            served_approx: g.served_approx,
            served_exact: g.served_exact,
            out_of_bound: g.out_of_bound,
            dropped: g.dropped,
            batches: g.batches,
            queue_depth: g.queue_depth,
            uptime_s: g
                .started
                .map(|s| s.elapsed().as_secs_f64())
                .unwrap_or(0.0),
            batch_sizes: WelfordState::of(&g.batch_sizes),
            latency: WelfordState::of(&g.latency),
            histogram: g.histogram.to_vec(),
            per_model: {
                let mut rows: Vec<ModelMetricsState> = g
                    .per_model
                    .iter()
                    .map(|(id, pm)| ModelMetricsState {
                        id: id.to_string(),
                        served_approx: pm.served_approx,
                        served_exact: pm.served_exact,
                        out_of_bound: pm.out_of_bound,
                        dropped: pm.dropped,
                        latency: WelfordState::of(&pm.latency),
                        substrate: pm.substrate.clone(),
                    })
                    .collect();
                rows.sort_by(|a, b| a.id.cmp(&b.id));
                rows
            },
        }
    }

    /// Rebuild a sink from transported state. The serving window is
    /// anchored `state.uptime_s` in the past so throughput over the
    /// rebuilt sink matches the exporting process (modulo transport
    /// latency). Histogram rows beyond the local bucket count are
    /// folded into the last bucket rather than dropped.
    pub fn from_state(state: &MetricsState) -> Metrics {
        let mut inner = Inner {
            started: if state.uptime_s > 0.0 {
                let ago = Duration::from_secs_f64(
                    state.uptime_s.max(0.0).min(1e9),
                );
                Some(Instant::now().checked_sub(ago).unwrap_or_else(Instant::now))
            } else {
                None
            },
            served_approx: state.served_approx,
            served_exact: state.served_exact,
            out_of_bound: state.out_of_bound,
            dropped: state.dropped,
            batches: state.batches,
            queue_depth: state.queue_depth,
            batch_sizes: state.batch_sizes.to_welford(),
            latency: state.latency.to_welford(),
            histogram: [0; BUCKETS],
            per_model: state
                .per_model
                .iter()
                .map(|m| {
                    let id: ModelId = std::sync::Arc::from(m.id.as_str());
                    let pm = PerModel {
                        served_approx: m.served_approx,
                        served_exact: m.served_exact,
                        out_of_bound: m.out_of_bound,
                        dropped: m.dropped,
                        latency: m.latency.to_welford(),
                        substrate: m.substrate.clone(),
                        // The byte gauges are a local-plane diagnostic:
                        // they describe *this process's* resident
                        // entries, so they are not transported and a
                        // rebuilt remote sink reports 0.
                        heap_bytes: 0,
                        mapped_bytes: 0,
                    };
                    (id, pm)
                })
                .collect(),
        };
        for (i, &h) in state.histogram.iter().enumerate() {
            inner.histogram[i.min(BUCKETS - 1)] += h;
        }
        Metrics { inner: Mutex::new(inner) }
    }
}

/// Transported Welford moments (see [`Welford::from_parts`]): the raw
/// sufficient statistics, so merging after transport is exact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WelfordState {
    pub count: u64,
    pub mean: f64,
    pub m2: f64,
    pub min: f64,
    pub max: f64,
}

impl WelfordState {
    fn of(w: &Welford) -> WelfordState {
        WelfordState {
            count: w.count(),
            mean: w.mean(),
            m2: w.m2(),
            min: w.min(),
            max: w.max(),
        }
    }

    fn to_welford(self) -> Welford {
        Welford::from_parts(self.count, self.mean, self.m2, self.min, self.max)
    }
}

/// Per-model slice of a [`MetricsState`].
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMetricsState {
    pub id: String,
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    pub dropped: u64,
    pub latency: WelfordState,
    /// Serving substrate label (empty before any served batch).
    pub substrate: String,
}

/// A [`Metrics`] sink's raw accumulator state in transportable form:
/// plain counters, gauges and Welford moments — no `Instant`s, no
/// interior mutability — so the wire layer can serialize it and a
/// remote router can reconstruct an equivalent sink with
/// [`Metrics::from_state`]. Rows are sorted by model id.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsState {
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    pub dropped: u64,
    pub batches: u64,
    pub queue_depth: u64,
    pub uptime_s: f64,
    pub batch_sizes: WelfordState,
    pub latency: WelfordState,
    /// Log-scale latency histogram counts (quarter-decade buckets).
    pub histogram: Vec<u64>,
    pub per_model: Vec<ModelMetricsState>,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let models: BTreeMap<String, Json> = self
            .per_model
            .iter()
            .map(|m| {
                (
                    m.id.clone(),
                    Json::obj(vec![
                        ("substrate", Json::str(m.substrate.clone())),
                        ("served_approx", Json::num(m.served_approx as f64)),
                        ("served_exact", Json::num(m.served_exact as f64)),
                        ("out_of_bound", Json::num(m.out_of_bound as f64)),
                        ("dropped", Json::num(m.dropped as f64)),
                        ("approx_fraction", Json::num(m.approx_fraction())),
                        ("mean_latency_s", Json::num(m.mean_latency_s)),
                        ("heap_bytes", Json::num(m.heap_bytes as f64)),
                        ("mapped_bytes", Json::num(m.mapped_bytes as f64)),
                        (
                            "shards",
                            Json::Arr(
                                m.shards
                                    .iter()
                                    .map(|&s| Json::num(s as f64))
                                    .collect(),
                            ),
                        ),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("served_approx", Json::num(self.served_approx as f64)),
            ("served_exact", Json::num(self.served_exact as f64)),
            ("out_of_bound", Json::num(self.out_of_bound as f64)),
            ("dropped_requests", Json::num(self.dropped as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("uptime_s", Json::num(self.uptime_s)),
            ("shard_count", Json::num(self.shard_count as f64)),
            (
                "latency_percentiles",
                Json::Arr(
                    self.p_latency_s
                        .iter()
                        .map(|&(p, v)| {
                            Json::obj(vec![
                                ("p", Json::num(p)),
                                ("seconds", Json::num(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("models", Json::Obj(models)),
            (
                "shard_health",
                Json::Arr(
                    self.shard_health
                        .iter()
                        .map(|h| {
                            Json::obj(vec![
                                ("shard", Json::num(h.shard as f64)),
                                (
                                    "reconnects",
                                    Json::num(h.reconnects as f64),
                                ),
                                (
                                    "restarts",
                                    Json::num(h.restarts as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Merge supervisor restart counters into the per-shard health
    /// rows (`restarts[i]` is shard `i`'s restart count). Rows are
    /// created for shards the router has no link ledger for, so a
    /// restart is never dropped; existing `reconnects` are kept.
    pub fn record_restarts(&mut self, restarts: &[u64]) {
        for (shard, &n) in restarts.iter().enumerate() {
            match self
                .shard_health
                .iter_mut()
                .find(|h| h.shard == shard)
            {
                Some(row) => row.restarts += n,
                None => self.shard_health.push(ShardHealth {
                    shard,
                    reconnects: 0,
                    restarts: n,
                }),
            }
        }
        self.shard_health.sort_by_key(|h| h.shard);
    }

    /// Render the per-model breakdown as an aligned text table (used by
    /// the CLI, `serving_bench` and the multi-tenant example). The
    /// `shard` column shows which executor lane(s) served the model.
    pub fn per_model_table(&self) -> String {
        let mut out = format!(
            "plane: shards={} queue_depth={} uptime={:.1}s",
            self.shard_count, self.queue_depth, self.uptime_s
        );
        if !self.shard_health.is_empty() {
            let reconnects: u64 =
                self.shard_health.iter().map(|h| h.reconnects).sum();
            let restarts: u64 =
                self.shard_health.iter().map(|h| h.restarts).sum();
            out.push_str(&format!(
                " reconnects={reconnects} restarts={restarts}"
            ));
        }
        out.push('\n');
        out.push_str(
            "model                    substrate shard  served   approx    \
             exact  oob drop  mean lat    heap B  mapped B\n",
        );
        for m in &self.per_model {
            let shards = m
                .shards
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "{:<24} {:>9} {:>5} {:>7} {:>8} {:>8} {:>4} {:>4} \
                 {:>8.1} µs {:>9} {:>9}\n",
                m.id,
                if m.substrate.is_empty() { "-" } else { m.substrate.as_str() },
                shards,
                m.served_total(),
                m.served_approx,
                m.served_exact,
                m.out_of_bound,
                m.dropped,
                m.mean_latency_s * 1e6,
                m.heap_bytes,
                m.mapped_bytes
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mid(s: &str) -> ModelId {
        std::sync::Arc::from(s)
    }

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        let a = mid("default");
        m.record_batch(&a, Route::Approx, 10, "maclaurin");
        m.record_batch(&a, Route::Exact, 3, "maclaurin");
        m.record_response(&a, Duration::from_micros(50), true);
        m.record_response(&a, Duration::from_micros(150), false);
        m.record_dropped(&a, 4);
        let s = m.snapshot();
        assert_eq!(s.served_approx, 10);
        assert_eq!(s.served_exact, 3);
        assert_eq!(s.out_of_bound, 1);
        assert_eq!(s.dropped, 4);
        assert_eq!(s.per_model[0].dropped, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 6.5).abs() < 1e-9);
        assert!(s.mean_latency_s > 0.0);
    }

    #[test]
    fn per_model_breakdown_separates_tenants() {
        let m = Metrics::new();
        let (a, b) = (mid("alpha"), mid("bravo"));
        m.record_batch(&a, Route::Approx, 5, "maclaurin");
        m.record_batch(&b, Route::Exact, 2, "maclaurin");
        m.record_response(&a, Duration::from_micros(10), true);
        m.record_response(&b, Duration::from_micros(20), false);
        let s = m.snapshot();
        assert_eq!(s.per_model.len(), 2);
        assert_eq!(s.per_model[0].id, "alpha");
        assert_eq!(s.per_model[0].served_approx, 5);
        assert_eq!(s.per_model[0].served_exact, 0);
        assert!((s.per_model[0].approx_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(s.per_model[1].id, "bravo");
        assert_eq!(s.per_model[1].served_exact, 2);
        assert_eq!(s.per_model[1].out_of_bound, 1);
        let table = s.per_model_table();
        assert!(table.contains("alpha") && table.contains("bravo"));
    }

    #[test]
    fn aggregate_sums_same_model_id_across_shards() {
        // Regression: two shards reporting the SAME model id must sum
        // into one row — dropped and out-of-bound counts included —
        // never overwrite each other.
        let shard0 = Metrics::new();
        let shard1 = Metrics::new();
        let id = mid("tenant");
        shard0.record_batch(&id, Route::Approx, 10, "maclaurin");
        shard0.record_response(&id, Duration::from_micros(50), false);
        shard0.record_dropped(&id, 3);
        shard1.record_batch(&id, Route::Approx, 7, "maclaurin");
        shard1.record_batch(&id, Route::Exact, 2, "maclaurin");
        shard1.record_response(&id, Duration::from_micros(150), false);
        shard1.record_dropped(&id, 4);
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert_eq!(s.shard_count, 2);
        assert_eq!(s.per_model.len(), 1, "one row per model id");
        let m = &s.per_model[0];
        assert_eq!(m.served_approx, 17, "summed, not overwritten");
        assert_eq!(m.served_exact, 2);
        assert_eq!(m.dropped, 7, "dropped must survive fan-in");
        assert_eq!(m.out_of_bound, 2, "oob must survive fan-in");
        assert_eq!(m.shards, vec![0, 1]);
        // Globals match the per-model sums.
        assert_eq!(s.served_approx, 17);
        assert_eq!(s.dropped, 7);
        assert_eq!(s.out_of_bound, 2);
        assert_eq!(s.batches, 3);
        // Merged mean latency is the exact pooled mean (100µs).
        assert!((m.mean_latency_s - 100e-6).abs() < 1e-9);
    }

    #[test]
    fn aggregate_keeps_distinct_models_distinct() {
        let shard0 = Metrics::new();
        let shard1 = Metrics::new();
        shard0.record_batch(&mid("alpha"), Route::Approx, 5, "maclaurin");
        shard1.record_batch(&mid("bravo"), Route::Exact, 3, "maclaurin");
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert_eq!(s.per_model.len(), 2);
        assert_eq!(s.per_model[0].id, "alpha");
        assert_eq!(s.per_model[0].shards, vec![0]);
        assert_eq!(s.per_model[1].id, "bravo");
        assert_eq!(s.per_model[1].shards, vec![1]);
        let table = s.per_model_table();
        assert!(table.contains("shard"), "table gains the shard column");
    }

    #[test]
    fn substrate_column_tracks_latest_report_and_survives_fanin() {
        let shard0 = Metrics::new();
        let shard1 = Metrics::new();
        let id = mid("tenant");
        // A drop-only row has no substrate yet.
        shard0.record_dropped(&id, 1);
        assert_eq!(Metrics::aggregate(&[&shard0]).per_model[0].substrate, "");
        // First served batch sets it; a republish onto a different
        // substrate updates it (latest non-empty report wins).
        shard0.record_batch(&id, Route::Approx, 4, "maclaurin");
        shard0.record_batch(&id, Route::Approx, 4, "rff");
        assert_eq!(shard0.snapshot().per_model[0].substrate, "rff");
        // Fan-in: a shard that never served the tenant (empty label)
        // must not blank the column.
        shard1.record_dropped(&id, 2);
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert_eq!(s.per_model[0].substrate, "rff");
        assert!(s.per_model_table().contains("rff"));
        assert!(s.per_model_table().contains("substrate"));
        // And it survives the transportable-state roundtrip.
        let rebuilt = Metrics::from_state(&shard0.export_state());
        assert_eq!(rebuilt.snapshot().per_model[0].substrate, "rff");
    }

    #[test]
    fn model_bytes_gauge_overwrites_locally_and_sums_across_shards() {
        let shard0 = Metrics::new();
        let shard1 = Metrics::new();
        let id = mid("tenant");
        shard0.record_batch(&id, Route::Approx, 1, "int8");
        shard0.set_model_bytes(&id, 4096, 0);
        // A hot swap to a mapped v2 entry overwrites the gauge.
        shard0.set_model_bytes(&id, 64, 4032);
        let s = shard0.snapshot();
        assert_eq!(s.per_model[0].heap_bytes, 64);
        assert_eq!(s.per_model[0].mapped_bytes, 4032);
        // Fan-in sums: each shard holds its own copy of the entry.
        shard1.set_model_bytes(&id, 64, 4032);
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert_eq!(s.per_model[0].heap_bytes, 128);
        assert_eq!(s.per_model[0].mapped_bytes, 8064);
        let table = s.per_model_table();
        assert!(table.contains("heap B"), "{table}");
        assert!(table.contains("8064"), "{table}");
        let json = s.to_json().to_string_compact();
        assert!(json.contains("\"heap_bytes\":128"), "{json}");
        assert!(json.contains("\"mapped_bytes\":8064"), "{json}");
    }

    #[test]
    fn histogram_buckets_monotone() {
        assert!(bucket_of(Duration::from_nanos(100)) <= bucket_of(Duration::from_micros(1)));
        assert!(bucket_of(Duration::from_micros(1)) < bucket_of(Duration::from_millis(1)));
        assert!(bucket_of(Duration::from_millis(1)) < bucket_of(Duration::from_secs(1)));
        assert_eq!(bucket_of(Duration::from_secs(10_000)), BUCKETS - 1);
    }

    #[test]
    fn snapshot_json_has_fields() {
        let m = Metrics::new();
        m.record_batch(&mid("default"), Route::Approx, 1, "maclaurin");
        m.record_response(&mid("default"), Duration::from_micros(10), true);
        let j = m.snapshot().to_json().to_string_compact();
        assert!(j.contains("served_approx"));
        assert!(j.contains("\"substrate\":\"maclaurin\""));
        assert!(j.contains("latency_percentiles"));
        assert!(j.contains("\"models\""));
        assert!(j.contains("\"default\""));
        assert!(j.contains("\"shard_count\""));
        assert!(j.contains("\"shards\""));
        assert!(j.contains("\"queue_depth\""));
        assert!(j.contains("\"uptime_s\""));
    }

    #[test]
    fn queue_depth_is_a_gauge_and_sums_across_shards() {
        let shard0 = Metrics::new();
        let shard1 = Metrics::new();
        shard0.set_queue_depth(7);
        shard0.set_queue_depth(3); // later sample overwrites
        shard1.set_queue_depth(5);
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert_eq!(s.queue_depth, 8);
        // No traffic yet: uptime stays 0 (the gauge alone does not
        // start the serving window).
        assert_eq!(s.uptime_s, 0.0);
        shard0.record_batch(&mid("a"), Route::Approx, 1, "maclaurin");
        let s = Metrics::aggregate(&[&shard0, &shard1]);
        assert!(s.uptime_s >= 0.0);
        assert!(s.per_model_table().contains("queue_depth=8"));
    }

    #[test]
    fn state_roundtrip_preserves_aggregate() {
        let m = Metrics::new();
        let (a, b) = (mid("alpha"), mid("bravo"));
        m.record_batch(&a, Route::Approx, 10, "maclaurin");
        m.record_batch(&b, Route::Exact, 3, "maclaurin");
        m.record_response(&a, Duration::from_micros(50), true);
        m.record_response(&a, Duration::from_micros(150), false);
        m.record_response(&b, Duration::from_millis(2), true);
        m.record_dropped(&b, 4);
        m.set_queue_depth(6);

        let state = m.export_state();
        let rebuilt = Metrics::from_state(&state);
        let (s0, s1) = (m.snapshot(), rebuilt.snapshot());
        assert_eq!(s0.served_approx, s1.served_approx);
        assert_eq!(s0.served_exact, s1.served_exact);
        assert_eq!(s0.out_of_bound, s1.out_of_bound);
        assert_eq!(s0.dropped, s1.dropped);
        assert_eq!(s0.batches, s1.batches);
        assert_eq!(s0.queue_depth, s1.queue_depth);
        assert!((s0.mean_batch_size - s1.mean_batch_size).abs() < 1e-12);
        assert!((s0.mean_latency_s - s1.mean_latency_s).abs() < 1e-12);
        assert_eq!(s0.p_latency_s, s1.p_latency_s);
        assert_eq!(s0.per_model.len(), s1.per_model.len());
        for (m0, m1) in s0.per_model.iter().zip(&s1.per_model) {
            assert_eq!(m0.id, m1.id);
            assert_eq!(m0.served_total(), m1.served_total());
            assert_eq!(m0.dropped, m1.dropped);
            assert!((m0.mean_latency_s - m1.mean_latency_s).abs() < 1e-12);
        }
        // A second export round-trips exactly (state is pure data).
        assert_eq!(rebuilt.export_state().histogram, state.histogram);
        assert_eq!(rebuilt.export_state().per_model, state.per_model);

        // Rebuilt sinks merge through the ordinary aggregate path.
        let merged = Metrics::aggregate(&[&m, &rebuilt]);
        assert_eq!(merged.served_approx, 2 * s0.served_approx);
        assert_eq!(merged.queue_depth, 2 * s0.queue_depth);
    }

    #[test]
    fn from_state_folds_oversized_histogram_tail() {
        let mut state = Metrics::new().export_state();
        state.histogram = vec![1u64; BUCKETS + 5];
        let rebuilt = Metrics::from_state(&state).export_state();
        assert_eq!(rebuilt.histogram.len(), BUCKETS);
        assert_eq!(
            rebuilt.histogram.iter().sum::<u64>(),
            (BUCKETS + 5) as u64
        );
        assert_eq!(rebuilt.histogram[BUCKETS - 1], 6);
    }

    #[test]
    fn shard_health_starts_empty_and_merges_restarts() {
        let m = Metrics::new();
        let mut s = m.snapshot();
        assert!(s.shard_health.is_empty());
        // A router-style row plus supervisor restarts for two shards.
        s.shard_health.push(ShardHealth {
            shard: 1,
            reconnects: 3,
            restarts: 0,
        });
        s.record_restarts(&[2, 1]);
        assert_eq!(
            s.shard_health,
            vec![
                ShardHealth { shard: 0, reconnects: 0, restarts: 2 },
                ShardHealth { shard: 1, reconnects: 3, restarts: 1 },
            ]
        );
        // Merging again accumulates rather than overwrites.
        s.record_restarts(&[0, 4]);
        assert_eq!(s.shard_health[1].restarts, 5);
    }

    #[test]
    fn shard_health_renders_in_table_and_json() {
        let m = Metrics::new();
        let a = mid("default");
        m.record_batch(&a, Route::Approx, 2, "maclaurin");
        let mut s = m.snapshot();
        // No health rows: the plane header stays as before.
        assert!(!s.per_model_table().contains("reconnects="));
        s.shard_health.push(ShardHealth {
            shard: 0,
            reconnects: 2,
            restarts: 1,
        });
        let table = s.per_model_table();
        assert!(table.contains("reconnects=2 restarts=1"), "{table}");
        let json = s.to_json().to_string_compact();
        assert!(json.contains("\"shard_health\""), "{json}");
        assert!(json.contains("\"reconnects\""), "{json}");
        assert!(json.contains("\"restarts\""), "{json}");
    }
}
