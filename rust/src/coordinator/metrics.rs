//! Serving metrics: per-route counters, latency distribution (log-scale
//! histogram + Welford moments), bound-violation counts, throughput.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Welford;

use super::request::Route;

/// Log-scale latency histogram: bucket i covers [10^(i/4 - 7), …) s,
/// i.e. 100ns … ~100s in quarter-decade steps.
const BUCKETS: usize = 40;

#[derive(Debug)]
struct Inner {
    started: Option<Instant>,
    served_approx: u64,
    served_exact: u64,
    out_of_bound: u64,
    batches: u64,
    batch_sizes: Welford,
    latency: Welford,
    histogram: [u64; BUCKETS],
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            started: None,
            served_approx: 0,
            served_exact: 0,
            out_of_bound: 0,
            batches: 0,
            batch_sizes: Welford::new(),
            latency: Welford::new(),
            histogram: [0; BUCKETS],
        }
    }
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub served_approx: u64,
    pub served_exact: u64,
    pub out_of_bound: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub mean_latency_s: f64,
    pub p_latency_s: Vec<(f64, f64)>,
    pub throughput_rps: f64,
}

fn bucket_of(lat: Duration) -> usize {
    let s = lat.as_secs_f64().max(1e-9);
    let idx = (s.log10() + 7.0) * 4.0;
    (idx.max(0.0) as usize).min(BUCKETS - 1)
}

fn bucket_lo(i: usize) -> f64 {
    10f64.powf(i as f64 / 4.0 - 7.0)
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_batch(&self, route: Route, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(Instant::now);
        g.batches += 1;
        g.batch_sizes.push(n as f64);
        match route {
            Route::Approx => g.served_approx += n as u64,
            Route::Exact => g.served_exact += n as u64,
        }
    }

    pub fn record_response(&self, latency: Duration, in_bound: bool) {
        let mut g = self.inner.lock().unwrap();
        g.latency.push(latency.as_secs_f64());
        g.histogram[bucket_of(latency)] += 1;
        if !in_bound {
            g.out_of_bound += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = g
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let total = g.served_approx + g.served_exact;
        // Percentiles from the histogram (bucket lower edges).
        let mut p_latency = Vec::new();
        let served = g.latency.count();
        if served > 0 {
            for target in [50.0f64, 95.0, 99.0] {
                let want = (target / 100.0 * served as f64).ceil() as u64;
                let mut acc = 0u64;
                let mut val = bucket_lo(BUCKETS - 1);
                for (i, &h) in g.histogram.iter().enumerate() {
                    acc += h;
                    if acc >= want {
                        val = bucket_lo(i);
                        break;
                    }
                }
                p_latency.push((target, val));
            }
        }
        MetricsSnapshot {
            served_approx: g.served_approx,
            served_exact: g.served_exact,
            out_of_bound: g.out_of_bound,
            batches: g.batches,
            mean_batch_size: g.batch_sizes.mean(),
            mean_latency_s: g.latency.mean(),
            p_latency_s: p_latency,
            throughput_rps: total as f64 / elapsed,
        }
    }
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("served_approx", Json::num(self.served_approx as f64)),
            ("served_exact", Json::num(self.served_exact as f64)),
            ("out_of_bound", Json::num(self.out_of_bound as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            (
                "latency_percentiles",
                Json::Arr(
                    self.p_latency_s
                        .iter()
                        .map(|&(p, v)| {
                            Json::obj(vec![
                                ("p", Json::num(p)),
                                ("seconds", Json::num(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        m.record_batch(Route::Approx, 10);
        m.record_batch(Route::Exact, 3);
        m.record_response(Duration::from_micros(50), true);
        m.record_response(Duration::from_micros(150), false);
        let s = m.snapshot();
        assert_eq!(s.served_approx, 10);
        assert_eq!(s.served_exact, 3);
        assert_eq!(s.out_of_bound, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 6.5).abs() < 1e-9);
        assert!(s.mean_latency_s > 0.0);
    }

    #[test]
    fn histogram_buckets_monotone() {
        assert!(bucket_of(Duration::from_nanos(100)) <= bucket_of(Duration::from_micros(1)));
        assert!(bucket_of(Duration::from_micros(1)) < bucket_of(Duration::from_millis(1)));
        assert!(bucket_of(Duration::from_millis(1)) < bucket_of(Duration::from_secs(1)));
        assert_eq!(bucket_of(Duration::from_secs(10_000)), BUCKETS - 1);
    }

    #[test]
    fn snapshot_json_has_fields() {
        let m = Metrics::new();
        m.record_batch(Route::Approx, 1);
        m.record_response(Duration::from_micros(10), true);
        let j = m.snapshot().to_json().to_string_compact();
        assert!(j.contains("served_approx"));
        assert!(j.contains("latency_percentiles"));
    }
}
