//! Bound-aware routing (the paper's Eq. 3.11 made operational).

use crate::linalg::vecops;

use super::request::Route;

/// Routing policy for incoming instances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Everything through the approximated model (paper's Table 2
    /// "approx" rows; guarantees abandoned when out of bound).
    AlwaysApprox,
    /// Everything through the exact model (Table 2 "exact" rows).
    AlwaysExact,
    /// Approx when Eq. (3.11) holds, exact otherwise: served accuracy
    /// keeps the 3.05% term-wise guarantee on every instance.
    Hybrid,
}

impl RoutePolicy {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::AlwaysApprox => "approx",
            RoutePolicy::AlwaysExact => "exact",
            RoutePolicy::Hybrid => "hybrid",
        }
    }
}

impl std::str::FromStr for RoutePolicy {
    type Err = crate::Error;

    fn from_str(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "approx" | "always-approx" => Ok(RoutePolicy::AlwaysApprox),
            "exact" | "always-exact" => Ok(RoutePolicy::AlwaysExact),
            "hybrid" | "bound" => Ok(RoutePolicy::Hybrid),
            other => Err(crate::Error::InvalidArg(format!(
                "unknown policy '{other}' (approx|exact|hybrid)"
            ))),
        }
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Stateless router: decides the route for one instance.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    /// ‖z‖² budget from [`crate::approx::ApproxModel::znorm_sq_budget`].
    pub znorm_sq_budget: f32,
}

impl Router {
    /// Route an instance; returns (route, ‖z‖², in_bound).
    /// ‖z‖² costs O(d) — the same quantity the approx evaluator needs,
    /// so the check is free in the approx path (paper §3.1).
    pub fn route(&self, features: &[f32]) -> (Route, f32, bool) {
        let zn = vecops::norm_sq(features);
        let in_bound = zn < self.znorm_sq_budget;
        let route = match self.policy {
            RoutePolicy::AlwaysApprox => Route::Approx,
            RoutePolicy::AlwaysExact => Route::Exact,
            RoutePolicy::Hybrid => {
                if in_bound {
                    Route::Approx
                } else {
                    Route::Exact
                }
            }
        };
        (route, zn, in_bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_routes_by_bound() {
        let r = Router { policy: RoutePolicy::Hybrid, znorm_sq_budget: 1.0 };
        let (route, zn, ok) = r.route(&[0.5, 0.5]); // ‖z‖² = 0.5 < 1
        assert_eq!(route, Route::Approx);
        assert!((zn - 0.5).abs() < 1e-6);
        assert!(ok);
        let (route, _, ok) = r.route(&[1.0, 1.0]); // ‖z‖² = 2 ≥ 1
        assert_eq!(route, Route::Exact);
        assert!(!ok);
    }

    #[test]
    fn fixed_policies_ignore_bound() {
        let a =
            Router { policy: RoutePolicy::AlwaysApprox, znorm_sq_budget: 0.0 };
        assert_eq!(a.route(&[9.0]).0, Route::Approx);
        let e = Router {
            policy: RoutePolicy::AlwaysExact,
            znorm_sq_budget: f32::INFINITY,
        };
        assert_eq!(e.route(&[0.0]).0, Route::Exact);
    }

    #[test]
    fn policy_parse() {
        assert_eq!("hybrid".parse::<RoutePolicy>().unwrap(), RoutePolicy::Hybrid);
        assert_eq!(
            "EXACT".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::AlwaysExact
        );
        assert!("x".parse::<RoutePolicy>().is_err());
        assert_eq!("bound".parse::<RoutePolicy>().unwrap(), RoutePolicy::Hybrid);
    }

    #[test]
    fn policy_display_roundtrips_through_fromstr() {
        for p in [
            RoutePolicy::AlwaysApprox,
            RoutePolicy::AlwaysExact,
            RoutePolicy::Hybrid,
        ] {
            assert_eq!(p.to_string().parse::<RoutePolicy>().unwrap(), p);
        }
    }
}
