//! The coordinator: wires ingress queue → batcher → executor → response
//! channel, owns the threads, and exposes the public serving API
//! ([`Coordinator::submit`] / [`Coordinator::submit_to`] /
//! [`Coordinator::recv`] / [`Coordinator::predict_all`]).
//!
//! Two ways to start one:
//!
//! * [`Coordinator::start`] — a single in-memory (exact, approx) pair
//!   served under the id [`DEFAULT_MODEL`] (the original single-tenant
//!   path; unchanged semantics).
//! * [`Coordinator::start_registry`] — multi-tenant serving over a
//!   [`ModelStore`]: requests address models by id, state is resolved
//!   lazily, and republished bundles hot-swap without dropping
//!   in-flight requests ([`Coordinator::refresh`] forces the check;
//!   `swap_poll` bounds how stale a tenant can get otherwise).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::linalg::Mat;
use crate::log_warn;
use crate::registry::ModelStore;
use crate::svm::SvmModel;
use crate::{Error, Result};

use super::batcher::IngressQueue;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{
    ModelId, PredictRequest, PredictResponse, WorkItem, DEFAULT_MODEL,
};
use super::router::RoutePolicy;
use super::worker::{ModelSource, WorkerParams};
pub use super::worker::ExecSpec;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub policy: RoutePolicy,
    pub exec: ExecSpec,
    /// Max instances per routed batch.
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Ingress queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Registry mode: how often the executor revalidates a model's
    /// on-disk generation without an explicit [`Coordinator::refresh`].
    pub swap_poll: Duration,
    /// Registry mode: LRU bound on models fully resident in the
    /// executor (evicted tenants reload lazily from the store).
    pub max_resident_models: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            policy: RoutePolicy::Hybrid,
            exec: ExecSpec::Native(crate::linalg::MathBackend::Blocked),
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            swap_poll: Duration::from_millis(200),
            max_resident_models: 512,
        }
    }
}

/// Per-model dimension checking at the submit boundary.
enum DimCheck {
    /// Single static model: one known dimension.
    Static(usize),
    /// Registry: dimensions read from bundle headers, cached.
    Registry { store: Arc<ModelStore>, cache: Mutex<HashMap<String, usize>> },
}

/// A running serving instance over one model or a whole registry.
pub struct Coordinator {
    ingress: Arc<IngressQueue>,
    resp_rx: Mutex<Receiver<PredictResponse>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dims: DimCheck,
    /// Bumped by [`Coordinator::refresh`]; the executor revalidates
    /// every tenant it touches after a bump.
    epoch: Arc<AtomicU64>,
    batcher: Option<JoinHandle<()>>,
    worker: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Spawn the serving threads over one in-memory model pair, served
    /// as [`DEFAULT_MODEL`]. `exact` and `approx` must describe the
    /// same underlying model (the builder guarantees this).
    pub fn start(
        exact: SvmModel,
        approx: ApproxModel,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        if exact.dim() != approx.dim() {
            return Err(Error::Shape(format!(
                "exact dim {} vs approx dim {}",
                exact.dim(),
                approx.dim()
            )));
        }
        let dim = exact.dim();
        Coordinator::start_inner(
            ModelSource::Static { exact, approx },
            DimCheck::Static(dim),
            config,
        )
    }

    /// Spawn the serving threads over a model registry: any id stored
    /// in `store` can be addressed via [`Coordinator::submit_to`], and
    /// republishing a bundle hot-swaps it.
    pub fn start_registry(
        store: Arc<ModelStore>,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        Coordinator::start_inner(
            ModelSource::Registry { store: store.clone() },
            DimCheck::Registry { store, cache: Mutex::new(HashMap::new()) },
            config,
        )
    }

    fn start_inner(
        source: ModelSource,
        dims: DimCheck,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let ingress = Arc::new(IngressQueue::new(config.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let epoch = Arc::new(AtomicU64::new(0));
        let (work_tx, work_rx): (Sender<WorkItem>, Receiver<WorkItem>) =
            mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();

        // Executor thread (owns predictors / PJRT engine / tenants).
        let worker_metrics = metrics.clone();
        let worker_epoch = epoch.clone();
        let spec = config.exec.clone();
        let params = WorkerParams {
            policy: config.policy,
            swap_poll: config.swap_poll,
            max_resident: config.max_resident_models,
        };
        let worker = std::thread::Builder::new()
            .name("approxrbf-executor".into())
            .spawn(move || {
                let out = super::worker::run_worker(
                    spec,
                    source,
                    params,
                    worker_epoch,
                    work_rx,
                    resp_tx,
                    worker_metrics,
                );
                if let Err(ref e) = out {
                    log_warn!("executor exited with error: {e}");
                }
                out
            })
            .map_err(|e| Error::Other(format!("spawn executor: {e}")))?;

        // Batcher thread: drains ingress, groups by model id, forwards.
        // Routing happens in the executor, which owns each model's
        // Eq. 3.11 budget.
        let b_ingress = ingress.clone();
        let (max_batch, max_wait) = (config.max_batch, config.max_wait);
        let batcher = std::thread::Builder::new()
            .name("approxrbf-batcher".into())
            .spawn(move || {
                'run: loop {
                    match b_ingress.pop_batch(max_batch, max_wait) {
                        None => {
                            let _ = work_tx.send(WorkItem::Shutdown);
                            break;
                        }
                        Some(batch) if batch.is_empty() => continue,
                        Some(batch) => {
                            // Stable grouping by model id (a popped batch
                            // holds a handful of tenants at most).
                            let mut groups: Vec<(
                                ModelId,
                                Vec<PredictRequest>,
                            )> = Vec::new();
                            for req in batch {
                                match groups
                                    .iter_mut()
                                    .find(|(m, _)| *m == req.model)
                                {
                                    Some((_, v)) => v.push(req),
                                    None => groups
                                        .push((req.model.clone(), vec![req])),
                                }
                            }
                            for (model, requests) in groups {
                                if work_tx
                                    .send(WorkItem::Batch { model, requests })
                                    .is_err()
                                {
                                    break 'run;
                                }
                            }
                        }
                    }
                }
            })
            .map_err(|e| Error::Other(format!("spawn batcher: {e}")))?;

        Ok(Coordinator {
            ingress,
            resp_rx: Mutex::new(resp_rx),
            metrics,
            next_id: AtomicU64::new(0),
            dims,
            epoch,
            batcher: Some(batcher),
            worker: Some(worker),
        })
    }

    /// Expected feature dimension for `model` (validated at submit so
    /// shape errors surface to the caller, not inside the executor).
    fn dim_of(&self, model: &str) -> Result<usize> {
        match &self.dims {
            DimCheck::Static(d) => {
                if model == DEFAULT_MODEL {
                    Ok(*d)
                } else {
                    Err(Error::InvalidArg(format!(
                        "unknown model '{model}': this coordinator serves \
                         only '{DEFAULT_MODEL}' (use start_registry for \
                         multi-tenant serving)"
                    )))
                }
            }
            DimCheck::Registry { store, cache } => {
                if let Some(&d) = cache.lock().unwrap().get(model) {
                    return Ok(d);
                }
                let info = store.peek(model)?;
                cache
                    .lock()
                    .unwrap()
                    .insert(model.to_string(), info.dim);
                Ok(info.dim)
            }
        }
    }

    /// Enqueue one instance for [`DEFAULT_MODEL`]; returns its request
    /// id. Blocks when the ingress queue is full (backpressure).
    pub fn submit(&self, features: Vec<f32>) -> Result<u64> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Enqueue one instance for a named model.
    pub fn submit_to(&self, model: &str, features: Vec<f32>) -> Result<u64> {
        let dim = self.dim_of(model)?;
        if features.len() != dim {
            return Err(Error::Shape(format!(
                "instance dim {} vs model '{model}' dim {dim}",
                features.len()
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let ok = self.ingress.push(PredictRequest {
            id,
            model: Arc::from(model),
            features,
            enqueued_at: Instant::now(),
        });
        if ok {
            Ok(id)
        } else {
            Err(Error::Other("coordinator is shut down".into()))
        }
    }

    /// Force the executor to revalidate model generations before the
    /// next batch of each tenant (hot-swap without waiting out
    /// `swap_poll`). Also drops cached dimension checks.
    pub fn refresh(&self) {
        if let DimCheck::Registry { cache, .. } = &self.dims {
            cache.lock().unwrap().clear();
        }
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Receive the next completed response (any order across batches).
    pub fn recv(&self, timeout: Duration) -> Option<PredictResponse> {
        self.recv_inner(timeout).ok()
    }

    fn recv_inner(
        &self,
        timeout: Duration,
    ) -> std::result::Result<PredictResponse, RecvTimeoutError> {
        self.resp_rx.lock().unwrap().recv_timeout(timeout)
    }

    /// Convenience synchronous API: submit every row of `z` to
    /// [`DEFAULT_MODEL`], wait for all responses, return them ordered
    /// by row.
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        self.predict_all_for(DEFAULT_MODEL, z)
    }

    /// [`Coordinator::predict_all`] addressed to a named model.
    pub fn predict_all_for(
        &self,
        model: &str,
        z: &Mat,
    ) -> Result<Vec<PredictResponse>> {
        let n = z.rows();
        let mut first_id = None;
        for r in 0..n {
            let id = self.submit_to(model, z.row(r).to_vec())?;
            if r == 0 {
                first_id = Some(id);
            }
        }
        let first_id = first_id.ok_or_else(|| {
            Error::InvalidArg("empty batch".into())
        })?;
        let mut out: Vec<Option<PredictResponse>> = vec![None; n];
        let mut got = 0;
        let deadline = Instant::now() + Duration::from_secs(600);
        while got < n {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| Error::Other("predict_all timed out".into()))?;
            // Poll in short steps so a slow first batch (e.g. lazy XLA
            // compilation) is not misread as a dead executor; a truly
            // disconnected channel (executor died) errors immediately.
            let resp = match self
                .recv_inner(remaining.min(Duration::from_millis(200)))
            {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Other(
                        "executor thread terminated".into(),
                    ))
                }
            };
            let idx = (resp.id - first_id) as usize;
            if idx < n && out[idx].is_none() {
                out[idx] = Some(resp);
                got += 1;
            }
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.ingress.len()
    }

    /// Graceful shutdown: drain, stop threads, surface executor errors.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        self.ingress.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker.take() {
            match h.join() {
                Ok(r) => r?,
                Err(_) => return Err(Error::Other("executor panicked".into())),
            }
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::builder::build_approx_model;
    use crate::coordinator::Route;
    use crate::data::synth;
    use crate::linalg::MathBackend;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn setup(gamma: f32) -> (SvmModel, ApproxModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(71, 250, 6, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let (model, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        (model, am, scaled)
    }

    #[test]
    fn serves_all_requests_and_matches_direct_eval() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model.clone(),
            am.clone(),
            CoordinatorConfig::default(),
        )
        .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        assert_eq!(responses.len(), ds.len());
        for (r, resp) in responses.iter().enumerate() {
            // γ in bound ⇒ hybrid routes to approx; value must match the
            // direct approx evaluation.
            let (want, _) = am.decision_one(ds.x.row(r));
            assert_eq!(resp.route, Route::Approx);
            assert_eq!(&*resp.model, DEFAULT_MODEL);
            assert_eq!(resp.generation, 0);
            assert!(
                (resp.decision - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                resp.decision
            );
        }
        let m = coord.metrics();
        assert_eq!(m.served_approx as usize, ds.len());
        assert_eq!(m.served_exact, 0);
        assert_eq!(m.per_model.len(), 1);
        assert_eq!(m.per_model[0].id, DEFAULT_MODEL);
        assert_eq!(m.per_model[0].served_approx as usize, ds.len());
        coord.shutdown().unwrap();
    }

    #[test]
    fn hybrid_escorts_out_of_bound_to_exact() {
        let (model, am, ds) = setup(1.5); // γ = 6× γ_max: all out of bound
        let coord =
            Coordinator::start(model.clone(), am, CoordinatorConfig::default())
                .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(resp.route, Route::Exact, "row {r}");
            assert!(!resp.in_bound);
            let want = model.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-3);
        }
        coord.shutdown().unwrap();
    }

    #[test]
    fn always_policies_force_route() {
        let (model, am, ds) = setup(0.2);
        for (policy, want) in [
            (RoutePolicy::AlwaysExact, Route::Exact),
            (RoutePolicy::AlwaysApprox, Route::Approx),
        ] {
            let coord = Coordinator::start(
                model.clone(),
                am.clone(),
                CoordinatorConfig { policy, ..Default::default() },
            )
            .unwrap();
            let responses =
                coord.predict_all(&ds.x.rows_slice(0, 20)).unwrap();
            assert!(responses.iter().all(|r| r.route == want));
            coord.shutdown().unwrap();
        }
    }

    #[test]
    fn dim_mismatch_rejected_at_submit() {
        let (model, am, _) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        assert!(coord.submit(vec![0.0; 99]).is_err());
        coord.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_rejected_on_static_coordinator() {
        let (model, am, ds) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        let err =
            coord.submit_to("ghost", ds.x.row(0).to_vec()).unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
        coord.shutdown().unwrap();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(model, am, CoordinatorConfig::default())
            .unwrap();
        coord.ingress.close();
        assert!(coord.submit(ds.x.row(0).to_vec()).is_err());
    }

    #[test]
    fn batching_actually_batches() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model,
            am,
            CoordinatorConfig {
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        )
        .unwrap();
        let _ = coord.predict_all(&ds.x).unwrap();
        let m = coord.metrics();
        assert!(
            m.mean_batch_size > 1.5,
            "expected dynamic batching, mean batch {}",
            m.mean_batch_size
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn registry_coordinator_serves_multiple_tenants() {
        let dir = std::env::temp_dir().join(format!(
            "approxrbf_server_registry_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(ModelStore::open(dir).unwrap());
        let (m_a, am_a, ds_a) = setup(0.2);
        let (m_b, am_b, ds_b) = setup(0.25);
        store.publish("alpha", &m_a, &am_a).unwrap();
        store.publish("bravo", &m_b, &am_b).unwrap();
        let coord = Coordinator::start_registry(
            store,
            CoordinatorConfig::default(),
        )
        .unwrap();
        let sub_a = ds_a.x.rows_slice(0, 40);
        let sub_b = ds_b.x.rows_slice(0, 30);
        let ra = coord.predict_all_for("alpha", &sub_a).unwrap();
        let rb = coord.predict_all_for("bravo", &sub_b).unwrap();
        for (r, resp) in ra.iter().enumerate() {
            let (want, _) = am_a.decision_one(sub_a.row(r));
            assert!((resp.decision - want).abs() < 1e-4);
            assert_eq!(&*resp.model, "alpha");
            assert_eq!(resp.generation, 1);
        }
        for (r, resp) in rb.iter().enumerate() {
            let (want, _) = am_b.decision_one(sub_b.row(r));
            assert!((resp.decision - want).abs() < 1e-4);
        }
        assert!(coord.submit_to("ghost", vec![0.0; 6]).is_err());
        let snap = coord.metrics();
        assert_eq!(snap.per_model.len(), 2);
        assert_eq!(snap.per_model[0].id, "alpha");
        assert_eq!(snap.per_model[0].served_total(), 40);
        assert_eq!(snap.per_model[1].served_total(), 30);
        coord.shutdown().unwrap();
    }
}
