//! The coordinator: wires ingress queue → batcher → executor → per-client
//! completion channels, owns the threads, and exposes the serving API.
//!
//! The public surface (since the `Predictor`/client redesign):
//!
//! * [`CoordinatorBuilder`] — configure and start a coordinator over one
//!   in-memory model pair ([`CoordinatorBuilder::start`]) or a whole
//!   registry ([`CoordinatorBuilder::start_registry`]).
//! * [`Client`] — a cloneable submission handle. Every clone has its own
//!   completion channel, so independent callers never steal each
//!   other's results. Completions are [`Completion`]s:
//!   `Ok(PredictResponse)` or a fail-fast `Err(PredictError)` (unknown
//!   model, dimension drift across a swap, execution failure, shutdown).
//! * [`Session`] — a scoped batch of submissions on its own private
//!   channel; [`Session::wait_all`] returns completions in submission
//!   order.
//!
//! The original `Coordinator::submit`/`submit_to`/`recv`/`predict_all`
//! methods remain as thin shims over an internal [`Client`] for one
//! release (see the deprecation notes on each); new code should hold a
//! [`Client`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::linalg::Mat;
use crate::log_warn;
use crate::registry::ModelStore;
use crate::svm::SvmModel;
use crate::{Error, Result};

use super::batcher::{run_batcher, IngressQueue};
use super::metrics::{Metrics, MetricsSnapshot};
use super::policy::PolicyTable;
use super::request::{
    Completion, ModelId, PredictError, PredictErrorKind, PredictRequest,
    PredictResponse, WorkItem, DEFAULT_MODEL,
};
use super::router::RoutePolicy;
use super::worker::{ModelSource, WorkerParams};
pub use super::worker::ExecSpec;

/// Coordinator configuration (the [`CoordinatorBuilder`] is the
/// ergonomic way to assemble one).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Default route policy; a tenant's
    /// [`super::TenantPolicy`] overrides it per model.
    pub policy: RoutePolicy,
    pub exec: ExecSpec,
    /// Default max instances per routed batch (per-tenant override:
    /// `TenantPolicy::max_batch`).
    pub max_batch: usize,
    /// Default max time a request waits for its batch to fill
    /// (per-tenant override: `TenantPolicy::max_wait`).
    pub max_wait: Duration,
    /// Ingress queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Registry mode: how often the executor revalidates a model's
    /// on-disk generation without an explicit [`Coordinator::refresh`].
    pub swap_poll: Duration,
    /// Registry mode: LRU bound on models fully resident in the
    /// executor (evicted tenants reload lazily from the store).
    pub max_resident_models: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            policy: RoutePolicy::Hybrid,
            exec: ExecSpec::Native(crate::linalg::MathBackend::Blocked),
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            swap_poll: Duration::from_millis(200),
            max_resident_models: 512,
        }
    }
}

/// Fluent construction of a [`Coordinator`].
///
/// ```text
/// let coord = CoordinatorBuilder::new()
///     .policy(RoutePolicy::Hybrid)
///     .max_batch(128)
///     .start_registry(store)?;
/// let client = coord.client();
/// ```
#[derive(Clone, Debug, Default)]
pub struct CoordinatorBuilder {
    config: CoordinatorConfig,
}

impl CoordinatorBuilder {
    pub fn new() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    /// Start from an explicit [`CoordinatorConfig`].
    pub fn from_config(config: CoordinatorConfig) -> CoordinatorBuilder {
        CoordinatorBuilder { config }
    }

    /// Default route policy (per-tenant policies override it).
    pub fn policy(mut self, policy: RoutePolicy) -> Self {
        self.config.policy = policy;
        self
    }

    /// Execution substrate (native math backend or the PJRT engine).
    pub fn exec(mut self, exec: ExecSpec) -> Self {
        self.config.exec = exec;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.config.max_batch = max_batch.max(1);
        self
    }

    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.config.max_wait = max_wait;
        self
    }

    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.config.queue_capacity = capacity.max(1);
        self
    }

    pub fn swap_poll(mut self, swap_poll: Duration) -> Self {
        self.config.swap_poll = swap_poll;
        self
    }

    pub fn max_resident_models(mut self, n: usize) -> Self {
        self.config.max_resident_models = n.max(1);
        self
    }

    /// Spawn the serving threads over one in-memory model pair, served
    /// as [`DEFAULT_MODEL`]. `exact` and `approx` must describe the
    /// same underlying model (the builder checks the dimensions agree).
    pub fn start(
        self,
        exact: SvmModel,
        approx: ApproxModel,
    ) -> Result<Coordinator> {
        if exact.dim() != approx.dim() {
            return Err(Error::Shape(format!(
                "exact dim {} vs approx dim {}",
                exact.dim(),
                approx.dim()
            )));
        }
        let dim = exact.dim();
        Coordinator::start_inner(
            ModelSource::Static { exact, approx },
            DimCheck::Static(dim),
            self.config,
        )
    }

    /// Spawn the serving threads over a model registry: any id stored
    /// in `store` can be addressed via [`Client::submit_to`], and
    /// republishing a bundle hot-swaps its weights and policy.
    pub fn start_registry(
        self,
        store: Arc<ModelStore>,
    ) -> Result<Coordinator> {
        Coordinator::start_inner(
            ModelSource::Registry { store: store.clone() },
            DimCheck::Registry { store, cache: Mutex::new(HashMap::new()) },
            self.config,
        )
    }
}

/// Per-model dimension checking at the submit boundary.
enum DimCheck {
    /// Single static model: one known dimension.
    Static(usize),
    /// Registry: dimensions read from bundle headers, cached.
    Registry { store: Arc<ModelStore>, cache: Mutex<HashMap<String, usize>> },
}

/// State shared between the [`Coordinator`] and every [`Client`].
struct Shared {
    ingress: Arc<IngressQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dims: DimCheck,
    /// Bumped by [`Coordinator::refresh`]; the executor revalidates
    /// every tenant it touches after a bump.
    epoch: Arc<AtomicU64>,
}

impl Shared {
    /// Expected feature dimension for `model` (validated at submit so
    /// shape errors surface to the caller, not inside the executor).
    fn dim_of(&self, model: &str) -> Result<usize> {
        match &self.dims {
            DimCheck::Static(d) => {
                if model == DEFAULT_MODEL {
                    Ok(*d)
                } else {
                    Err(Error::InvalidArg(format!(
                        "unknown model '{model}': this coordinator serves \
                         only '{DEFAULT_MODEL}' (use start_registry for \
                         multi-tenant serving)"
                    )))
                }
            }
            DimCheck::Registry { store, cache } => {
                if let Some(&d) = cache.lock().unwrap().get(model) {
                    return Ok(d);
                }
                let info = store.peek(model)?;
                cache
                    .lock()
                    .unwrap()
                    .insert(model.to_string(), info.dim);
                Ok(info.dim)
            }
        }
    }

    /// Validate and enqueue one instance; its completion will be
    /// delivered on `reply`.
    fn submit_with(
        &self,
        model: &str,
        features: Vec<f32>,
        reply: &Sender<Completion>,
    ) -> std::result::Result<u64, PredictError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mid: ModelId = Arc::from(model);
        let dim = self.dim_of(model).map_err(|e| {
            PredictError::new(
                id,
                mid.clone(),
                PredictErrorKind::UnknownModel { detail: e.to_string() },
            )
        })?;
        if features.len() != dim {
            return Err(PredictError::new(
                id,
                mid,
                PredictErrorKind::DimMismatch {
                    got: features.len(),
                    want: dim,
                },
            ));
        }
        let ok = self.ingress.push(PredictRequest {
            id,
            model: mid.clone(),
            features,
            enqueued_at: Instant::now(),
            reply: reply.clone(),
        });
        if ok {
            Ok(id)
        } else {
            Err(PredictError::new(id, mid, PredictErrorKind::Shutdown))
        }
    }
}

/// A cloneable submission handle onto a running [`Coordinator`].
///
/// Each `Client` (and each clone) owns a private completion channel:
/// completions for its submissions are delivered there and nowhere
/// else. Submission errors and executor-side failures are both typed
/// [`PredictError`]s, so a request that cannot be served fails fast
/// instead of timing out.
pub struct Client {
    shared: Arc<Shared>,
    reply_tx: Sender<Completion>,
    reply_rx: Mutex<Receiver<Completion>>,
}

impl Clone for Client {
    /// A clone is an independent client: same coordinator, fresh
    /// completion channel.
    fn clone(&self) -> Client {
        Client::new(self.shared.clone())
    }
}

impl Client {
    fn new(shared: Arc<Shared>) -> Client {
        let (reply_tx, reply_rx) = mpsc::channel();
        Client { shared, reply_tx, reply_rx: Mutex::new(reply_rx) }
    }

    /// Enqueue one instance for [`DEFAULT_MODEL`]; returns its request
    /// id. Blocks when the ingress queue is full (backpressure).
    pub fn submit(
        &self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Enqueue one instance for a named model.
    pub fn submit_to(
        &self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.shared.submit_with(model, features, &self.reply_tx)
    }

    /// Receive this client's next completion (any order across
    /// batches). `None` on timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        self.reply_rx.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Open a [`Session`]: a scoped group of submissions with its own
    /// completion channel and ordered [`Session::wait_all`].
    pub fn session(&self) -> Session<'_> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Session { client: self, reply_tx, reply_rx, submitted: Vec::new() }
    }

    /// Synchronous convenience: submit every row of `z` to
    /// [`DEFAULT_MODEL`] and return the responses ordered by row,
    /// failing fast on the first [`PredictError`].
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        self.predict_all_for(DEFAULT_MODEL, z)
    }

    /// [`Client::predict_all`] addressed to a named model.
    pub fn predict_all_for(
        &self,
        model: &str,
        z: &Mat,
    ) -> Result<Vec<PredictResponse>> {
        if z.rows() == 0 {
            return Err(Error::InvalidArg("empty batch".into()));
        }
        let mut session = self.session();
        for r in 0..z.rows() {
            session
                .submit_to(model, z.row(r).to_vec())
                .map_err(Error::from)?;
        }
        let completions = session.wait_all(Duration::from_secs(600))?;
        completions
            .into_iter()
            .map(|c| c.map_err(Error::from))
            .collect()
    }

    /// Serving metrics snapshot (shared across all clients).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.ingress.len()
    }
}

/// A scoped batch of submissions with a private completion channel.
///
/// Submit through the session, then call [`Session::wait_all`] to get
/// every completion in submission order — including fail-fast
/// [`PredictError`]s for requests the executor could not serve.
pub struct Session<'c> {
    client: &'c Client,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    submitted: Vec<(u64, ModelId)>,
}

impl Session<'_> {
    /// Submit one instance for [`DEFAULT_MODEL`].
    pub fn submit(
        &mut self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Submit one instance for a named model.
    pub fn submit_to(
        &mut self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        let id =
            self.client
                .shared
                .submit_with(model, features, &self.reply_tx)?;
        self.submitted.push((id, Arc::from(model)));
        Ok(id)
    }

    /// Number of submissions made through this session.
    pub fn len(&self) -> usize {
        self.submitted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.submitted.is_empty()
    }

    /// Receive this session's next completion (unordered). `None` on
    /// timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        self.reply_rx.recv_timeout(timeout).ok()
    }

    /// Wait for every submission's completion and return them in
    /// submission order. If the executor terminates, every still-
    /// pending request completes as `Err(PredictError)` with
    /// [`PredictErrorKind::Shutdown`] — callers never hang on a dead
    /// coordinator. Errors with [`Error::Other`] only if `timeout`
    /// elapses first.
    pub fn wait_all(self, timeout: Duration) -> Result<Vec<Completion>> {
        // Drop our own sender half first: once every in-flight
        // request's reply clone is gone (executor/batcher dead), the
        // receive loop must observe Disconnected rather than spin on
        // timeouts until the deadline.
        let Session { client: _, reply_tx, reply_rx, submitted } = self;
        drop(reply_tx);
        let n = submitted.len();
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(n);
        for (i, (id, _)) in submitted.iter().enumerate() {
            index.insert(*id, i);
        }
        let mut out: Vec<Option<Completion>> = vec![None; n];
        let mut got = 0usize;
        let deadline = Instant::now() + timeout;
        while got < n {
            let Some(remaining) =
                deadline.checked_duration_since(Instant::now())
            else {
                return Err(Error::Other(format!(
                    "session wait_all timed out with {got}/{n} completions"
                )));
            };
            match reply_rx.recv_timeout(remaining) {
                Ok(c) => {
                    let id = match &c {
                        Ok(resp) => resp.id,
                        Err(e) => e.id,
                    };
                    if let Some(&i) = index.get(&id) {
                        if out[i].is_none() {
                            out[i] = Some(c);
                            got += 1;
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    for (i, (id, model)) in submitted.iter().enumerate() {
                        if out[i].is_none() {
                            out[i] = Some(Err(PredictError::new(
                                *id,
                                model.clone(),
                                PredictErrorKind::Shutdown,
                            )));
                            got += 1;
                        }
                    }
                }
            }
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }
}

/// A running serving instance over one model or a whole registry.
///
/// Owns the batcher/executor threads. Hand out [`Coordinator::client`]
/// handles for submission; the coordinator itself keeps an internal
/// legacy client so the original `submit`/`recv` methods keep working
/// during the deprecation window.
pub struct Coordinator {
    shared: Arc<Shared>,
    legacy: Client,
    batcher: Option<JoinHandle<()>>,
    worker: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Fluent configuration entry point.
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::new()
    }

    /// Start over one in-memory model pair with an explicit config.
    ///
    /// Shim kept for one release: prefer
    /// [`Coordinator::builder`] → [`CoordinatorBuilder::start`].
    pub fn start(
        exact: SvmModel,
        approx: ApproxModel,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        CoordinatorBuilder::from_config(config).start(exact, approx)
    }

    /// Start over a model registry with an explicit config.
    ///
    /// Shim kept for one release: prefer
    /// [`Coordinator::builder`] → [`CoordinatorBuilder::start_registry`].
    pub fn start_registry(
        store: Arc<ModelStore>,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        CoordinatorBuilder::from_config(config).start_registry(store)
    }

    fn start_inner(
        source: ModelSource,
        dims: DimCheck,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let ingress = Arc::new(IngressQueue::new(config.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let epoch = Arc::new(AtomicU64::new(0));
        let policies = Arc::new(PolicyTable::new());
        let (work_tx, work_rx): (Sender<WorkItem>, Receiver<WorkItem>) =
            mpsc::channel();

        // Executor thread (owns predictors / PJRT engine / tenants).
        let worker_metrics = metrics.clone();
        let worker_epoch = epoch.clone();
        let spec = config.exec.clone();
        let params = WorkerParams {
            policy: config.policy,
            swap_poll: config.swap_poll,
            max_resident: config.max_resident_models,
            policies: policies.clone(),
        };
        let worker = std::thread::Builder::new()
            .name("approxrbf-executor".into())
            .spawn(move || {
                let out = super::worker::run_worker(
                    spec,
                    source,
                    params,
                    worker_epoch,
                    work_rx,
                    worker_metrics,
                );
                if let Err(ref e) = out {
                    log_warn!("executor exited with error: {e}");
                }
                out
            })
            .map_err(|e| Error::Other(format!("spawn executor: {e}")))?;

        // Batcher thread: drains ingress, groups by model id, flushes
        // each group on its tenant's max_batch/max_wait. Routing
        // happens in the executor, which owns each model's Eq. 3.11
        // budget and route policy.
        let b_ingress = ingress.clone();
        let b_policies = policies.clone();
        let (max_batch, max_wait) = (config.max_batch, config.max_wait);
        let batcher = std::thread::Builder::new()
            .name("approxrbf-batcher".into())
            .spawn(move || {
                run_batcher(b_ingress, work_tx, b_policies, max_batch, max_wait)
            })
            .map_err(|e| Error::Other(format!("spawn batcher: {e}")))?;

        let shared = Arc::new(Shared {
            ingress,
            metrics,
            next_id: AtomicU64::new(0),
            dims,
            epoch,
        });
        Ok(Coordinator {
            legacy: Client::new(shared.clone()),
            shared,
            batcher: Some(batcher),
            worker: Some(worker),
        })
    }

    /// A new independent [`Client`] handle (cheap; cloneable).
    pub fn client(&self) -> Client {
        Client::new(self.shared.clone())
    }

    /// Enqueue one instance for [`DEFAULT_MODEL`] on the coordinator's
    /// internal client.
    ///
    /// Shim kept for one release: prefer [`Client::submit`] via
    /// [`Coordinator::client`] (typed [`PredictError`]s, per-client
    /// completion channels).
    pub fn submit(&self, features: Vec<f32>) -> Result<u64> {
        self.legacy.submit(features).map_err(Error::from)
    }

    /// Enqueue one instance for a named model on the coordinator's
    /// internal client.
    ///
    /// Shim kept for one release: prefer [`Client::submit_to`].
    pub fn submit_to(&self, model: &str, features: Vec<f32>) -> Result<u64> {
        self.legacy.submit_to(model, features).map_err(Error::from)
    }

    /// Force the executor to revalidate model generations before the
    /// next batch of each tenant (hot-swap without waiting out
    /// `swap_poll`). Also drops cached dimension checks.
    pub fn refresh(&self) {
        if let DimCheck::Registry { cache, .. } = &self.shared.dims {
            cache.lock().unwrap().clear();
        }
        self.shared.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Receive the next successful response on the coordinator's
    /// internal client, silently skipping error completions (the
    /// pre-redesign drop semantics).
    ///
    /// Shim kept for one release: prefer [`Client::recv`], which
    /// surfaces [`PredictError`]s instead of hiding them.
    pub fn recv(&self, timeout: Duration) -> Option<PredictResponse> {
        let deadline = Instant::now() + timeout;
        loop {
            // saturating: a zero timeout still polls for an already-
            // delivered completion (the pre-redesign semantics).
            let remaining =
                deadline.saturating_duration_since(Instant::now());
            match self.legacy.recv(remaining) {
                Some(Ok(resp)) => return Some(resp),
                Some(Err(_)) => continue,
                None => return None,
            }
        }
    }

    /// Synchronous convenience on the internal client: every row of
    /// `z` to [`DEFAULT_MODEL`], responses ordered by row.
    ///
    /// Shim kept for one release: prefer [`Client::predict_all`].
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        self.legacy.predict_all(z)
    }

    /// [`Coordinator::predict_all`] addressed to a named model.
    ///
    /// Shim kept for one release: prefer [`Client::predict_all_for`].
    pub fn predict_all_for(
        &self,
        model: &str,
        z: &Mat,
    ) -> Result<Vec<PredictResponse>> {
        self.legacy.predict_all_for(model, z)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.ingress.len()
    }

    /// Graceful shutdown: drain, stop threads, surface executor errors.
    /// Clients that outlive the coordinator fail fast with
    /// [`PredictErrorKind::Shutdown`].
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        self.shared.ingress.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker.take() {
            match h.join() {
                Ok(r) => r?,
                Err(_) => return Err(Error::Other("executor panicked".into())),
            }
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::builder::build_approx_model;
    use crate::coordinator::Route;
    use crate::data::synth;
    use crate::linalg::MathBackend;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn setup(gamma: f32) -> (SvmModel, ApproxModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(71, 250, 6, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let (model, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        (model, am, scaled)
    }

    #[test]
    fn serves_all_requests_and_matches_direct_eval() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model.clone(),
            am.clone(),
            CoordinatorConfig::default(),
        )
        .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        assert_eq!(responses.len(), ds.len());
        for (r, resp) in responses.iter().enumerate() {
            // γ in bound ⇒ hybrid routes to approx; value must match the
            // direct approx evaluation.
            let (want, _) = am.decision_one(ds.x.row(r));
            assert_eq!(resp.route, Route::Approx);
            assert_eq!(&*resp.model, DEFAULT_MODEL);
            assert_eq!(resp.generation, 0);
            assert!(
                (resp.decision - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                resp.decision
            );
        }
        let m = coord.metrics();
        assert_eq!(m.served_approx as usize, ds.len());
        assert_eq!(m.served_exact, 0);
        assert_eq!(m.per_model.len(), 1);
        assert_eq!(m.per_model[0].id, DEFAULT_MODEL);
        assert_eq!(m.per_model[0].served_approx as usize, ds.len());
        coord.shutdown().unwrap();
    }

    #[test]
    fn builder_client_and_session_roundtrip() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .max_batch(64)
            .max_wait(Duration::from_millis(1))
            .start(model, am.clone())
            .unwrap();
        let client = coord.client();
        // Clones are independent clients (fresh channels).
        let clone = client.clone();
        let mut session = client.session();
        let n = 25usize;
        for r in 0..n {
            session.submit(ds.x.row(r).to_vec()).unwrap();
        }
        assert_eq!(session.len(), n);
        let completions =
            session.wait_all(Duration::from_secs(30)).unwrap();
        assert_eq!(completions.len(), n);
        for (r, c) in completions.iter().enumerate() {
            let resp = c.as_ref().expect("all in-bound requests succeed");
            let (want, _) = am.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-4, "row {r}");
        }
        // The clone's channel saw none of the session's completions.
        assert!(clone.recv(Duration::from_millis(10)).is_none());
        coord.shutdown().unwrap();
    }

    #[test]
    fn client_outliving_coordinator_fails_fast_with_shutdown() {
        let (model, am, ds) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        let client = coord.client();
        coord.shutdown().unwrap();
        let err = client.submit(ds.x.row(0).to_vec()).unwrap_err();
        assert_eq!(err.kind, PredictErrorKind::Shutdown);
    }

    #[test]
    fn hybrid_escorts_out_of_bound_to_exact() {
        let (model, am, ds) = setup(1.5); // γ = 6× γ_max: all out of bound
        let coord =
            Coordinator::start(model.clone(), am, CoordinatorConfig::default())
                .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(resp.route, Route::Exact, "row {r}");
            assert!(!resp.in_bound);
            let want = model.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-3);
        }
        coord.shutdown().unwrap();
    }

    #[test]
    fn always_policies_force_route() {
        let (model, am, ds) = setup(0.2);
        for (policy, want) in [
            (RoutePolicy::AlwaysExact, Route::Exact),
            (RoutePolicy::AlwaysApprox, Route::Approx),
        ] {
            let coord = Coordinator::builder()
                .policy(policy)
                .start(model.clone(), am.clone())
                .unwrap();
            let responses =
                coord.predict_all(&ds.x.rows_slice(0, 20)).unwrap();
            assert!(responses.iter().all(|r| r.route == want));
            coord.shutdown().unwrap();
        }
    }

    #[test]
    fn dim_mismatch_rejected_at_submit() {
        let (model, am, _) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        // Legacy shim keeps the crate-level error class…
        assert!(coord.submit(vec![0.0; 99]).is_err());
        // …and the client surfaces the typed kind.
        let err = coord.client().submit(vec![0.0; 99]).unwrap_err();
        assert!(
            matches!(err.kind, PredictErrorKind::DimMismatch { got: 99, .. }),
            "{err}"
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_rejected_on_static_coordinator() {
        let (model, am, ds) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        let err =
            coord.submit_to("ghost", ds.x.row(0).to_vec()).unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
        let err =
            coord.client().submit_to("ghost", ds.x.row(0).to_vec()).unwrap_err();
        assert!(
            matches!(err.kind, PredictErrorKind::UnknownModel { .. }),
            "{err}"
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(model, am, CoordinatorConfig::default())
            .unwrap();
        coord.shared.ingress.close();
        assert!(coord.submit(ds.x.row(0).to_vec()).is_err());
    }

    #[test]
    fn batching_actually_batches() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model,
            am,
            CoordinatorConfig {
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        )
        .unwrap();
        let _ = coord.predict_all(&ds.x).unwrap();
        let m = coord.metrics();
        assert!(
            m.mean_batch_size > 1.5,
            "expected dynamic batching, mean batch {}",
            m.mean_batch_size
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn registry_coordinator_serves_multiple_tenants() {
        let dir = std::env::temp_dir().join(format!(
            "approxrbf_server_registry_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(ModelStore::open(dir).unwrap());
        let (m_a, am_a, ds_a) = setup(0.2);
        let (m_b, am_b, ds_b) = setup(0.25);
        store.publish("alpha", &m_a, &am_a).unwrap();
        store.publish("bravo", &m_b, &am_b).unwrap();
        let coord = Coordinator::builder()
            .start_registry(store)
            .unwrap();
        let client = coord.client();
        let sub_a = ds_a.x.rows_slice(0, 40);
        let sub_b = ds_b.x.rows_slice(0, 30);
        let ra = client.predict_all_for("alpha", &sub_a).unwrap();
        let rb = client.predict_all_for("bravo", &sub_b).unwrap();
        for (r, resp) in ra.iter().enumerate() {
            let (want, _) = am_a.decision_one(sub_a.row(r));
            assert!((resp.decision - want).abs() < 1e-4);
            assert_eq!(&*resp.model, "alpha");
            assert_eq!(resp.generation, 1);
        }
        for (r, resp) in rb.iter().enumerate() {
            let (want, _) = am_b.decision_one(sub_b.row(r));
            assert!((resp.decision - want).abs() < 1e-4);
        }
        assert!(client.submit_to("ghost", vec![0.0; 6]).is_err());
        let snap = coord.metrics();
        assert_eq!(snap.per_model.len(), 2);
        assert_eq!(snap.per_model[0].id, "alpha");
        assert_eq!(snap.per_model[0].served_total(), 40);
        assert_eq!(snap.per_model[1].served_total(), 30);
        coord.shutdown().unwrap();
    }
}
