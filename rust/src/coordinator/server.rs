//! The coordinator: wires ingress queue → batcher → router → executor →
//! response channel, owns the threads, and exposes the public serving
//! API ([`Coordinator::submit`] / [`Coordinator::recv`] /
//! [`Coordinator::predict_all`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::log_warn;
use crate::linalg::Mat;
use crate::svm::SvmModel;
use crate::{Error, Result};

use super::batcher::IngressQueue;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{PredictRequest, PredictResponse, Route, WorkItem};
use super::router::{RoutePolicy, Router};
pub use super::worker::ExecSpec;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub policy: RoutePolicy,
    pub exec: ExecSpec,
    /// Max instances per routed batch.
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Ingress queue capacity (backpressure threshold).
    pub queue_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            policy: RoutePolicy::Hybrid,
            exec: ExecSpec::Native(crate::linalg::MathBackend::Blocked),
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
        }
    }
}

/// A running serving instance over one (exact, approx) model pair.
pub struct Coordinator {
    ingress: Arc<IngressQueue>,
    resp_rx: Mutex<Receiver<PredictResponse>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dim: usize,
    batcher: Option<JoinHandle<()>>,
    worker: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Spawn the serving threads. `exact` and `approx` must describe the
    /// same underlying model (the builder guarantees this).
    pub fn start(
        exact: SvmModel,
        approx: ApproxModel,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        if exact.dim() != approx.dim() {
            return Err(Error::Shape(format!(
                "exact dim {} vs approx dim {}",
                exact.dim(),
                approx.dim()
            )));
        }
        let dim = exact.dim();
        // The router only needs the scalar budget; capture it before the
        // models move into the executor thread.
        let router = Router {
            policy: config.policy,
            znorm_sq_budget: approx.znorm_sq_budget(),
        };
        let ingress = Arc::new(IngressQueue::new(config.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let (work_tx, work_rx): (Sender<WorkItem>, Receiver<WorkItem>) =
            mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();

        // Executor thread (owns predictors / PJRT engine).
        let worker_metrics = metrics.clone();
        let spec = config.exec.clone();
        let worker = std::thread::Builder::new()
            .name("approxrbf-executor".into())
            .spawn(move || {
                let out = super::worker::run_worker(
                    spec,
                    exact,
                    approx,
                    work_rx,
                    resp_tx,
                    worker_metrics,
                );
                if let Err(ref e) = out {
                    log_warn!("executor exited with error: {e}");
                }
                out
            })
            .map_err(|e| Error::Other(format!("spawn executor: {e}")))?;

        // Batcher thread (drains ingress, routes, forwards).
        let b_ingress = ingress.clone();
        let (max_batch, max_wait) = (config.max_batch, config.max_wait);
        let batcher = std::thread::Builder::new()
            .name("approxrbf-batcher".into())
            .spawn(move || {
                loop {
                    match b_ingress.pop_batch(max_batch, max_wait) {
                        None => {
                            let _ = work_tx.send(WorkItem::Shutdown);
                            break;
                        }
                        Some(batch) if batch.is_empty() => continue,
                        Some(batch) => {
                            let mut approx_reqs = Vec::new();
                            let mut exact_reqs = Vec::new();
                            for req in batch {
                                let (route, _, _) =
                                    router.route(&req.features);
                                match route {
                                    Route::Approx => approx_reqs.push(req),
                                    Route::Exact => exact_reqs.push(req),
                                }
                            }
                            if !approx_reqs.is_empty()
                                && work_tx
                                    .send(WorkItem::Batch {
                                        route: Route::Approx,
                                        requests: approx_reqs,
                                    })
                                    .is_err()
                            {
                                break;
                            }
                            if !exact_reqs.is_empty()
                                && work_tx
                                    .send(WorkItem::Batch {
                                        route: Route::Exact,
                                        requests: exact_reqs,
                                    })
                                    .is_err()
                            {
                                break;
                            }
                        }
                    }
                }
            })
            .map_err(|e| Error::Other(format!("spawn batcher: {e}")))?;

        Ok(Coordinator {
            ingress,
            resp_rx: Mutex::new(resp_rx),
            metrics,
            next_id: AtomicU64::new(0),
            dim,
            batcher: Some(batcher),
            worker: Some(worker),
        })
    }

    /// Enqueue one instance; returns its request id. Blocks when the
    /// ingress queue is full (backpressure).
    pub fn submit(&self, features: Vec<f32>) -> Result<u64> {
        if features.len() != self.dim {
            return Err(Error::Shape(format!(
                "instance dim {} vs model dim {}",
                features.len(),
                self.dim
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let ok = self.ingress.push(PredictRequest {
            id,
            features,
            enqueued_at: Instant::now(),
        });
        if ok {
            Ok(id)
        } else {
            Err(Error::Other("coordinator is shut down".into()))
        }
    }

    /// Receive the next completed response (any order across batches).
    pub fn recv(&self, timeout: Duration) -> Option<PredictResponse> {
        self.recv_inner(timeout).ok()
    }

    fn recv_inner(
        &self,
        timeout: Duration,
    ) -> std::result::Result<PredictResponse, RecvTimeoutError> {
        self.resp_rx.lock().unwrap().recv_timeout(timeout)
    }

    /// Convenience synchronous API: submit every row of `z`, wait for
    /// all responses, return them ordered by row.
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        let n = z.rows();
        let mut first_id = None;
        for r in 0..n {
            let id = self.submit(z.row(r).to_vec())?;
            if r == 0 {
                first_id = Some(id);
            }
        }
        let first_id = first_id.ok_or_else(|| {
            Error::InvalidArg("empty batch".into())
        })?;
        let mut out: Vec<Option<PredictResponse>> = vec![None; n];
        let mut got = 0;
        let deadline = Instant::now() + Duration::from_secs(600);
        while got < n {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| Error::Other("predict_all timed out".into()))?;
            // Poll in short steps so a slow first batch (e.g. lazy XLA
            // compilation) is not misread as a dead executor; a truly
            // disconnected channel (executor died) errors immediately.
            let resp = match self
                .recv_inner(remaining.min(Duration::from_millis(200)))
            {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Other(
                        "executor thread terminated".into(),
                    ))
                }
            };
            let idx = (resp.id - first_id) as usize;
            if idx < n && out[idx].is_none() {
                out[idx] = Some(resp);
                got += 1;
            }
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.ingress.len()
    }

    /// Graceful shutdown: drain, stop threads, surface executor errors.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        self.ingress.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker.take() {
            match h.join() {
                Ok(r) => r?,
                Err(_) => return Err(Error::Other("executor panicked".into())),
            }
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::builder::build_approx_model;
    use crate::data::synth;
    use crate::linalg::MathBackend;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn setup(gamma: f32) -> (SvmModel, ApproxModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(71, 250, 6, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let (model, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        (model, am, scaled)
    }

    #[test]
    fn serves_all_requests_and_matches_direct_eval() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model.clone(),
            am.clone(),
            CoordinatorConfig::default(),
        )
        .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        assert_eq!(responses.len(), ds.len());
        for (r, resp) in responses.iter().enumerate() {
            // γ in bound ⇒ hybrid routes to approx; value must match the
            // direct approx evaluation.
            let (want, _) = am.decision_one(ds.x.row(r));
            assert_eq!(resp.route, Route::Approx);
            assert!(
                (resp.decision - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                resp.decision
            );
        }
        let m = coord.metrics();
        assert_eq!(m.served_approx as usize, ds.len());
        assert_eq!(m.served_exact, 0);
        coord.shutdown().unwrap();
    }

    #[test]
    fn hybrid_escorts_out_of_bound_to_exact() {
        let (model, am, ds) = setup(1.5); // γ = 6× γ_max: all out of bound
        let coord =
            Coordinator::start(model.clone(), am, CoordinatorConfig::default())
                .unwrap();
        let responses = coord.predict_all(&ds.x).unwrap();
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(resp.route, Route::Exact, "row {r}");
            assert!(!resp.in_bound);
            let want = model.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-3);
        }
        coord.shutdown().unwrap();
    }

    #[test]
    fn always_policies_force_route() {
        let (model, am, ds) = setup(0.2);
        for (policy, want) in [
            (RoutePolicy::AlwaysExact, Route::Exact),
            (RoutePolicy::AlwaysApprox, Route::Approx),
        ] {
            let coord = Coordinator::start(
                model.clone(),
                am.clone(),
                CoordinatorConfig { policy, ..Default::default() },
            )
            .unwrap();
            let responses =
                coord.predict_all(&ds.x.rows_slice(0, 20)).unwrap();
            assert!(responses.iter().all(|r| r.route == want));
            coord.shutdown().unwrap();
        }
    }

    #[test]
    fn dim_mismatch_rejected_at_submit() {
        let (model, am, _) = setup(0.2);
        let coord =
            Coordinator::start(model, am, CoordinatorConfig::default())
                .unwrap();
        assert!(coord.submit(vec![0.0; 99]).is_err());
        coord.shutdown().unwrap();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(model, am, CoordinatorConfig::default())
            .unwrap();
        coord.ingress.close();
        assert!(coord.submit(ds.x.row(0).to_vec()).is_err());
    }

    #[test]
    fn batching_actually_batches() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::start(
            model,
            am,
            CoordinatorConfig {
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        )
        .unwrap();
        let _ = coord.predict_all(&ds.x).unwrap();
        let m = coord.metrics();
        assert!(
            m.mean_batch_size > 1.5,
            "expected dynamic batching, mean batch {}",
            m.mean_batch_size
        );
        coord.shutdown().unwrap();
    }
}
