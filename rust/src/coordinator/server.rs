//! The coordinator: a sharded serving plane behind a stable client API.
//!
//! [`CoordinatorBuilder::shards`]`(n)` spins up a
//! `ShardSet` ([`super::shard`]) of `n` executor lanes — each with its own
//! ingress queue, batcher, resident-model LRU and
//! [`crate::predictor::Predictor`] instances — and the public surface
//! stays exactly the client API:
//!
//! * [`CoordinatorBuilder`] — configure and start a coordinator over one
//!   in-memory model pair ([`CoordinatorBuilder::start`]) or a whole
//!   registry ([`CoordinatorBuilder::start_registry`]).
//! * [`Client`] — the **only ingress**: a cloneable submission handle.
//!   Every clone has its own completion channel, so independent callers
//!   never steal each other's results. Submission places the request on
//!   its model's owning shard (rendezvous hashing on the model id, see
//!   [`super::shard::assign`]); completions fan back in on the
//!   submitting client's channel. Completions are [`Completion`]s:
//!   `Ok(PredictResponse)` or a fail-fast `Err(PredictError)` (unknown
//!   model, dimension drift across a swap, execution failure, shutdown).
//! * [`Session`] — a scoped batch of submissions on its own private
//!   channel; [`Session::wait_all`] returns completions in submission
//!   order even when several shards complete into it concurrently.
//!
//! The pre-redesign `Coordinator::submit*`/`recv`/`predict_all*`
//! methods (and the `Coordinator::start*` constructors) were removed in
//! this release after their one-release deprecation window; hold a
//! [`Client`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::approx::ApproxModel;
use crate::linalg::Mat;
use crate::log_warn;
use crate::registry::ModelStore;
use crate::svm::SvmModel;
use crate::{Error, Result};

use super::batcher::IngressQueue;
use crate::util::sync::lock_unpoisoned;
use super::metrics::{Metrics, MetricsSnapshot, MetricsState};
use super::request::{
    Completion, ModelId, PredictError, PredictErrorKind, PredictRequest,
    PredictResponse, DEFAULT_MODEL,
};
use super::router::RoutePolicy;
use super::shard::{assign, ShardSet};
use super::worker::ModelSource;
pub use super::worker::ExecSpec;

/// Default shard count: the `APPROXRBF_TEST_SHARDS` environment
/// variable when set (the CI `tier1-sharded` job runs the whole suite
/// at 4), else 1. An explicit [`CoordinatorBuilder::shards`] always
/// wins. The override is logged once so a production embedder with a
/// leaked test environment can see why their plane is sharded.
fn default_shards() -> usize {
    let n = std::env::var("APPROXRBF_TEST_SHARDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.clamp(1, 64))
        .unwrap_or(1);
    if n != 1 {
        static ANNOUNCED: std::sync::Once = std::sync::Once::new();
        ANNOUNCED.call_once(|| {
            log_warn!(
                "coordinator: APPROXRBF_TEST_SHARDS={n} overrides the \
                 default shard count (builder .shards() still wins)"
            );
        });
    }
    n
}

/// Coordinator configuration (the [`CoordinatorBuilder`] is the
/// ergonomic way to assemble one).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Default route policy; a tenant's
    /// [`super::TenantPolicy`] overrides it per model.
    pub policy: RoutePolicy,
    pub exec: ExecSpec,
    /// Default max instances per routed batch (per-tenant override:
    /// `TenantPolicy::max_batch`).
    pub max_batch: usize,
    /// Default max time a request waits for its batch to fill
    /// (per-tenant override: `TenantPolicy::max_wait`).
    pub max_wait: Duration,
    /// Per-shard ingress queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Registry mode: how often each shard's executor revalidates a
    /// model's on-disk generation without an explicit
    /// [`Coordinator::refresh`]. A detected republish is decoded off
    /// the hot path (shard prefetch) and swapped in atomically.
    pub swap_poll: Duration,
    /// Plane-wide residency target: each shard's executor is capped at
    /// its even share of this plus 25% headroom (rendezvous ownership
    /// is binomial, not exact), so worst-case total residency is
    /// 1.25× this value. Evicted tenants reload lazily from the store.
    pub max_resident_models: usize,
    /// Number of executor lanes. Tenants are placed by rendezvous
    /// hashing on the model id, so every model's batches stay on one
    /// shard. Defaults to `APPROXRBF_TEST_SHARDS` (else 1).
    pub shards: usize,
    /// Registry mode: pre-decode each shard's owned tenants at startup
    /// (shard-aware warm; see
    /// [`crate::registry::ModelStore::warm_where`]).
    pub warm_start: bool,
    /// Max absolute decision drift a quantized (f16/int8) tenant's
    /// dequantization may add before its Hybrid router escorts the
    /// instance to the exact path — folded into each tenant's Eq. 3.11
    /// budget (see [`crate::registry::ModelEntry::znorm_sq_budget_with`]).
    /// Irrelevant for f32 tenants. Default:
    /// [`crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL`].
    pub quant_drift_tol: f32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            policy: RoutePolicy::Hybrid,
            exec: ExecSpec::Native(crate::linalg::MathBackend::Blocked),
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            swap_poll: Duration::from_millis(200),
            max_resident_models: 512,
            shards: default_shards(),
            warm_start: false,
            quant_drift_tol: crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL,
        }
    }
}

/// Fluent construction of a [`Coordinator`].
///
/// ```text
/// let coord = CoordinatorBuilder::new()
///     .policy(RoutePolicy::Hybrid)
///     .shards(4)
///     .start_registry(store)?;
/// let client = coord.client();
/// ```
#[derive(Clone, Debug, Default)]
pub struct CoordinatorBuilder {
    config: CoordinatorConfig,
}

impl CoordinatorBuilder {
    pub fn new() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    /// Start from an explicit [`CoordinatorConfig`].
    pub fn from_config(config: CoordinatorConfig) -> CoordinatorBuilder {
        CoordinatorBuilder { config }
    }

    /// Default route policy (per-tenant policies override it).
    pub fn policy(mut self, policy: RoutePolicy) -> Self {
        self.config.policy = policy;
        self
    }

    /// Execution substrate (native math backend or the PJRT engine).
    pub fn exec(mut self, exec: ExecSpec) -> Self {
        self.config.exec = exec;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.config.max_batch = max_batch.max(1);
        self
    }

    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.config.max_wait = max_wait;
        self
    }

    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.config.queue_capacity = capacity.max(1);
        self
    }

    pub fn swap_poll(mut self, swap_poll: Duration) -> Self {
        self.config.swap_poll = swap_poll;
        self
    }

    pub fn max_resident_models(mut self, n: usize) -> Self {
        self.config.max_resident_models = n.max(1);
        self
    }

    /// Number of executor lanes ([`super::shard`]). Overrides the
    /// `APPROXRBF_TEST_SHARDS` default.
    pub fn shards(mut self, n: usize) -> Self {
        self.config.shards = n.clamp(1, 64);
        self
    }

    /// Registry mode: pre-decode each shard's owned tenants at startup
    /// so first requests skip the cold `.arbf` decode.
    pub fn warm_start(mut self, warm: bool) -> Self {
        self.config.warm_start = warm;
        self
    }

    /// Quantization drift tolerance folded into quantized tenants'
    /// routing budgets (see [`CoordinatorConfig::quant_drift_tol`]).
    pub fn quant_drift_tol(mut self, tol: f32) -> Self {
        self.config.quant_drift_tol = tol.max(0.0);
        self
    }

    /// Spawn the serving plane over one in-memory model pair, served
    /// as [`DEFAULT_MODEL`]. `exact` and `approx` must describe the
    /// same underlying model (the builder checks the dimensions agree).
    pub fn start(
        self,
        exact: SvmModel,
        approx: ApproxModel,
    ) -> Result<Coordinator> {
        if exact.dim() != approx.dim() {
            return Err(Error::Shape(format!(
                "exact dim {} vs approx dim {}",
                exact.dim(),
                approx.dim()
            )));
        }
        let dim = exact.dim();
        Coordinator::start_inner(
            ModelSource::Static { exact, approx },
            DimCheck::Static(dim),
            self.config,
        )
    }

    /// Spawn the serving plane over a model registry: any id stored
    /// in `store` can be addressed via [`Client::submit_to`], and
    /// republishing a bundle hot-swaps its weights and policy on the
    /// owning shard.
    pub fn start_registry(
        self,
        store: Arc<ModelStore>,
    ) -> Result<Coordinator> {
        Coordinator::start_inner(
            ModelSource::Registry { store: store.clone() },
            DimCheck::Registry { store, cache: Mutex::new(HashMap::new()) },
            self.config,
        )
    }
}

/// Per-model dimension checking at the submit boundary.
enum DimCheck {
    /// Single static model: one known dimension.
    Static(usize),
    /// Registry: dimensions read from bundle headers, cached.
    Registry { store: Arc<ModelStore>, cache: Mutex<HashMap<String, usize>> },
}

/// State shared between the [`Coordinator`] and every [`Client`].
struct Shared {
    /// Per-shard ingress queues, indexed by [`assign`] output.
    ingresses: Vec<Arc<IngressQueue>>,
    /// Per-shard metrics sinks, fanned in by [`Metrics::aggregate`].
    metrics: Vec<Arc<Metrics>>,
    next_id: AtomicU64,
    dims: DimCheck,
    /// Bumped by [`Coordinator::refresh`]; every shard's executor
    /// revalidates the tenants it touches after a bump.
    epoch: Arc<AtomicU64>,
}

impl Shared {
    /// Expected feature dimension for `model` (validated at submit so
    /// shape errors surface to the caller, not inside an executor).
    fn dim_of(&self, model: &str) -> Result<usize> {
        match &self.dims {
            DimCheck::Static(d) => {
                if model == DEFAULT_MODEL {
                    Ok(*d)
                } else {
                    Err(Error::InvalidArg(format!(
                        "unknown model '{model}': this coordinator serves \
                         only '{DEFAULT_MODEL}' (use start_registry for \
                         multi-tenant serving)"
                    )))
                }
            }
            DimCheck::Registry { store, cache } => {
                if let Some(&d) = lock_unpoisoned(cache).get(model) {
                    return Ok(d);
                }
                let info = store.peek(model)?;
                lock_unpoisoned(cache)
                    .insert(model.to_string(), info.dim);
                Ok(info.dim)
            }
        }
    }

    /// Validate and enqueue one instance on its model's owning shard;
    /// its completion will be delivered on `reply`.
    fn submit_with(
        &self,
        model: &str,
        features: Vec<f32>,
        reply: &Sender<Completion>,
    ) -> std::result::Result<u64, PredictError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mid: ModelId = Arc::from(model);
        let dim = self.dim_of(model).map_err(|e| {
            PredictError::new(
                id,
                mid.clone(),
                PredictErrorKind::UnknownModel { detail: e.to_string() },
            )
        })?;
        if features.len() != dim {
            return Err(PredictError::new(
                id,
                mid,
                PredictErrorKind::DimMismatch {
                    got: features.len(),
                    want: dim,
                },
            ));
        }
        let shard = assign(model, self.ingresses.len());
        let ok = self.ingresses[shard].push(PredictRequest {
            id,
            model: mid.clone(),
            features,
            enqueued_at: Instant::now(),
            reply: reply.clone(),
        });
        if ok {
            Ok(id)
        } else {
            Err(PredictError::new(id, mid, PredictErrorKind::Shutdown))
        }
    }

    /// Sample each lane's ingress backlog into its sink's queue-depth
    /// gauge, so every snapshot (local or exported over the wire)
    /// carries the backlog observed at snapshot time.
    fn sample_queue_gauges(&self) {
        for (q, m) in self.ingresses.iter().zip(&self.metrics) {
            m.set_queue_depth(q.len());
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.sample_queue_gauges();
        let refs: Vec<&Metrics> =
            self.metrics.iter().map(|m| &**m).collect();
        Metrics::aggregate(&refs)
    }

    fn metrics_states(&self) -> Vec<MetricsState> {
        self.sample_queue_gauges();
        self.metrics.iter().map(|m| m.export_state()).collect()
    }

    fn queue_depth(&self) -> usize {
        self.ingresses.iter().map(|q| q.len()).sum()
    }
}

/// A cloneable submission handle onto a running [`Coordinator`] — the
/// crate's only serving ingress.
///
/// Each `Client` (and each clone) owns a private completion channel:
/// completions for its submissions are delivered there and nowhere
/// else, regardless of which shard served them. Submission errors and
/// executor-side failures are both typed [`PredictError`]s, so a
/// request that cannot be served fails fast instead of timing out.
pub struct Client {
    shared: Arc<Shared>,
    reply_tx: Sender<Completion>,
    reply_rx: Mutex<Receiver<Completion>>,
}

impl Clone for Client {
    /// A clone is an independent client: same coordinator, fresh
    /// completion channel.
    fn clone(&self) -> Client {
        Client::new(self.shared.clone())
    }
}

impl Client {
    fn new(shared: Arc<Shared>) -> Client {
        let (reply_tx, reply_rx) = mpsc::channel();
        Client { shared, reply_tx, reply_rx: Mutex::new(reply_rx) }
    }

    /// Enqueue one instance for [`DEFAULT_MODEL`]; returns its request
    /// id. Blocks when the owning shard's ingress queue is full
    /// (backpressure).
    pub fn submit(
        &self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Enqueue one instance for a named model.
    pub fn submit_to(
        &self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.shared.submit_with(model, features, &self.reply_tx)
    }

    /// Receive this client's next completion (any order across
    /// batches). `None` on timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        lock_unpoisoned(&self.reply_rx).recv_timeout(timeout).ok()
    }

    /// Open a [`Session`]: a scoped group of submissions with its own
    /// completion channel and ordered [`Session::wait_all`].
    pub fn session(&self) -> Session<'_> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Session { client: self, reply_tx, reply_rx, submitted: Vec::new() }
    }

    /// Synchronous convenience: submit every row of `z` to
    /// [`DEFAULT_MODEL`] and return the responses ordered by row,
    /// failing fast on the first [`PredictError`].
    pub fn predict_all(&self, z: &Mat) -> Result<Vec<PredictResponse>> {
        self.predict_all_for(DEFAULT_MODEL, z)
    }

    /// [`Client::predict_all`] addressed to a named model.
    pub fn predict_all_for(
        &self,
        model: &str,
        z: &Mat,
    ) -> Result<Vec<PredictResponse>> {
        if z.rows() == 0 {
            return Err(Error::InvalidArg("empty batch".into()));
        }
        let mut session = self.session();
        for r in 0..z.rows() {
            session
                .submit_to(model, z.row(r).to_vec())
                .map_err(Error::from)?;
        }
        let completions = session.wait_all(Duration::from_secs(600))?;
        completions
            .into_iter()
            .map(|c| c.map_err(Error::from))
            .collect()
    }

    /// Serving metrics snapshot, aggregated across every shard (shared
    /// by all clients).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics()
    }

    /// Requests queued across every shard's ingress.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth()
    }
}

/// A scoped batch of submissions with a private completion channel.
///
/// Submit through the session, then call [`Session::wait_all`] to get
/// every completion in submission order — including fail-fast
/// [`PredictError`]s for requests the executors could not serve.
pub struct Session<'c> {
    client: &'c Client,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    submitted: Vec<(u64, ModelId)>,
}

impl Session<'_> {
    /// Submit one instance for [`DEFAULT_MODEL`].
    pub fn submit(
        &mut self,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        self.submit_to(DEFAULT_MODEL, features)
    }

    /// Submit one instance for a named model.
    pub fn submit_to(
        &mut self,
        model: &str,
        features: Vec<f32>,
    ) -> std::result::Result<u64, PredictError> {
        let id =
            self.client
                .shared
                .submit_with(model, features, &self.reply_tx)?;
        self.submitted.push((id, Arc::from(model)));
        Ok(id)
    }

    /// Number of submissions made through this session.
    pub fn len(&self) -> usize {
        self.submitted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.submitted.is_empty()
    }

    /// Receive this session's next completion (unordered). `None` on
    /// timeout.
    pub fn recv(&self, timeout: Duration) -> Option<Completion> {
        self.reply_rx.recv_timeout(timeout).ok()
    }

    /// Wait for every submission's completion and return them in
    /// submission order. If the executors terminate, every still-
    /// pending request completes as `Err(PredictError)` with
    /// [`PredictErrorKind::Shutdown`] — callers never hang on a dead
    /// coordinator. Errors with [`Error::Other`] only if `timeout`
    /// elapses first.
    pub fn wait_all(self, timeout: Duration) -> Result<Vec<Completion>> {
        // Drop our own sender half first: once every in-flight
        // request's reply clone is gone (executors/batchers dead), the
        // receive loop must observe Disconnected rather than spin on
        // timeouts until the deadline.
        let Session { client: _, reply_tx, reply_rx, submitted } = self;
        drop(reply_tx);
        let n = submitted.len();
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(n);
        for (i, (id, _)) in submitted.iter().enumerate() {
            index.insert(*id, i);
        }
        let mut out: Vec<Option<Completion>> = vec![None; n];
        let mut got = 0usize;
        let deadline = Instant::now() + timeout;
        while got < n {
            let Some(remaining) =
                deadline.checked_duration_since(Instant::now())
            else {
                return Err(Error::Other(format!(
                    "session wait_all timed out with {got}/{n} completions"
                )));
            };
            match reply_rx.recv_timeout(remaining) {
                Ok(c) => {
                    let id = match &c {
                        Ok(resp) => resp.id,
                        Err(e) => e.id,
                    };
                    if let Some(&i) = index.get(&id) {
                        if out[i].is_none() {
                            out[i] = Some(c);
                            got += 1;
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    for (i, (id, model)) in submitted.iter().enumerate() {
                        if out[i].is_none() {
                            out[i] = Some(Err(PredictError::new(
                                *id,
                                model.clone(),
                                PredictErrorKind::Shutdown,
                            )));
                            got += 1;
                        }
                    }
                }
            }
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }
}

/// A running serving plane over one model or a whole registry.
///
/// Owns the `ShardSet` (per-shard batcher/executor threads). Hand out
/// [`Coordinator::client`] handles for submission — the coordinator
/// itself has no submit surface.
pub struct Coordinator {
    shared: Arc<Shared>,
    shards: ShardSet,
    finished: bool,
}

impl Coordinator {
    /// Fluent configuration entry point.
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::new()
    }

    fn start_inner(
        source: ModelSource,
        dims: DimCheck,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let epoch = Arc::new(AtomicU64::new(0));
        let shards = ShardSet::spawn(&config, &source, &epoch)?;
        let shared = Arc::new(Shared {
            ingresses: shards.ingresses(),
            metrics: shards.metrics(),
            next_id: AtomicU64::new(0),
            dims,
            epoch,
        });
        Ok(Coordinator { shared, shards, finished: false })
    }

    /// A new independent [`Client`] handle (cheap; cloneable).
    pub fn client(&self) -> Client {
        Client::new(self.shared.clone())
    }

    /// Number of executor lanes in the plane.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Force every shard's executor to revalidate model generations
    /// before the next batch of each tenant (hot-swap without waiting
    /// out `swap_poll`). Also drops cached dimension checks.
    pub fn refresh(&self) {
        if let DimCheck::Registry { cache, .. } = &self.shared.dims {
            lock_unpoisoned(cache).clear();
        }
        self.shared.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Metrics snapshot aggregated across every shard.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics()
    }

    /// Transport seam for the network tier ([`crate::net`]): validate
    /// and enqueue one instance for `model`, delivering its completion
    /// on `reply` — the same path [`Client::submit_to`] takes, minus
    /// the client-owned channel.
    pub(crate) fn submit_with(
        &self,
        model: &str,
        features: Vec<f32>,
        reply: &Sender<Completion>,
    ) -> std::result::Result<u64, PredictError> {
        self.shared.submit_with(model, features, reply)
    }

    /// Per-lane transportable metrics states (one per shard, in shard
    /// order) for the network tier: a shard server answers a metrics
    /// pull with these, and the router rebuilds sinks via
    /// [`Metrics::from_state`] and fans them all into one
    /// [`Metrics::aggregate`].
    pub(crate) fn metrics_states(&self) -> Vec<MetricsState> {
        self.shared.metrics_states()
    }

    /// Requests queued across every shard's ingress.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth()
    }

    /// Graceful shutdown: drain every shard, stop its threads, surface
    /// executor errors. Clients that outlive the coordinator fail fast
    /// with [`PredictErrorKind::Shutdown`].
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        self.shards.shutdown()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::builder::build_approx_model;
    use crate::coordinator::Route;
    use crate::data::synth;
    use crate::linalg::MathBackend;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn setup(gamma: f32) -> (SvmModel, ApproxModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(71, 250, 6, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let (model, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        (model, am, scaled)
    }

    #[test]
    fn serves_all_requests_and_matches_direct_eval() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder()
            .start(model.clone(), am.clone())
            .unwrap();
        let client = coord.client();
        let responses = client.predict_all(&ds.x).unwrap();
        assert_eq!(responses.len(), ds.len());
        for (r, resp) in responses.iter().enumerate() {
            // γ in bound ⇒ hybrid routes to approx; value must match the
            // direct approx evaluation.
            let (want, _) = am.decision_one(ds.x.row(r));
            assert_eq!(resp.route, Route::Approx);
            assert_eq!(&*resp.model, DEFAULT_MODEL);
            assert_eq!(resp.generation, 0);
            assert!(
                (resp.decision - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                resp.decision
            );
        }
        let m = coord.metrics();
        assert_eq!(m.served_approx as usize, ds.len());
        assert_eq!(m.served_exact, 0);
        assert_eq!(m.per_model.len(), 1);
        assert_eq!(m.per_model[0].id, DEFAULT_MODEL);
        assert_eq!(m.per_model[0].served_approx as usize, ds.len());
        coord.shutdown().unwrap();
    }

    #[test]
    fn builder_client_and_session_roundtrip() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .max_batch(64)
            .max_wait(Duration::from_millis(1))
            .start(model, am.clone())
            .unwrap();
        let client = coord.client();
        // Clones are independent clients (fresh channels).
        let clone = client.clone();
        let mut session = client.session();
        let n = 25usize;
        for r in 0..n {
            session.submit(ds.x.row(r).to_vec()).unwrap();
        }
        assert_eq!(session.len(), n);
        let completions =
            session.wait_all(Duration::from_secs(30)).unwrap();
        assert_eq!(completions.len(), n);
        for (r, c) in completions.iter().enumerate() {
            let resp = c.as_ref().expect("all in-bound requests succeed");
            let (want, _) = am.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-4, "row {r}");
        }
        // The clone's channel saw none of the session's completions.
        assert!(clone.recv(Duration::from_millis(10)).is_none());
        coord.shutdown().unwrap();
    }

    #[test]
    fn client_outliving_coordinator_fails_fast_with_shutdown() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder().start(model, am).unwrap();
        let client = coord.client();
        coord.shutdown().unwrap();
        let err = client.submit(ds.x.row(0).to_vec()).unwrap_err();
        assert_eq!(err.kind, PredictErrorKind::Shutdown);
    }

    #[test]
    fn hybrid_escorts_out_of_bound_to_exact() {
        let (model, am, ds) = setup(1.5); // γ = 6× γ_max: all out of bound
        let coord = Coordinator::builder()
            .start(model.clone(), am)
            .unwrap();
        let client = coord.client();
        let responses = client.predict_all(&ds.x).unwrap();
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(resp.route, Route::Exact, "row {r}");
            assert!(!resp.in_bound);
            let want = model.decision_one(ds.x.row(r));
            assert!((resp.decision - want).abs() < 1e-3);
        }
        coord.shutdown().unwrap();
    }

    #[test]
    fn always_policies_force_route() {
        let (model, am, ds) = setup(0.2);
        for (policy, want) in [
            (RoutePolicy::AlwaysExact, Route::Exact),
            (RoutePolicy::AlwaysApprox, Route::Approx),
        ] {
            let coord = Coordinator::builder()
                .policy(policy)
                .start(model.clone(), am.clone())
                .unwrap();
            let responses = coord
                .client()
                .predict_all(&ds.x.rows_slice(0, 20))
                .unwrap();
            assert!(responses.iter().all(|r| r.route == want));
            coord.shutdown().unwrap();
        }
    }

    #[test]
    fn dim_mismatch_rejected_at_submit() {
        let (model, am, _) = setup(0.2);
        let coord = Coordinator::builder().start(model, am).unwrap();
        let err = coord.client().submit(vec![0.0; 99]).unwrap_err();
        assert!(
            matches!(err.kind, PredictErrorKind::DimMismatch { got: 99, .. }),
            "{err}"
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_rejected_on_static_coordinator() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder().start(model, am).unwrap();
        let err = coord
            .client()
            .submit_to("ghost", ds.x.row(0).to_vec())
            .unwrap_err();
        assert!(
            matches!(err.kind, PredictErrorKind::UnknownModel { .. }),
            "{err}"
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder().start(model, am).unwrap();
        let client = coord.client();
        for q in &coord.shared.ingresses {
            q.close();
        }
        let err = client.submit(ds.x.row(0).to_vec()).unwrap_err();
        assert_eq!(err.kind, PredictErrorKind::Shutdown);
    }

    #[test]
    fn batching_actually_batches() {
        let (model, am, ds) = setup(0.2);
        let coord = Coordinator::builder()
            .max_wait(Duration::from_millis(20))
            .start(model, am)
            .unwrap();
        let _ = coord.client().predict_all(&ds.x).unwrap();
        let m = coord.metrics();
        assert!(
            m.mean_batch_size > 1.5,
            "expected dynamic batching, mean batch {}",
            m.mean_batch_size
        );
        coord.shutdown().unwrap();
    }

    #[test]
    fn sharded_static_plane_serves_identically_to_single_shard() {
        // The static pair lives on exactly one shard (rendezvous on
        // DEFAULT_MODEL); the other lanes idle. Decisions must be
        // bit-identical to the unsharded plane.
        let (model, am, ds) = setup(0.2);
        let sub = ds.x.rows_slice(0, 40);
        let single = Coordinator::builder()
            .shards(1)
            .start(model.clone(), am.clone())
            .unwrap();
        let sharded = Coordinator::builder()
            .shards(3)
            .start(model, am)
            .unwrap();
        assert_eq!(sharded.shard_count(), 3);
        let r1 = single.client().predict_all(&sub).unwrap();
        let r3 = sharded.client().predict_all(&sub).unwrap();
        for (a, b) in r1.iter().zip(&r3) {
            assert_eq!(a.decision.to_bits(), b.decision.to_bits());
            assert_eq!(a.route, b.route);
        }
        let m = sharded.metrics();
        assert_eq!(m.shard_count, 3);
        assert_eq!(m.per_model.len(), 1);
        assert_eq!(m.per_model[0].shards.len(), 1, "one owning shard");
        single.shutdown().unwrap();
        sharded.shutdown().unwrap();
    }

    #[test]
    fn registry_coordinator_serves_multiple_tenants() {
        let dir = std::env::temp_dir().join(format!(
            "approxrbf_server_registry_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(ModelStore::open(dir).unwrap());
        let (m_a, am_a, ds_a) = setup(0.2);
        let (m_b, am_b, ds_b) = setup(0.25);
        store.publish("alpha", &m_a, &am_a).unwrap();
        store.publish("bravo", &m_b, &am_b).unwrap();
        // Reference decisions come from the loaded entries, so the
        // assertion holds whatever payload kind the publish used
        // (APPROXRBF_TEST_QUANT may quantize it).
        let ent_a = store.load("alpha").unwrap();
        let ent_b = store.load("bravo").unwrap();
        let coord = Coordinator::builder()
            .start_registry(store)
            .unwrap();
        let client = coord.client();
        let sub_a = ds_a.x.rows_slice(0, 40);
        let sub_b = ds_b.x.rows_slice(0, 30);
        let ra = client.predict_all_for("alpha", &sub_a).unwrap();
        let rb = client.predict_all_for("bravo", &sub_b).unwrap();
        for (r, resp) in ra.iter().enumerate() {
            let want = match resp.route {
                Route::Approx => ent_a.approx_decision_one(sub_a.row(r)),
                Route::Exact => ent_a.exact_decision_one(sub_a.row(r)),
            };
            assert!((resp.decision - want).abs() < 1e-3);
            assert_eq!(&*resp.model, "alpha");
            assert_eq!(resp.generation, 1);
        }
        for (r, resp) in rb.iter().enumerate() {
            let want = match resp.route {
                Route::Approx => ent_b.approx_decision_one(sub_b.row(r)),
                Route::Exact => ent_b.exact_decision_one(sub_b.row(r)),
            };
            assert!((resp.decision - want).abs() < 1e-3);
        }
        assert!(client.submit_to("ghost", vec![0.0; 6]).is_err());
        let snap = coord.metrics();
        assert_eq!(snap.per_model.len(), 2);
        assert_eq!(snap.per_model[0].id, "alpha");
        assert_eq!(snap.per_model[0].served_total(), 40);
        assert_eq!(snap.per_model[1].served_total(), 30);
        coord.shutdown().unwrap();
    }
}
