//! SVM model container + LIBSVM-compatible text format.
//!
//! The decision function follows the representer theorem (Eq. 3.2):
//! `f(z) = Σ_i coef_i κ(x_i, z) + b` with `coef_i = α_i y_i`. LIBSVM
//! stores `rho = -b`; the text I/O honours that so models written here
//! load in LIBSVM and vice versa (the subset used by the paper:
//! binary c_svc, rbf/linear/poly kernels).
//!
//! Model *text size* matters: Table 3 of the paper compares text-format
//! model sizes, so [`SvmModel::to_text`] mirrors LIBSVM's sparse SV
//! encoding and [`SvmModel::text_size_bytes`] is the Table 3 metric.

use std::path::Path;

use crate::data::libsvm_format::fmt_f32;
use crate::linalg::Mat;
use crate::svm::Kernel;
use crate::{Error, Result};

/// A trained (binary) kernel SVM model.
#[derive(Clone, Debug)]
pub struct SvmModel {
    pub kernel: Kernel,
    /// Support vectors, one per row (n_SV × d).
    pub sv: Mat,
    /// coef_i = α_i y_i.
    pub coef: Vec<f32>,
    /// Bias term b (LIBSVM's −rho).
    pub b: f32,
}

impl SvmModel {
    pub fn new(kernel: Kernel, sv: Mat, coef: Vec<f32>, b: f32) -> Result<Self> {
        if sv.rows() != coef.len() {
            return Err(Error::Shape(format!(
                "{} SVs vs {} coefficients",
                sv.rows(),
                coef.len()
            )));
        }
        Ok(SvmModel { kernel, sv, coef, b })
    }

    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// Shared validation behind every codec (text and `.arbf` binary):
    /// shapes must agree and every parameter must be finite. Returns a
    /// human-readable defect description.
    pub fn check_finite(&self) -> std::result::Result<(), String> {
        if self.sv.rows() != self.coef.len() {
            return Err(format!(
                "{} SVs vs {} coefficients",
                self.sv.rows(),
                self.coef.len()
            ));
        }
        let (gamma, beta) = match self.kernel {
            Kernel::Linear => (0.0, 0.0),
            Kernel::Rbf { gamma } => (gamma, 0.0),
            Kernel::Poly2 { gamma, beta } => (gamma, beta),
        };
        for (name, val) in
            [("gamma", gamma), ("coef0", beta), ("b", self.b)]
        {
            if !val.is_finite() {
                return Err(format!("non-finite {name}: {val}"));
            }
        }
        if let Some(i) = self.coef.iter().position(|x| !x.is_finite()) {
            return Err(format!("non-finite coefficient for SV {i}"));
        }
        if let Some(i) = self.sv.as_slice().iter().position(|x| !x.is_finite())
        {
            return Err(format!("non-finite SV feature (flat index {i})"));
        }
        Ok(())
    }

    pub fn dim(&self) -> usize {
        self.sv.cols()
    }

    /// Max squared SV norm — `‖x_M‖²` of Eq. (3.11).
    pub fn max_sv_norm_sq(&self) -> f32 {
        self.sv.row_norms_sq().into_iter().fold(0.0, f32::max)
    }

    /// Exact decision value for one instance (naive reference path).
    pub fn decision_one(&self, z: &[f32]) -> f32 {
        let mut acc = self.b;
        for i in 0..self.n_sv() {
            acc += self.coef[i] * self.kernel.eval(self.sv.row(i), z);
        }
        acc
    }

    /// LIBSVM-compatible text encoding.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("svm_type c_svc\n");
        match self.kernel {
            Kernel::Rbf { gamma } => {
                out.push_str("kernel_type rbf\n");
                out.push_str(&format!("gamma {}\n", fmt_f32(gamma)));
            }
            Kernel::Linear => out.push_str("kernel_type linear\n"),
            Kernel::Poly2 { gamma, beta } => {
                out.push_str("kernel_type polynomial\ndegree 2\n");
                out.push_str(&format!("gamma {}\n", fmt_f32(gamma)));
                out.push_str(&format!("coef0 {}\n", fmt_f32(beta)));
            }
        }
        out.push_str("nr_class 2\n");
        out.push_str(&format!("total_sv {}\n", self.n_sv()));
        out.push_str(&format!("rho {}\n", fmt_f32(-self.b)));
        out.push_str("label 1 -1\n");
        let npos = self.coef.iter().filter(|&&c| c > 0.0).count();
        out.push_str(&format!("nr_sv {} {}\n", npos, self.n_sv() - npos));
        out.push_str("SV\n");
        for i in 0..self.n_sv() {
            out.push_str(&fmt_f32(self.coef[i]));
            for (j, &v) in self.sv.row(i).iter().enumerate() {
                if v != 0.0 {
                    out.push_str(&format!(" {}:{}", j + 1, fmt_f32(v)));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Text-format size in bytes (Table 3's "exact" column).
    pub fn text_size_bytes(&self) -> usize {
        self.to_text().len()
    }

    /// Parse the LIBSVM text format (subset: binary c_svc).
    pub fn from_text(text: &str) -> Result<SvmModel> {
        let mut kernel_type = "";
        let mut gamma = 0.0f32;
        let mut coef0 = 0.0f32;
        let mut degree = 0usize;
        let mut rho = 0.0f32;
        let mut dim_hint = 0usize;
        let mut lines = text.lines();
        for line in lines.by_ref() {
            let line = line.trim();
            if line == "SV" {
                break;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("svm_type") => {
                    let t = it.next().unwrap_or("");
                    if t != "c_svc" {
                        return Err(Error::Parse(format!(
                            "unsupported svm_type '{t}'"
                        )));
                    }
                }
                Some("kernel_type") => {
                    kernel_type = match it.next() {
                        Some("rbf") => "rbf",
                        Some("linear") => "linear",
                        Some("polynomial") => "polynomial",
                        other => {
                            return Err(Error::Parse(format!(
                                "unsupported kernel_type {other:?}"
                            )))
                        }
                    };
                }
                Some("gamma") => gamma = parse_f32(it.next())?,
                Some("coef0") => coef0 = parse_f32(it.next())?,
                Some("degree") => {
                    degree = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| Error::Parse("bad degree".into()))?
                }
                Some("rho") => rho = parse_f32(it.next())?,
                Some("nr_class") | Some("total_sv") | Some("label")
                | Some("nr_sv") | None => {}
                Some(other) => {
                    return Err(Error::Parse(format!(
                        "unknown model header '{other}'"
                    )))
                }
            }
        }
        let kernel = match kernel_type {
            "rbf" => Kernel::Rbf { gamma },
            "linear" => Kernel::Linear,
            "polynomial" => {
                if degree != 2 {
                    return Err(Error::Parse(format!(
                        "only degree-2 polynomial supported, got {degree}"
                    )));
                }
                Kernel::Poly2 { gamma, beta: coef0 }
            }
            _ => return Err(Error::Parse("missing kernel_type".into())),
        };
        // SV block: "coef idx:val ..."
        let mut coefs = Vec::new();
        let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            coefs.push(parse_f32(it.next())?);
            let mut feats = Vec::new();
            for tok in it {
                let (i, v) = tok
                    .split_once(':')
                    .ok_or_else(|| Error::Parse("bad SV feature".into()))?;
                let idx: usize = i
                    .parse()
                    .map_err(|_| Error::Parse("bad SV index".into()))?;
                let val: f32 = v
                    .parse()
                    .map_err(|_| Error::Parse("bad SV value".into()))?;
                if idx == 0 {
                    return Err(Error::Parse("SV indices are 1-based".into()));
                }
                dim_hint = dim_hint.max(idx);
                feats.push((idx - 1, val));
            }
            rows.push(feats);
        }
        let mut sv = Mat::zeros(rows.len(), dim_hint);
        for (r, feats) in rows.into_iter().enumerate() {
            for (c, v) in feats {
                *sv.at_mut(r, c) = v;
            }
        }
        let model = SvmModel::new(kernel, sv, coefs, -rho)?;
        // Rust's f32 parser accepts "nan"/"inf"; reject them here so a
        // damaged model file cannot silently poison every decision.
        model.check_finite().map_err(Error::Parse)?;
        Ok(model)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SvmModel> {
        SvmModel::from_text(&std::fs::read_to_string(path)?)
    }
}

fn parse_f32(tok: Option<&str>) -> Result<f32> {
    tok.and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Parse("bad float in model".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> SvmModel {
        SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            Mat::from_vec(3, 2, vec![1., 0., 0., 2., -1., 1.]).unwrap(),
            vec![0.5, -1.0, 0.75],
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn decision_matches_manual() {
        let m = toy_model();
        let z = [0.5f32, 0.5];
        let manual: f32 = m.b
            + m.coef[0] * (-0.25f32 * (0.25 + 0.25)).exp()
            + m.coef[1] * (-0.25f32 * (0.25 + 2.25)).exp()
            + m.coef[2] * (-0.25f32 * (2.25 + 0.25)).exp();
        assert!((m.decision_one(&z) - manual).abs() < 1e-6);
    }

    #[test]
    fn text_roundtrip() {
        let m = toy_model();
        let back = SvmModel::from_text(&m.to_text()).unwrap();
        assert_eq!(back.n_sv(), 3);
        assert_eq!(back.dim(), 2);
        assert_eq!(back.coef, m.coef);
        assert!((back.b - m.b).abs() < 1e-6);
        assert_eq!(back.kernel, m.kernel);
        assert_eq!(back.sv.max_abs_diff(&m.sv), 0.0);
    }

    #[test]
    fn poly2_roundtrip() {
        let m = SvmModel::new(
            Kernel::Poly2 { gamma: 0.5, beta: 1.0 },
            Mat::from_vec(1, 2, vec![1., 2.]).unwrap(),
            vec![1.0],
            -0.3,
        )
        .unwrap();
        let back = SvmModel::from_text(&m.to_text()).unwrap();
        assert_eq!(back.kernel, m.kernel);
    }

    #[test]
    fn non_finite_text_rejected() {
        // gamma / rho / coefficients / SV values: "nan" parses as f32,
        // so the codec must check finiteness explicitly.
        let cases = [
            "svm_type c_svc\nkernel_type rbf\ngamma nan\nrho 0\nSV\n1 1:1\n",
            "svm_type c_svc\nkernel_type rbf\ngamma 0.5\nrho inf\nSV\n1 1:1\n",
            "svm_type c_svc\nkernel_type rbf\ngamma 0.5\nrho 0\nSV\nnan 1:1\n",
            "svm_type c_svc\nkernel_type rbf\ngamma 0.5\nrho 0\nSV\n1 1:inf\n",
        ];
        for text in cases {
            let err = SvmModel::from_text(text).unwrap_err();
            assert!(
                matches!(err, Error::Parse(ref m) if m.contains("non-finite")),
                "{text:?}: {err}"
            );
        }
    }

    #[test]
    fn malformed_sv_rows_rejected() {
        let bad = "svm_type c_svc\nkernel_type linear\nrho 0\nSV\n1 0:2\n";
        assert!(SvmModel::from_text(bad).is_err(), "0-based index");
        let bad = "svm_type c_svc\nkernel_type linear\nrho 0\nSV\n1 7\n";
        assert!(SvmModel::from_text(bad).is_err(), "feature without ':'");
    }

    #[test]
    fn rejects_unsupported() {
        assert!(SvmModel::from_text("svm_type nu_svc\nSV\n").is_err());
        assert!(SvmModel::from_text(
            "svm_type c_svc\nkernel_type sigmoid\nSV\n"
        )
        .is_err());
    }

    #[test]
    fn max_sv_norm() {
        let m = toy_model();
        assert_eq!(m.max_sv_norm_sq(), 4.0);
    }

    #[test]
    fn rho_sign_convention() {
        // LIBSVM: f(z) = sum coef K - rho. We store b = -rho.
        let text = "svm_type c_svc\nkernel_type linear\nrho 0.5\nSV\n1 1:1\n";
        let m = SvmModel::from_text(text).unwrap();
        assert!((m.b + 0.5).abs() < 1e-6);
        // f([0]) = coef*<1,0> + b = -0.5
        assert!((m.decision_one(&[0.0]) + 0.5).abs() < 1e-6);
    }
}
