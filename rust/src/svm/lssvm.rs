//! LS-SVM classifier (the LS-SVMlab role; Suykens & Vandewalle 1999).
//!
//! The paper highlights that LS-SVM models are *not sparse* — every
//! training point becomes a support vector — which makes them the
//! best-case customer for the approximation (§3, §5: "the compression
//! ratios would be even larger"). We reproduce that ablation.
//!
//! KKT system (classification):
//! ```text
//! [ 0   yᵀ    ] [ b ]   [ 0 ]
//! [ y   Ω+I/γ ] [ α ] = [ 1 ]      Ω_ij = y_i y_j κ(x_i, x_j)
//! ```
//! Solved by block elimination with two conjugate-gradient solves on the
//! SPD matrix `A = Ω + I/γ` (Suykens' standard scheme): solve `A η = y`
//! and `A ν = 1`; then `b = (yᵀν)/(yᵀη)` (and `yᵀν = 1ᵀη` since `A⁻¹` is
//! symmetric) and `α = ν − η·b`.

use crate::data::Dataset;
use crate::log_warn;
use crate::linalg::{vecops, Mat};
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

/// LS-SVM hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct LssvmParams {
    /// Regularization γ_c (larger = less regularization).
    pub gamma_c: f32,
    /// CG tolerance on the relative residual.
    pub tol: f64,
    /// CG iteration cap.
    pub max_iter: usize,
}

impl Default for LssvmParams {
    fn default() -> Self {
        LssvmParams { gamma_c: 10.0, tol: 1e-6, max_iter: 2000 }
    }
}

/// Train an LS-SVM classifier. Every training point becomes a support
/// vector (coef_i = α_i y_i, like the C-SVC convention).
pub fn train_lssvm(
    ds: &Dataset,
    kernel: Kernel,
    params: LssvmParams,
) -> Result<SvmModel> {
    let n = ds.len();
    if n == 0 {
        return Err(Error::InvalidArg("empty training set".into()));
    }
    if n > 20_000 {
        return Err(Error::InvalidArg(format!(
            "dense LS-SVM capped at 20k points, got {n}"
        )));
    }
    // Dense Ω + I/γ (SPD).
    let norms = ds.x.row_norms_sq();
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        let xi = ds.x.row(i);
        for j in i..n {
            let k = kernel.eval_precomp(
                norms[i],
                norms[j],
                vecops::dot(xi, ds.x.row(j)),
            );
            let v = ds.y[i] * ds.y[j] * k
                + if i == j { 1.0 / params.gamma_c } else { 0.0 };
            *a.at_mut(i, j) = v;
            *a.at_mut(j, i) = v;
        }
    }
    // Block elimination: solve A η = y and A ν = 1.
    let eta = cg_solve(&a, &ds.y, params.tol, params.max_iter)?;
    let ones = vec![1.0f32; n];
    let nu = cg_solve(&a, &ones, params.tol, params.max_iter)?;
    // b = (ηᵀ·1) / (ηᵀ·y);  α = ν − η·b.
    let s: f64 = ds.y.iter().zip(&eta).map(|(&yi, &e)| f64::from(yi * e)).sum();
    if s.abs() < 1e-12 {
        return Err(Error::Other("degenerate LS-SVM system".into()));
    }
    let num: f64 = eta.iter().map(|&e| f64::from(e)).sum();
    let b = (num / s) as f32;
    let alpha: Vec<f32> =
        nu.iter().zip(&eta).map(|(&v, &e)| v - e * b).collect();
    let coef: Vec<f32> =
        alpha.iter().zip(&ds.y).map(|(&a, &y)| a * y).collect();
    SvmModel::new(kernel, ds.x.clone(), coef, b)
}

/// Conjugate gradient for SPD `A x = rhs`.
fn cg_solve(a: &Mat, rhs: &[f32], tol: f64, max_iter: usize) -> Result<Vec<f32>> {
    let n = rhs.len();
    let mut x = vec![0.0f32; n];
    let mut r: Vec<f32> = rhs.to_vec();
    let mut p = r.clone();
    let rhs_norm = f64::from(vecops::norm_sq(rhs)).sqrt().max(1e-30);
    let mut rs_old: f64 = f64::from(vecops::norm_sq(&r));
    for _ in 0..max_iter {
        if rs_old.sqrt() / rhs_norm < tol {
            return Ok(x);
        }
        let ap = crate::linalg::gemm::gemv(a, &p);
        let pap: f64 = p
            .iter()
            .zip(&ap)
            .map(|(&pi, &api)| f64::from(pi) * f64::from(api))
            .sum();
        if pap <= 0.0 {
            return Err(Error::Other("CG: matrix not SPD".into()));
        }
        let alpha = (rs_old / pap) as f32;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = f64::from(vecops::norm_sq(&r));
        let beta = (rs_new / rs_old) as f32;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    // Converged "enough" or hit the cap; accept with a warning.
    log_warn!(
        "CG hit max_iter={max_iter} (rel residual {:.2e})",
        rs_old.sqrt() / rhs_norm
    );
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::stats::accuracy;

    #[test]
    fn cg_solves_small_spd() {
        // A = [[4,1],[1,3]], rhs = [1,2] -> x = [1/11, 7/11]
        let a = Mat::from_vec(2, 2, vec![4., 1., 1., 3.]).unwrap();
        let x = cg_solve(&a, &[1.0, 2.0], 1e-10, 100).unwrap();
        assert!((x[0] - 1.0 / 11.0).abs() < 1e-5);
        assert!((x[1] - 7.0 / 11.0).abs() < 1e-5);
    }

    #[test]
    fn all_points_become_svs() {
        let ds = synth::two_gaussians(11, 120, 5, 2.0);
        let m = train_lssvm(&ds, Kernel::Rbf { gamma: 0.5 }, Default::default())
            .unwrap();
        assert_eq!(m.n_sv(), ds.len()); // non-sparsity, §3 of the paper
    }

    #[test]
    fn classifies_separable_data() {
        let ds = synth::two_gaussians(12, 200, 6, 2.5);
        let m = train_lssvm(&ds, Kernel::Rbf { gamma: 0.5 }, Default::default())
            .unwrap();
        let pred: Vec<f32> =
            (0..ds.len()).map(|r| m.decision_one(ds.x.row(r))).collect();
        let acc = accuracy(&pred, &ds.y);
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn kkt_residual_small() {
        // LS-SVM KKT row i: y_i f(x_i) + α_i/γ_c = 1; multiplying by
        // y_i gives the residual form y_i − f(x_i) − coef_i/γ_c = 0.
        let ds = synth::two_gaussians(13, 80, 4, 1.5);
        let gamma_c = 7.0f32;
        let m = train_lssvm(&ds, Kernel::Rbf { gamma: 0.4 }, LssvmParams {
            gamma_c,
            ..Default::default()
        })
        .unwrap();
        for i in 0..ds.len() {
            let fi = m.decision_one(ds.x.row(i));
            let resid = ds.y[i] - fi - m.coef[i] / gamma_c;
            assert!(resid.abs() < 5e-2, "i={i} resid={resid}");
        }
    }

    #[test]
    fn empty_rejected() {
        let ds = Dataset::new(Mat::zeros(0, 2), vec![]).unwrap();
        assert!(train_lssvm(&ds, Kernel::Linear, Default::default()).is_err());
    }
}
