//! C-SVC trainer: SMO with second-order working-set selection (WSS2,
//! Fan/Chen/Lin — the algorithm inside LIBSVM, which the paper uses to
//! produce all of its exact models). Dense kernel rows are memoized in
//! an LRU cache keyed by example index.
//!
//! Dual problem:
//! ```text
//! min ½ αᵀQα − eᵀα   s.t. 0 ≤ α_i ≤ C,  yᵀα = 0,   Q_ij = y_i y_j κ(x_i, x_j)
//! ```
//! Gradient `G_i = Σ_j Q_ij α_j − 1`. Selection:
//! `i = argmax_{t ∈ I_up} −y_t G_t`, then `j` minimizing the second-order
//! objective `−b_t²/a_t` over violating `t ∈ I_low`. Convergence when the
//! max violation `m − M < ε`.

use crate::data::Dataset;
use crate::log_debug;
use crate::linalg::vecops;
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

/// SMO hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SmoParams {
    /// Soft-margin cost C.
    pub c: f32,
    /// Stopping tolerance ε on the max KKT violation.
    pub eps: f32,
    /// Hard iteration cap (safety; LIBSVM uses a similar guard).
    pub max_iter: usize,
    /// Kernel-row cache size in rows.
    pub cache_rows: usize,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams { c: 1.0, eps: 1e-3, max_iter: 2_000_000, cache_rows: 4096 }
    }
}

/// Training statistics for logs / EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct TrainStats {
    pub iterations: usize,
    pub n_sv: usize,
    pub n_bounded_sv: usize,
    pub objective: f64,
    pub converged: bool,
}

/// LRU cache of dense kernel rows.
struct RowCache {
    rows: std::collections::HashMap<usize, (u64, Vec<f32>)>,
    capacity: usize,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    fn new(capacity: usize) -> Self {
        RowCache {
            rows: std::collections::HashMap::new(),
            capacity: capacity.max(2),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Fetch row `i`, computing via `make` on miss.
    fn get<F: FnOnce() -> Vec<f32>>(&mut self, i: usize, make: F) -> &[f32] {
        self.clock += 1;
        let clock = self.clock;
        if self.rows.contains_key(&i) {
            self.hits += 1;
            let e = self.rows.get_mut(&i).unwrap();
            e.0 = clock;
            return &self.rows[&i].1;
        }
        self.misses += 1;
        if self.rows.len() >= self.capacity {
            // Evict least-recently-used.
            let oldest = *self
                .rows
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k)
                .unwrap();
            self.rows.remove(&oldest);
        }
        self.rows.insert(i, (clock, make()));
        &self.rows[&i].1
    }
}

/// Train a binary C-SVC. Labels must be ±1.
pub fn train_csvc(
    ds: &Dataset,
    kernel: Kernel,
    params: SmoParams,
) -> Result<(SvmModel, TrainStats)> {
    let n = ds.len();
    if n == 0 {
        return Err(Error::InvalidArg("empty training set".into()));
    }
    let c = params.c;
    let y = &ds.y;
    // Precompute norms once; kernel rows use the precomp form.
    let norms = ds.x.row_norms_sq();
    // Kernel diagonal: κ(x_t, x_t) = eval_precomp(n_t, n_t, n_t).
    let kdiag: Vec<f32> = norms
        .iter()
        .map(|&nt| kernel.eval_precomp(nt, nt, nt))
        .collect();
    let mut cache = RowCache::new(params.cache_rows);
    let kernel_row = |t: usize, norms: &[f32]| -> Vec<f32> {
        let xt = ds.x.row(t);
        let nt = norms[t];
        (0..n)
            .map(|u| {
                kernel.eval_precomp(nt, norms[u], vecops::dot(xt, ds.x.row(u)))
            })
            .collect()
    };

    let mut alpha = vec![0.0f32; n];
    let mut grad = vec![-1.0f32; n]; // G_i = Σ Q α − 1, α = 0 initially
    let tau = 1e-12f64;

    let in_up = |t: usize, alpha: &[f32]| {
        (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0)
    };
    let in_low = |t: usize, alpha: &[f32]| {
        (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c)
    };

    let mut iterations = 0usize;
    let mut converged = false;
    while iterations < params.max_iter {
        // --- selection: first order for i, second order for j ---
        let mut m = f64::NEG_INFINITY;
        let mut i = usize::MAX;
        for t in 0..n {
            if in_up(t, &alpha) {
                let v = f64::from(-y[t] * grad[t]);
                if v > m {
                    m = v;
                    i = t;
                }
            }
        }
        let mut big_m = f64::INFINITY;
        for t in 0..n {
            if in_low(t, &alpha) {
                big_m = big_m.min(f64::from(-y[t] * grad[t]));
            }
        }
        if i == usize::MAX || m - big_m < f64::from(params.eps) {
            converged = true;
            break;
        }
        // Kernel row for i (borrow ends before we mutate).
        let ki: Vec<f32> = cache.get(i, || kernel_row(i, &norms)).to_vec();
        let kii = f64::from(ki[i]);
        let mut j = usize::MAX;
        let mut best = f64::INFINITY;
        for t in 0..n {
            if !in_low(t, &alpha) {
                continue;
            }
            let gt = f64::from(-y[t] * grad[t]);
            let bdiff = m - gt;
            if bdiff <= 0.0 {
                continue;
            }
            let ktt = f64::from(kdiag[t]);
            let kit = f64::from(ki[t]);
            let a = (kii + ktt - 2.0 * kit).max(tau);
            let obj = -(bdiff * bdiff) / a;
            if obj < best {
                best = obj;
                j = t;
            }
        }
        if j == usize::MAX {
            converged = true;
            break;
        }
        let kj: Vec<f32> = cache.get(j, || kernel_row(j, &norms)).to_vec();

        // --- two-variable analytic update (LIBSVM conventions) ---
        let (yi, yj) = (y[i], y[j]);
        let qii = f64::from(ki[i]); // y_i y_i K_ii = K_ii
        let qjj = f64::from(kj[j]);
        let qij = f64::from(yi * yj * ki[j]);
        let (old_ai, old_aj) = (f64::from(alpha[i]), f64::from(alpha[j]));
        let cf = f64::from(c);
        let (mut ai, mut aj);
        if yi != yj {
            let quad = (qii + qjj + 2.0 * qij).max(tau);
            let delta = f64::from(-grad[i] - grad[j]) / quad;
            let diff = old_ai - old_aj;
            ai = old_ai + delta;
            aj = old_aj + delta;
            if diff > 0.0 && aj < 0.0 {
                aj = 0.0;
                ai = diff;
            } else if diff <= 0.0 && ai < 0.0 {
                ai = 0.0;
                aj = -diff;
            }
            if diff > 0.0 {
                if ai > cf {
                    ai = cf;
                    aj = cf - diff;
                }
            } else if aj > cf {
                aj = cf;
                ai = cf + diff;
            }
        } else {
            let quad = (qii + qjj - 2.0 * qij).max(tau);
            let delta = f64::from(grad[i] - grad[j]) / quad;
            let sum = old_ai + old_aj;
            ai = old_ai - delta;
            aj = old_aj + delta;
            if sum > cf {
                if ai > cf {
                    ai = cf;
                    aj = sum - cf;
                }
                if aj > cf {
                    aj = cf;
                    ai = sum - cf;
                }
            } else {
                if aj < 0.0 {
                    aj = 0.0;
                    ai = sum;
                }
                if ai < 0.0 {
                    ai = 0.0;
                    aj = sum;
                }
            }
        }
        let dai = (ai - old_ai) as f32;
        let daj = (aj - old_aj) as f32;
        if dai.abs() < 1e-12 && daj.abs() < 1e-12 {
            converged = true;
            break;
        }
        alpha[i] = ai as f32;
        alpha[j] = aj as f32;
        // Gradient update: G_t += Q_ti Δα_i + Q_tj Δα_j.
        for t in 0..n {
            grad[t] += y[t] * (yi * dai * ki[t] + yj * daj * kj[t]);
        }
        iterations += 1;
    }

    // rho/b from free SVs (or the violation midpoint when none free).
    let mut free_sum = 0.0f64;
    let mut free_count = 0usize;
    for t in 0..n {
        if alpha[t] > 0.0 && alpha[t] < c {
            free_sum += f64::from(y[t] * grad[t]);
            free_count += 1;
        }
    }
    let b = if free_count > 0 {
        (-free_sum / free_count as f64) as f32
    } else {
        let mut m = f64::NEG_INFINITY;
        let mut big_m = f64::INFINITY;
        for t in 0..n {
            let v = f64::from(-y[t] * grad[t]);
            if in_up(t, &alpha) {
                m = m.max(v);
            }
            if in_low(t, &alpha) {
                big_m = big_m.min(v);
            }
        }
        ((m + big_m) / 2.0) as f32
    };

    // Dual objective ½αᵀQα − eᵀα = ½ Σ α_i(G_i − 1)  (since G = Qα − e).
    let objective: f64 = 0.5
        * alpha
            .iter()
            .zip(&grad)
            .map(|(&a, &g)| f64::from(a) * (f64::from(g) - 1.0))
            .sum::<f64>();

    // Extract SVs.
    let sv_idx: Vec<usize> =
        (0..n).filter(|&t| alpha[t] > 1e-8).collect();
    let coef: Vec<f32> = sv_idx.iter().map(|&t| alpha[t] * y[t]).collect();
    let sv = ds.x.gather_rows(&sv_idx);
    let n_bounded = sv_idx.iter().filter(|&&t| alpha[t] >= c - 1e-8).count();
    let stats = TrainStats {
        iterations,
        n_sv: sv_idx.len(),
        n_bounded_sv: n_bounded,
        objective,
        converged,
    };
    log_debug!(
        "smo: iters={} n_sv={} bounded={} obj={:.4} converged={} cache h/m={}/{}",
        stats.iterations,
        stats.n_sv,
        stats.n_bounded_sv,
        stats.objective,
        stats.converged,
        cache.hits,
        cache.misses
    );
    Ok((SvmModel::new(kernel, sv, coef, b)?, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::stats::accuracy;

    fn predict_all(m: &SvmModel, ds: &Dataset) -> Vec<f32> {
        (0..ds.len()).map(|r| m.decision_one(ds.x.row(r))).collect()
    }

    #[test]
    fn separable_case_trains_clean() {
        let ds = synth::two_gaussians(1, 300, 8, 3.0);
        let (model, stats) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.5 },
            SmoParams { c: 10.0, ..Default::default() },
        )
        .unwrap();
        assert!(stats.converged);
        let acc = accuracy(&predict_all(&model, &ds), &ds.y);
        assert!(acc > 0.97, "train acc {acc}");
        // Well-separated data ⇒ few SVs.
        assert!(model.n_sv() < ds.len() / 2);
    }

    #[test]
    fn generalizes_on_holdout() {
        let (tr, te) = synth::SynthProfile::ControlLike.generate(3, 800, 400);
        let (model, stats) =
            train_csvc(&tr, Kernel::Rbf { gamma: 1.0 }, SmoParams {
                c: 2.0,
                ..Default::default()
            })
            .unwrap();
        assert!(stats.converged);
        let acc = accuracy(&predict_all(&model, &te), &te.y);
        assert!(acc > 0.85, "test acc {acc}");
    }

    #[test]
    fn dual_constraints_hold() {
        let ds = synth::two_gaussians(5, 200, 4, 1.0);
        let c = 1.5f32;
        let (model, _) = train_csvc(&ds, Kernel::Rbf { gamma: 0.8 }, SmoParams {
            c,
            ..Default::default()
        })
        .unwrap();
        // 0 <= alpha <= C  (coef = alpha*y so |coef| <= C)
        for &co in &model.coef {
            assert!(co.abs() <= c + 1e-4);
        }
        // Σ α y = Σ coef ≈ 0 (equality constraint).
        let s: f32 = model.coef.iter().sum();
        assert!(s.abs() < 1e-2 * c * model.n_sv() as f32 + 1e-3, "sum={s}");
    }

    #[test]
    fn kkt_conditions_approximately_hold() {
        let ds = synth::two_gaussians(6, 150, 3, 1.2);
        let c = 1.0f32;
        let (model, _) = train_csvc(&ds, Kernel::Rbf { gamma: 0.6 }, SmoParams {
            c,
            eps: 1e-4,
            ..Default::default()
        })
        .unwrap();
        // Free SVs must satisfy y f(x) ≈ 1.
        for i in 0..model.n_sv() {
            let a = model.coef[i].abs();
            if a > 1e-5 && a < c - 1e-5 {
                let yi = model.coef[i].signum();
                let margin = yi * model.decision_one(model.sv.row(i));
                assert!(
                    (margin - 1.0).abs() < 0.05,
                    "free SV margin {margin}"
                );
            }
        }
    }

    #[test]
    fn harder_data_yields_more_svs() {
        let easy = synth::two_gaussians(7, 300, 6, 3.0);
        let hard = synth::two_gaussians(7, 300, 6, 0.5);
        let p = SmoParams::default();
        let k = Kernel::Rbf { gamma: 0.5 };
        let (me, _) = train_csvc(&easy, k, p).unwrap();
        let (mh, _) = train_csvc(&hard, k, p).unwrap();
        assert!(
            mh.n_sv() > me.n_sv(),
            "hard {} <= easy {}",
            mh.n_sv(),
            me.n_sv()
        );
    }

    #[test]
    fn linear_kernel_trains() {
        let ds = synth::two_gaussians(8, 200, 5, 2.5);
        let (model, stats) =
            train_csvc(&ds, Kernel::Linear, SmoParams::default()).unwrap();
        assert!(stats.converged);
        let acc = accuracy(&predict_all(&model, &ds), &ds.y);
        assert!(acc > 0.9, "linear acc {acc}");
    }

    #[test]
    fn empty_dataset_rejected() {
        let ds = Dataset::new(crate::linalg::Mat::zeros(0, 3), vec![]).unwrap();
        assert!(train_csvc(&ds, Kernel::Linear, SmoParams::default()).is_err());
    }

    #[test]
    fn row_cache_evicts_and_hits() {
        let mut cache = RowCache::new(2);
        cache.get(0, || vec![0.0]);
        cache.get(1, || vec![1.0]);
        cache.get(0, || panic!("should hit"));
        cache.get(2, || vec![2.0]); // evicts 1 (LRU)
        cache.get(1, || vec![1.5]); // miss again
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 4);
    }
}
