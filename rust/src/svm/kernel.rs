//! Kernel functions. The paper's method targets [`Kernel::Rbf`];
//! [`Kernel::Poly2`] implements the degree-2 polynomial kernel of §3.2
//! (the exact quadratic model the approximation is contrasted with) and
//! [`Kernel::Linear`] is the fast-but-less-accurate baseline the
//! introduction motivates against.

use crate::linalg::vecops;

/// A kernel function κ(x, y).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// κ(x,y) = exp(-γ‖x−y‖²)  (Eq. 1.1)
    Rbf { gamma: f32 },
    /// κ(x,y) = xᵀy
    Linear,
    /// κ(x,y) = (γ xᵀy + β)²  (Eq. 3.12)
    Poly2 { gamma: f32, beta: f32 },
}

impl Kernel {
    #[inline]
    pub fn eval(&self, x: &[f32], y: &[f32]) -> f32 {
        match *self {
            Kernel::Rbf { gamma } => (-gamma * vecops::dist_sq(x, y)).exp(),
            Kernel::Linear => vecops::dot(x, y),
            Kernel::Poly2 { gamma, beta } => {
                let u = gamma * vecops::dot(x, y) + beta;
                u * u
            }
        }
    }

    /// Scalar-arithmetic evaluation (single serial accumulator): the
    /// paper's LOOPS / SIMD-off configuration. [`Kernel::eval`] is the
    /// vectorized counterpart.
    #[inline]
    pub fn eval_scalar(&self, x: &[f32], y: &[f32]) -> f32 {
        match *self {
            Kernel::Rbf { gamma } => {
                let mut acc = 0.0f32;
                for i in 0..x.len() {
                    let d = x[i] - y[i];
                    acc += d * d;
                }
                (-gamma * acc).exp()
            }
            Kernel::Linear => vecops::dot_scalar(x, y),
            Kernel::Poly2 { gamma, beta } => {
                let u = gamma * vecops::dot_scalar(x, y) + beta;
                u * u
            }
        }
    }

    /// Kernel value from precomputed norms and inner product — the form
    /// used by row-wise evaluation with cached ‖x‖².
    #[inline]
    pub fn eval_precomp(&self, xn: f32, yn: f32, xy: f32) -> f32 {
        match *self {
            Kernel::Rbf { gamma } => (-gamma * (xn + yn - 2.0 * xy)).exp(),
            Kernel::Linear => xy,
            Kernel::Poly2 { gamma, beta } => {
                let u = gamma * xy + beta;
                u * u
            }
        }
    }

    pub fn gamma(&self) -> Option<f32> {
        match *self {
            Kernel::Rbf { gamma } | Kernel::Poly2 { gamma, .. } => Some(gamma),
            Kernel::Linear => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Rbf { .. } => "rbf",
            Kernel::Linear => "linear",
            Kernel::Poly2 { .. } => "poly2",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;

    #[test]
    fn rbf_identity_is_one() {
        let k = Kernel::Rbf { gamma: 0.7 };
        let x = [1.0f32, -2.0, 3.0];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rbf_known_value() {
        let k = Kernel::Rbf { gamma: 0.5 };
        // ||x-y||^2 = 2 => exp(-1)
        let v = k.eval(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((v - (-1.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn precomp_matches_direct() {
        let mut rng = crate::util::Rng::new(10);
        let x: Vec<f32> = (0..13).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..13).map(|_| rng.normal() as f32).collect();
        let xn = vecops::norm_sq(&x);
        let yn = vecops::norm_sq(&y);
        let xy = vecops::dot(&x, &y);
        for k in [
            Kernel::Rbf { gamma: 0.3 },
            Kernel::Linear,
            Kernel::Poly2 { gamma: 0.3, beta: 1.0 },
        ] {
            assert!(
                (k.eval(&x, &y) - k.eval_precomp(xn, yn, xy)).abs() < 1e-4,
                "{}",
                k.name()
            );
        }
    }

    #[test]
    fn rbf_bounded_and_symmetric() {
        prop_cases!("rbf-bounds", 8, |rng| {
            let d = 1 + rng.below(20);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = Kernel::Rbf { gamma: rng.range(1e-3, 2.0) as f32 };
            let v = k.eval(&x, &y);
            assert!((0.0..=1.0 + 1e-6).contains(&v));
            assert!((v - k.eval(&y, &x)).abs() < 1e-6);
        });
    }
}
