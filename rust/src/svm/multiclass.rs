//! One-vs-rest multiclass SVM — the setting behind the paper's mnist
//! experiment ("10 classes — we classified class 1 versus others").
//!
//! Trains one binary C-SVC per class and predicts by argmax of decision
//! values. Each binary model approximates independently (Eq. 3.8), so a
//! K-class approximated ensemble costs `K·O(d²)` per instance — still
//! independent of the SV counts, preserving the paper's headline
//! property across the multiclass reduction.

use crate::approx::builder::build_approx_model;
use crate::approx::ApproxModel;
use crate::data::Dataset;
use crate::linalg::{Mat, MathBackend};
use crate::svm::smo::{train_csvc, SmoParams};
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

/// Multiclass labeled dataset (labels are arbitrary integers).
#[derive(Clone, Debug)]
pub struct MulticlassDataset {
    pub x: Mat,
    pub y: Vec<i32>,
}

impl MulticlassDataset {
    pub fn new(x: Mat, y: Vec<i32>) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(Error::Shape("rows vs labels".into()));
        }
        Ok(MulticlassDataset { x, y })
    }

    /// Distinct labels in ascending order.
    pub fn classes(&self) -> Vec<i32> {
        let mut c = self.y.clone();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Binary view: `class` vs rest (+1 / −1).
    pub fn one_vs_rest(&self, class: i32) -> Result<Dataset> {
        let y = self
            .y
            .iter()
            .map(|&l| if l == class { 1.0 } else { -1.0 })
            .collect();
        Dataset::new(self.x.clone(), y)
    }
}

/// One-vs-rest ensemble of exact binary models.
pub struct OvrModel {
    pub classes: Vec<i32>,
    pub models: Vec<SvmModel>,
}

impl OvrModel {
    /// Train one C-SVC per class.
    pub fn train(
        ds: &MulticlassDataset,
        kernel: Kernel,
        params: SmoParams,
    ) -> Result<OvrModel> {
        let classes = ds.classes();
        if classes.len() < 2 {
            return Err(Error::InvalidArg("need ≥2 classes".into()));
        }
        let mut models = Vec::with_capacity(classes.len());
        for &c in &classes {
            let binary = ds.one_vs_rest(c)?;
            let (m, _) = train_csvc(&binary, kernel, params)?;
            models.push(m);
        }
        Ok(OvrModel { classes, models })
    }

    /// Predicted class labels (argmax of decision values).
    pub fn predict(&self, z: &Mat, backend: MathBackend) -> Result<Vec<i32>> {
        let mut scores = vec![f32::NEG_INFINITY; z.rows()];
        let mut labels = vec![self.classes[0]; z.rows()];
        for (k, model) in self.models.iter().enumerate() {
            let pred =
                crate::svm::predict::ExactPredictor::new(model, backend)?;
            let dec = pred.decision_batch(z)?;
            for r in 0..z.rows() {
                if dec[r] > scores[r] {
                    scores[r] = dec[r];
                    labels[r] = self.classes[k];
                }
            }
        }
        Ok(labels)
    }

    /// Approximate every binary member (Eq. 3.8).
    pub fn approximate(&self, backend: MathBackend) -> Result<OvrApprox> {
        let mut approx = Vec::with_capacity(self.models.len());
        for m in &self.models {
            approx.push(build_approx_model(m, backend)?);
        }
        Ok(OvrApprox { classes: self.classes.clone(), models: approx })
    }

    pub fn total_text_size(&self) -> usize {
        self.models.iter().map(|m| m.text_size_bytes()).sum()
    }
}

/// One-vs-rest ensemble of approximated models: `K·O(d²)` prediction.
pub struct OvrApprox {
    pub classes: Vec<i32>,
    pub models: Vec<ApproxModel>,
}

impl OvrApprox {
    /// Predicted class labels; also reports the fraction of instances
    /// within the validity bound of *every* member (the ensemble-level
    /// Eq. 3.11 check: the argmax is guaranteed only when all member
    /// decisions are accurate).
    pub fn predict(
        &self,
        z: &Mat,
        backend: MathBackend,
    ) -> Result<(Vec<i32>, f64)> {
        let mut scores = vec![f32::NEG_INFINITY; z.rows()];
        let mut labels = vec![self.classes[0]; z.rows()];
        // The bound is per-model (each has its own ‖x_M‖² and γ); the
        // tightest member budget governs the ensemble guarantee.
        let min_budget = self
            .models
            .iter()
            .map(|m| m.znorm_sq_budget())
            .fold(f32::INFINITY, f32::min);
        let mut in_bound = 0usize;
        for (k, model) in self.models.iter().enumerate() {
            let (dec, norms) = model.decision_batch(z, backend)?;
            if k == 0 {
                in_bound =
                    norms.iter().filter(|&&n| n < min_budget).count();
            }
            for r in 0..z.rows() {
                if dec[r] > scores[r] {
                    scores[r] = dec[r];
                    labels[r] = self.classes[k];
                }
            }
        }
        Ok((labels, in_bound as f64 / z.rows().max(1) as f64))
    }

    pub fn total_text_size(&self) -> usize {
        self.models.iter().map(|m| m.text_size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// 3-class Gaussian blobs.
    fn three_blobs(seed: u64, n: usize, d: usize) -> MulticlassDataset {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for r in 0..n {
            let class = (r % 3) as i32;
            let center = match class {
                0 => 2.0,
                1 => -2.0,
                _ => 0.0,
            };
            let row = x.row_mut(r);
            for (j, item) in row.iter_mut().enumerate() {
                let mu = if j == 0 { center } else { 0.3 * center };
                *item = (mu + rng.normal() * 0.6) as f32;
            }
            y.push(class);
        }
        MulticlassDataset::new(x, y).unwrap()
    }

    #[test]
    fn classes_and_binary_view() {
        let ds = three_blobs(1, 30, 4);
        assert_eq!(ds.classes(), vec![0, 1, 2]);
        let bin = ds.one_vs_rest(1).unwrap();
        let pos = bin.y.iter().filter(|&&v| v > 0.0).count();
        assert_eq!(pos, 10);
    }

    #[test]
    fn ovr_learns_three_blobs() {
        let train = three_blobs(2, 300, 6);
        let test = three_blobs(3, 150, 6);
        let ovr = OvrModel::train(
            &train,
            Kernel::Rbf { gamma: 0.2 },
            SmoParams::default(),
        )
        .unwrap();
        assert_eq!(ovr.models.len(), 3);
        let pred = ovr.predict(&test.x, MathBackend::Blocked).unwrap();
        let acc = pred
            .iter()
            .zip(&test.y)
            .filter(|(a, b)| a == b)
            .count() as f64
            / test.y.len() as f64;
        assert!(acc > 0.9, "multiclass acc {acc}");
    }

    #[test]
    fn approximated_ensemble_matches_exact() {
        let train = three_blobs(4, 240, 5);
        let test = three_blobs(5, 120, 5);
        // γ inside the bound for this data scale.
        let max_norm = train.x.row_norms_sq().into_iter().fold(0.0, f32::max);
        let gamma = 1.0 / (4.0 * max_norm);
        let ovr = OvrModel::train(
            &train,
            Kernel::Rbf { gamma },
            SmoParams::default(),
        )
        .unwrap();
        let approx = ovr.approximate(MathBackend::Blocked).unwrap();
        let exact = ovr.predict(&test.x, MathBackend::Blocked).unwrap();
        let (fast, in_bound) =
            approx.predict(&test.x, MathBackend::Blocked).unwrap();
        let agree = exact
            .iter()
            .zip(&fast)
            .filter(|(a, b)| a == b)
            .count() as f64
            / exact.len() as f64;
        assert!(agree > 0.97, "exact/approx agreement {agree}");
        assert!(in_bound > 0.9, "in-bound fraction {in_bound}");
    }

    #[test]
    fn size_independent_of_svs_across_members() {
        let train = three_blobs(6, 300, 5);
        let ovr = OvrModel::train(
            &train,
            Kernel::Rbf { gamma: 0.1 },
            SmoParams::default(),
        )
        .unwrap();
        let approx = ovr.approximate(MathBackend::Blocked).unwrap();
        // K approx models of the same d have near-identical sizes even
        // though their SV counts differ.
        let sizes: Vec<usize> =
            approx.models.iter().map(|m| m.text_size_bytes()).collect();
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        let spread = (max - min) as f64 / max as f64;
        assert!(spread < 0.2, "{sizes:?}");
    }

    #[test]
    fn single_class_rejected() {
        let ds = MulticlassDataset::new(Mat::zeros(4, 2), vec![7; 4]).unwrap();
        assert!(OvrModel::train(
            &ds,
            Kernel::Linear,
            SmoParams::default()
        )
        .is_err());
    }
}
