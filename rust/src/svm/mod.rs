//! SVM substrate: kernel functions, the C-SVC SMO trainer (the LIBSVM
//! role in the paper's pipeline), LS-SVM (the LS-SVMlab role), exact
//! predictors with swappable math backends, a LIBSVM-compatible text
//! model format, and the ANN decision-function comparator of Kang & Cho
//! [15] that the paper benchmarks against in §4.3.

#![forbid(unsafe_code)]

pub mod ann_approx;
pub mod kernel;
pub mod lssvm;
pub mod model;
pub mod multiclass;
pub mod predict;
pub mod smo;

pub use kernel::Kernel;
pub use model::SvmModel;
pub use predict::ExactPredictor;
pub use smo::{SmoParams, train_csvc};
