//! ANN decision-function approximation — the competing method the paper
//! benchmarks against (Kang & Cho [15], §4.3): distill `f(z)` into a
//! single-hidden-layer tanh network by regressing on the exact model's
//! decision values. Prediction complexity O(n_HN · d); the paper's
//! argument is that complex boundaries (many SVs) need many hidden
//! nodes, while the quadratic approximation stays O(d²) regardless.

use crate::linalg::{vecops, Mat};
use crate::svm::SvmModel;
use crate::util::Rng;
use crate::{Error, Result};

/// Single-hidden-layer regression network: f̂(z) = w2ᵀ tanh(W1 z + b1) + b2.
#[derive(Clone, Debug)]
pub struct AnnApprox {
    /// (n_hidden × d) input weights.
    pub w1: Mat,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: f32,
}

/// Distillation hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct AnnParams {
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for AnnParams {
    fn default() -> Self {
        AnnParams { hidden: 32, epochs: 60, lr: 0.02, seed: 0xA77 }
    }
}

impl AnnApprox {
    /// Distill `model`'s decision function on the rows of `x`
    /// (typically the training inputs, per Kang & Cho).
    pub fn distill(
        model: &SvmModel,
        x: &Mat,
        params: AnnParams,
    ) -> Result<AnnApprox> {
        if x.cols() != model.dim() {
            return Err(Error::Shape("distillation data dim".into()));
        }
        // Teacher targets (exact decisions), standardized for stable SGD.
        let pred = crate::svm::predict::ExactPredictor::new(
            model,
            crate::linalg::MathBackend::Blocked,
        )?;
        let targets = pred.decision_batch(x)?;
        let t_mean =
            targets.iter().map(|&t| f64::from(t)).sum::<f64>() / targets.len() as f64;
        let t_std = (targets
            .iter()
            .map(|&t| (f64::from(t) - t_mean).powi(2))
            .sum::<f64>()
            / targets.len() as f64)
            .sqrt()
            .max(1e-6);
        let norm_t: Vec<f32> = targets
            .iter()
            .map(|&t| ((f64::from(t) - t_mean) / t_std) as f32)
            .collect();

        let (n, d) = (x.rows(), x.cols());
        let h = params.hidden;
        let mut rng = Rng::new(params.seed);
        let xavier = (1.0 / d as f64).sqrt();
        let mut w1 = Mat::from_vec(
            h,
            d,
            (0..h * d).map(|_| (rng.normal() * xavier) as f32).collect(),
        )?;
        let mut b1 = vec![0.0f32; h];
        let mut w2: Vec<f32> =
            (0..h).map(|_| (rng.normal() * 0.1) as f32).collect();
        let mut b2 = 0.0f32;

        let mut order: Vec<usize> = (0..n).collect();
        let mut hidden = vec![0.0f32; h];
        for epoch in 0..params.epochs {
            // 1/sqrt decay keeps late epochs from thrashing.
            let lr = params.lr / (1.0 + epoch as f32 / 10.0);
            rng.shuffle(&mut order);
            for &r in &order {
                let z = x.row(r);
                for j in 0..h {
                    hidden[j] = (vecops::dot(w1.row(j), z) + b1[j]).tanh();
                }
                let out = vecops::dot(&w2, &hidden) + b2;
                let err = out - norm_t[r];
                // Backprop.
                b2 -= lr * err;
                for j in 0..h {
                    let gw2 = err * hidden[j];
                    let gh = err * w2[j] * (1.0 - hidden[j] * hidden[j]);
                    w2[j] -= lr * gw2;
                    b1[j] -= lr * gh;
                    vecops::axpy(-lr * gh, z, w1.row_mut(j));
                }
            }
        }
        // Fold the target standardization back into the output layer.
        for w in &mut w2 {
            *w *= t_std as f32;
        }
        b2 = b2 * t_std as f32 + t_mean as f32;
        Ok(AnnApprox { w1, b1, w2, b2 })
    }

    /// Decision value for one instance — O(hidden · d).
    pub fn decision_one(&self, z: &[f32]) -> f32 {
        let mut acc = self.b2;
        for j in 0..self.w2.len() {
            acc += self.w2[j]
                * (vecops::dot(self.w1.row(j), z) + self.b1[j]).tanh();
        }
        acc
    }

    pub fn decision_batch(&self, z: &Mat) -> Vec<f32> {
        (0..z.rows()).map(|r| self.decision_one(z.row(r))).collect()
    }

    pub fn hidden(&self) -> usize {
        self.w2.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;
    use crate::util::stats::label_diff_fraction;

    #[test]
    fn distillation_tracks_teacher_labels() {
        let ds = synth::two_gaussians(31, 400, 6, 2.0);
        let (model, _) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.4 },
            SmoParams::default(),
        )
        .unwrap();
        let ann = AnnApprox::distill(&model, &ds.x, AnnParams::default())
            .unwrap();
        let teacher: Vec<f32> =
            (0..ds.len()).map(|r| model.decision_one(ds.x.row(r))).collect();
        let student = ann.decision_batch(&ds.x);
        let diff = label_diff_fraction(&teacher, &student);
        assert!(diff < 0.08, "label diff {diff}");
    }

    #[test]
    fn more_hidden_units_fit_better() {
        let ds = synth::two_gaussians(32, 300, 5, 1.0);
        let (model, _) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.8 },
            SmoParams::default(),
        )
        .unwrap();
        let teacher: Vec<f32> =
            (0..ds.len()).map(|r| model.decision_one(ds.x.row(r))).collect();
        let mse = |ann: &AnnApprox| {
            let s = ann.decision_batch(&ds.x);
            s.iter()
                .zip(&teacher)
                .map(|(a, b)| f64::from((a - b) * (a - b)))
                .sum::<f64>()
                / s.len() as f64
        };
        let small = AnnApprox::distill(&model, &ds.x, AnnParams {
            hidden: 2,
            ..Default::default()
        })
        .unwrap();
        let large = AnnApprox::distill(&model, &ds.x, AnnParams {
            hidden: 48,
            ..Default::default()
        })
        .unwrap();
        assert!(
            mse(&large) < mse(&small),
            "large {} vs small {}",
            mse(&large),
            mse(&small)
        );
    }

    #[test]
    fn dim_mismatch_rejected() {
        let ds = synth::two_gaussians(33, 50, 4, 2.0);
        let (model, _) =
            train_csvc(&ds, Kernel::Rbf { gamma: 0.5 }, SmoParams::default())
                .unwrap();
        let bad = Mat::zeros(10, model.dim() + 2);
        assert!(AnnApprox::distill(&model, &bad, Default::default()).is_err());
    }
}
