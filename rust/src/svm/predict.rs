//! Exact prediction with swappable math backends — the paper's Table 2
//! "exact" rows. `Loops` evaluates per-SV scalar kernels (the paper's
//! LOOPS + no-SIMD config); `Blocked` batches the cross-term `Z·Xᵀ`
//! through the blocked GEMM with cached SV norms (the BLAS role); the
//! XLA path lives in [`crate::runtime`] and is selected by the
//! coordinator when artifacts are loaded.

use crate::linalg::{gemm, vecops, Mat, MathBackend};
use crate::svm::SvmModel;
use crate::{Error, Result};

/// Batched exact predictor with precomputed SV norms.
pub struct ExactPredictor<'m> {
    pub model: &'m SvmModel,
    sv_norms: Vec<f32>,
    backend: MathBackend,
}

impl<'m> ExactPredictor<'m> {
    pub fn new(model: &'m SvmModel, backend: MathBackend) -> Result<Self> {
        Self::with_norms(model, model.sv.row_norms_sq(), backend)
    }

    /// Construct with precomputed SV norms, skipping the O(n_SV·d)
    /// pass — the serving executor caches the norms per model
    /// generation and rebuilds the (cheap) predictor per batch.
    pub fn with_norms(
        model: &'m SvmModel,
        sv_norms: Vec<f32>,
        backend: MathBackend,
    ) -> Result<Self> {
        if backend == MathBackend::Xla {
            return Err(Error::InvalidArg(
                "use runtime::Engine for the XLA backend".into(),
            ));
        }
        if sv_norms.len() != model.n_sv() {
            return Err(Error::Shape(format!(
                "{} SV norms vs {} SVs",
                sv_norms.len(),
                model.n_sv()
            )));
        }
        Ok(ExactPredictor { model, sv_norms, backend })
    }

    /// Decision values for a batch of rows.
    pub fn decision_batch(&self, z: &Mat) -> Result<Vec<f32>> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        match self.backend {
            MathBackend::Loops => Ok(self.decision_loops(z)),
            MathBackend::Blocked => Ok(self.decision_blocked(z)),
            MathBackend::Xla => unreachable!("rejected in constructor"),
        }
    }

    /// Naive per-SV loop, scalar arithmetic (paper: LOOPS, SIMD off).
    fn decision_loops(&self, z: &Mat) -> Vec<f32> {
        let m = self.model;
        (0..z.rows())
            .map(|r| {
                let zr = z.row(r);
                let mut acc = m.b;
                for i in 0..m.n_sv() {
                    acc += m.coef[i] * m.kernel.eval_scalar(m.sv.row(i), zr);
                }
                acc
            })
            .collect()
    }

    /// Blocked: fused streaming evaluation (paper: exact + BLAS role).
    ///
    /// Perf note (EXPERIMENTS.md §Perf, L3-P1): the first version
    /// materialized the full `(B × n_SV)` cross-term via GEMM and then
    /// re-walked it — 54 MB of traffic for vehicle-like, making
    /// "blocked" no faster than naive loops. This version streams SV
    /// panels and fuses kernel+accumulate into the panel pass,
    /// parallelized over batch rows with scoped threads.
    fn decision_blocked(&self, z: &Mat) -> Vec<f32> {
        let m = self.model;
        let n_sv = m.n_sv();
        const PANEL: usize = 256; // SV rows per panel (~d·256·4B ≤ L2)
        let threads = gemm::effective_threads(z.rows());
        let rows_per = z.rows().div_ceil(threads);
        let mut out = vec![0.0f32; z.rows()];
        let chunks: Vec<(usize, &mut [f32])> = {
            let mut v = Vec::new();
            let mut rest = out.as_mut_slice();
            let mut row0 = 0;
            while row0 < z.rows() {
                let take = rows_per.min(z.rows() - row0);
                let (head, tail) = rest.split_at_mut(take);
                v.push((row0, head));
                rest = tail;
                row0 += take;
            }
            v
        };
        std::thread::scope(|scope| {
            for (row0, chunk) in chunks {
                scope.spawn(move || {
                    for (i, acc_out) in chunk.iter_mut().enumerate() {
                        let zr = z.row(row0 + i);
                        let zn = vecops::norm_sq(zr);
                        let mut acc = f64::from(m.b);
                        for p0 in (0..n_sv).step_by(PANEL) {
                            let p1 = (p0 + PANEL).min(n_sv);
                            let mut panel_acc = 0.0f32;
                            for s in p0..p1 {
                                let cross = vecops::dot(m.sv.row(s), zr);
                                panel_acc += m.coef[s]
                                    * m.kernel.eval_precomp(
                                        self.sv_norms[s],
                                        zn,
                                        cross,
                                    );
                            }
                            acc += f64::from(panel_acc);
                        }
                        *acc_out = acc as f32;
                    }
                });
            }
        });
        out
    }
}

/// The exact evaluator as a [`crate::predictor::Predictor`]: the
/// O(n_SV·d) reference path behind the same surface as the approx and
/// XLA substrates. The exact path does not compute ‖z‖² as a
/// by-product, so `znorms_sq` is `None` (the serving router supplies
/// its own norms).
impl crate::predictor::Predictor for ExactPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        "exact-native"
    }

    fn predict_batch(
        &self,
        z: &Mat,
    ) -> Result<crate::predictor::PredictOutput> {
        let decisions = self.decision_batch(z)?;
        Ok(crate::predictor::PredictOutput { decisions, znorms_sq: None })
    }
}

/// Predicted ±1 labels from decision values.
pub fn labels_from_decisions(dec: &[f32]) -> Vec<f32> {
    dec.iter().map(|&d| if d >= 0.0 { 1.0 } else { -1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn trained() -> (SvmModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(21, 150, 6, 1.5);
        let (m, _) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.4 },
            SmoParams::default(),
        )
        .unwrap();
        (m, ds)
    }

    #[test]
    fn backends_agree_with_reference() {
        let (m, ds) = trained();
        let loops = ExactPredictor::new(&m, MathBackend::Loops).unwrap();
        let blocked = ExactPredictor::new(&m, MathBackend::Blocked).unwrap();
        let dl = loops.decision_batch(&ds.x).unwrap();
        let db = blocked.decision_batch(&ds.x).unwrap();
        for r in 0..ds.len() {
            let reference = m.decision_one(ds.x.row(r));
            assert!((dl[r] - reference).abs() < 1e-4);
            assert!((db[r] - reference).abs() < 1e-3);
        }
    }

    #[test]
    fn xla_backend_rejected_here() {
        let (m, _) = trained();
        assert!(ExactPredictor::new(&m, MathBackend::Xla).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (m, _) = trained();
        let p = ExactPredictor::new(&m, MathBackend::Loops).unwrap();
        let bad = Mat::zeros(2, m.dim() + 1);
        assert!(p.decision_batch(&bad).is_err());
    }

    #[test]
    fn labels_sign() {
        assert_eq!(
            labels_from_decisions(&[0.5, -0.1, 0.0]),
            vec![1.0, -1.0, 1.0]
        );
    }
}
