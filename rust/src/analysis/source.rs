//! Line-level source model shared by every arblint rule.
//!
//! The rules in [`super::rules`] are deliberately token-level, not
//! AST-level: the crate must lint itself with nothing but `std`, so a
//! full parser is out of budget. What the rules *do* need, precisely,
//! is the distinction between code, comment and string-literal text —
//! `.unwrap()` inside an error message is fine, `.unwrap()` on a lock
//! is not — plus knowledge of which lines sit inside `#[cfg(test)]`
//! regions. [`SourceFile::parse`] provides exactly that: each line of
//! the input is split into a `code` view (string/char-literal contents
//! blanked to spaces so delimiters stay balanced, comments removed)
//! and a `comment` view (comment text only), and a post-pass marks
//! test regions by brace tracking.
//!
//! The lexer handles the constructs that actually appear in this tree:
//! nested block comments, `//`/`///`/`//!` line comments, string
//! literals with escapes, raw strings (`r"…"`, `r#"…"#`, byte
//! variants), char literals vs. lifetimes, and raw identifiers
//! (`r#fn`). It is shared by the `arblint` binary and the self-tests,
//! so a classifier bug fails the fixture suite, not just the live run.

/// One physical line of a source file, split into lexical views.
pub struct Line {
    /// Original text, untouched. Env-var scanning uses this view:
    /// `APPROXRBF_*` names live inside string literals by design.
    pub raw: String,
    /// Code view: comments stripped, string/char contents blanked.
    /// Delimiters (`"`, `'`) are kept so parens/braces stay balanced.
    pub code: String,
    /// Comment text on this line (line and block comments, doc
    /// comments included), without the `//`/`/*` markers.
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

/// A classified source file, addressed by its repo-relative path.
pub struct SourceFile {
    /// Path relative to the repo root, `/`-separated.
    pub rel: String,
    pub lines: Vec<Line>,
}

/// Cross-line lexer state.
enum Mode {
    Code,
    /// Inside `/* … */`; block comments nest, so track the depth.
    Block(u32),
    /// Inside a normal string literal.
    Str,
    /// Inside a raw string; the payload is the number of `#`s.
    RawStr(usize),
}

impl SourceFile {
    /// Classify `text` line by line. `rel` is recorded verbatim.
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let mut mode = Mode::Code;
        let mut lines = Vec::new();
        for raw in text.lines() {
            let (code, comment, next) = classify_line(raw, mode);
            mode = next;
            lines.push(Line {
                raw: raw.to_string(),
                code,
                comment,
                in_test: false,
            });
        }
        mark_test_regions(&mut lines);
        SourceFile { rel: rel.to_string(), lines }
    }
}

/// Split one line into code/comment views, advancing the lexer mode.
fn classify_line(raw: &str, mut mode: Mode) -> (String, String, Mode) {
    let ch: Vec<char> = raw.chars().collect();
    let n = ch.len();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < n {
        match mode {
            Mode::Block(depth) => {
                if ch[i] == '*' && i + 1 < n && ch[i + 1] == '/' {
                    mode = if depth > 1 {
                        Mode::Block(depth - 1)
                    } else {
                        Mode::Code
                    };
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if ch[i] == '/' && i + 1 < n && ch[i + 1] == '*' {
                    mode = Mode::Block(depth + 1);
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else {
                    comment.push(ch[i]);
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Str => {
                if ch[i] == '\\' {
                    code.push(' ');
                    if i + 1 < n {
                        code.push(' ');
                    }
                    i += 2;
                } else if ch[i] == '"' {
                    mode = Mode::Code;
                    code.push('"');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if ch[i] == '"' && i + hashes < n
                    && ch[i + 1..].iter().take(hashes).all(|&c| c == '#')
                {
                    mode = Mode::Code;
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    i += 1 + hashes;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Code => {
                let c = ch[i];
                if c == '/' && i + 1 < n && ch[i + 1] == '/' {
                    // Line comment: the rest of the line is comment
                    // text (doc-comment slashes land there too, which
                    // is fine — evidence checks are substring-based).
                    for &cc in &ch[i + 2..] {
                        comment.push(cc);
                    }
                    break;
                } else if c == '/' && i + 1 < n && ch[i + 1] == '*' {
                    mode = Mode::Block(1);
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && !prev_is_ident(&ch, i)
                    && raw_string_hashes(&ch, i).is_some()
                {
                    let (skip, hashes) =
                        raw_string_hashes(&ch, i).unwrap_or((0, 0));
                    mode = Mode::RawStr(hashes);
                    for _ in 0..skip {
                        code.push(' ');
                    }
                    code.push('"');
                    i += skip + 1;
                } else if c == 'b' && !prev_is_ident(&ch, i) && i + 1 < n && ch[i + 1] == '"' {
                    mode = Mode::Str;
                    code.push(' ');
                    code.push('"');
                    i += 2;
                } else if c == '\'' {
                    match char_literal_len(&ch, i) {
                        Some(len) => {
                            code.push('\'');
                            for _ in 1..len {
                                code.push(' ');
                            }
                            i += len;
                        }
                        None => {
                            // Lifetime or loop label: plain code.
                            code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    (code, comment, mode)
}

/// Is `ch[i]` preceded by an identifier character? Guards against
/// treating the final `r` of `var"…"`-like sequences as a raw-string
/// prefix (cannot occur syntactically, but cheap to be safe).
fn prev_is_ident(ch: &[char], i: usize) -> bool {
    i > 0 && (ch[i - 1].is_alphanumeric() || ch[i - 1] == '_')
}

/// If `ch[i..]` starts a raw (byte) string — `r"`, `r#"`, `br##"` … —
/// return `(prefix_len_before_quote, hash_count)`. Raw identifiers
/// like `r#fn` return `None` (no quote after the hashes).
fn raw_string_hashes(ch: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if ch[j] == 'b' {
        j += 1;
        if j >= ch.len() || ch[j] != 'r' {
            return None;
        }
    }
    if ch[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < ch.len() && ch[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < ch.len() && ch[j] == '"' {
        Some((j - i, hashes))
    } else {
        None
    }
}

/// If `ch[i..]` (starting at a `'`) is a char literal, return its
/// total length in chars; `None` means lifetime/label.
fn char_literal_len(ch: &[char], i: usize) -> Option<usize> {
    let n = ch.len();
    if i + 1 >= n {
        return None;
    }
    if ch[i + 1] == '\\' {
        // Escaped char: scan to the closing quote.
        let mut j = i + 2;
        while j < n && ch[j] != '\'' {
            j += 1;
        }
        if j < n {
            return Some(j - i + 1);
        }
        return None;
    }
    // Unescaped: exactly one char then a closing quote ('x'); any
    // other shape ('a as a lifetime, '_, 'static) is not a literal.
    if i + 2 < n && ch[i + 2] == '\'' && ch[i + 1] != '\'' {
        return Some(3);
    }
    None
}

/// Mark lines inside `#[cfg(test)]` items. The attribute may be
/// followed by further attributes, blank lines or comments before the
/// item it gates; braced items (`mod`, `fn`, `impl`) are tracked to
/// their closing brace, unbraced ones (`use …;`) to the semicolon.
fn mark_test_regions(lines: &mut [Line]) {
    let n = lines.len();
    let mut i = 0;
    while i < n {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find the first real code line at or after the attribute
        // (the attribute line itself may open the item).
        let mut j = i;
        let item = loop {
            if j >= n {
                break None;
            }
            let after = if j == i {
                let code = &lines[j].code;
                let pos = code.find("#[cfg(test)]").map(|p| p + 12);
                pos.map(|p| code[p..].trim().to_string())
            } else {
                Some(lines[j].code.trim().to_string())
            };
            match after {
                Some(t) if t.is_empty() || t.starts_with("#[") || t.starts_with("#!") => {
                    j += 1;
                }
                other => break other,
            }
        };
        let Some(item) = item else {
            break;
        };
        let end = if item.contains('{') {
            brace_region_end(lines, j)
        } else {
            // Unbraced item: runs to the line ending in `;`.
            let mut k = j;
            while k < n && !lines[k].code.trim_end().ends_with(';') {
                k += 1;
            }
            k.min(n - 1)
        };
        for line in lines.iter_mut().take(end + 1).skip(i) {
            line.in_test = true;
        }
        i = end + 1;
    }
}

/// Index of the line on which the brace region opened at line `start`
/// closes (depth returns to zero). Counts braces in the code view, so
/// braces inside strings/comments are already blanked.
fn brace_region_end(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for (k, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return k;
        }
    }
    lines.len() - 1
}

/// Result of scanning a comment for an allowance marker.
pub enum Allow {
    /// No marker present.
    None,
    /// Well-formed marker: `(rule_key, reason)`.
    Key(String, String),
    /// Marker present but the grammar is wrong; payload explains how.
    Malformed(String),
}

/// Allowance keys accepted in markers, with the rule each silences.
/// Example of the accepted form, as it appears in source:
/// `// LINT-ALLOW(panic): poisoning is unreachable, lock scope is three lines.`
pub const ALLOW_KEYS: [(&str, &str); 5] = [
    ("safety", "safety"),
    ("env-doc", "env-doc"),
    ("doc-sync", "doc-sync"),
    ("alloc", "alloc-guard"),
    ("panic", "no-panic"),
];

/// Parse an allowance marker out of comment text.
pub fn parse_allow(comment: &str) -> Allow {
    let Some(pos) = comment.find("LINT-ALLOW") else {
        return Allow::None;
    };
    let rest = &comment[pos + "LINT-ALLOW".len()..];
    let Some(body) = rest.strip_prefix('(') else {
        return Allow::Malformed(
            "expected `(` after LINT-ALLOW".to_string(),
        );
    };
    let Some(close) = body.find(')') else {
        return Allow::Malformed(
            "unclosed `(` in LINT-ALLOW marker".to_string(),
        );
    };
    let key = &body[..close];
    let after = &body[close + 1..];
    let Some(reason) = after.strip_prefix(':') else {
        return Allow::Malformed(
            "expected `:` and a reason after the rule key".to_string(),
        );
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Allow::Malformed(
            "empty reason — say why the allowance is sound".to_string(),
        );
    }
    Allow::Key(key.to_string(), reason.to_string())
}

/// Does this line's comment carry a well-formed allowance for `key`?
pub fn allows(line: &Line, key: &str) -> bool {
    matches!(parse_allow(&line.comment), Allow::Key(k, _) if k == key)
}

/// Find `word` in `code` at an identifier boundary (neither neighbor
/// is alphanumeric or `_`). Returns the byte offset of the match.
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let at = from + p;
        let end = at + word.len();
        let left_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let right_ok =
            end >= bytes.len() || !is_ident_byte(bytes[end]);
        if left_ok && right_ok {
            return Some(at);
        }
        from = at + word.len();
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> SourceFile {
        SourceFile::parse("rust/src/fake.rs", text)
    }

    #[test]
    fn line_comment_split() {
        let f = parse("let x = 1; // trailing note\n");
        assert_eq!(f.lines[0].code.trim_end(), "let x = 1;");
        assert_eq!(f.lines[0].comment, " trailing note");
    }

    #[test]
    fn slashes_inside_strings_are_code() {
        let f = parse("let u = \"https://example/a\"; // real\n");
        assert!(f.lines[0].comment.contains("real"));
        assert!(!f.lines[0].comment.contains("example"));
        // String contents blanked, quotes kept.
        assert!(f.lines[0].code.contains('"'));
        assert!(!f.lines[0].code.contains("https"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let f = parse("a/* one /* two */ still */b\n/* open\nend */c\n");
        assert_eq!(f.lines[0].code.replace(' ', ""), "ab");
        assert_eq!(f.lines[1].code.trim(), "");
        assert_eq!(f.lines[2].code.replace(' ', ""), "c");
        assert!(f.lines[1].comment.contains("open"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let f = parse(
            "let a = r#\"quote \" inside\"#;\nlet b = \"esc \\\" q\";\n",
        );
        assert!(!f.lines[0].code.contains("inside"));
        assert!(f.lines[0].code.trim_end().ends_with(';'));
        assert!(!f.lines[1].code.contains('q'));
        assert!(f.lines[1].code.trim_end().ends_with(';'));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let f = parse("fn f<'a>(x: &'a str) -> char { '}' }\n");
        // The brace inside the char literal must not unbalance code.
        let open =
            f.lines[0].code.chars().filter(|&c| c == '{').count();
        let close =
            f.lines[0].code.chars().filter(|&c| c == '}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let f = parse(
            "pub fn live() {}\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 fn helper() { x.unwrap(); }\n\
             }\n\
             pub fn also_live() {}\n",
        );
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allow_marker_grammar() {
        assert!(matches!(
            parse_allow(" LINT-ALLOW(panic): startup only."),
            Allow::Key(k, _) if k == "panic"
        ));
        assert!(matches!(
            parse_allow(" LINT-ALLOW(panic):"),
            Allow::Malformed(_)
        ));
        assert!(matches!(
            parse_allow(" LINT-ALLOW panic: x"),
            Allow::Malformed(_)
        ));
        assert!(matches!(parse_allow(" plain note"), Allow::None));
    }

    #[test]
    fn find_word_respects_boundaries() {
        assert!(find_word("call unsafe_op_in_unsafe_fn", "unsafe")
            .is_none());
        assert_eq!(find_word("an unsafe block", "unsafe"), Some(3));
    }
}
