//! Lint fixture (violating): decode-direction allocations sized by an
//! untrusted count with no cap check anywhere in the function. Never
//! compiled — loaded via `include_str!` by the rule self-tests.

pub fn decode_rows(n_raw: u32) -> Vec<u64> {
    let n = n_raw as usize;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        rows.push(0);
    }
    rows
}

pub fn read_payload(len_raw: u64) -> Vec<u8> {
    vec![0u8; len_raw as usize]
}
