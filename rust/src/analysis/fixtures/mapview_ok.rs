//! Lint fixture: a map-backing view reader that satisfies the safety
//! and alloc-guard rules — cap-check call before the length-driven
//! allocation, `SAFETY:` comment adjacent to the raw-pointer read.
//! Never compiled — loaded via `include_str!` by the rule self-tests.

fn check_view(len: usize, cap: usize) -> bool {
    len <= cap
}

pub fn read_view(bytes: &[u8], len: usize) -> Vec<f32> {
    if !check_view(len, bytes.len() / 4) {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(len);
    // SAFETY: `check_view` above bounded `len * 4` within `bytes`, and
    // `f32` has no invalid bit patterns, so the unaligned read stays
    // in bounds and yields a valid value.
    let head = unsafe { bytes.as_ptr().cast::<f32>().read_unaligned() };
    out.push(head);
    out
}
