//! Lint fixture (violating): a naked `unsafe` block with no adjacent
//! justification. Never compiled — loaded via `include_str!`.

pub fn naked(x: &[u8]) -> u8 {
    unsafe { *x.as_ptr() }
}
