//! Lint fixture: protocol/format constants for the doc-sync rule.
//! Never compiled — loaded via `include_str!` by the rule self-tests,
//! which pair it with small in-test markdown tables.

const K_HELLO: u16 = 1;
const K_DATA_ROW: u16 = 2;

const KIND_A: u16 = 1;
const KIND_B: u16 = 2;

pub const FLAG_ALPHA: u64 = 1;
pub const FLAG_BETA: u64 = 1 << 1;

pub const FORMAT_V1: u16 = 1;
pub const FORMAT_V2: u16 = 2;
pub const PAYLOAD_ALIGN: usize = 64;
