//! Lint fixture: reads one env var the test README documents and one
//! it does not. Never compiled — loaded via `include_str!`.

pub fn knobs() -> (Option<String>, Option<String>) {
    (
        std::env::var("APPROXRBF_FIXTURE_DOCUMENTED").ok(),
        std::env::var("APPROXRBF_FIXTURE_SECRET").ok(),
    )
}
