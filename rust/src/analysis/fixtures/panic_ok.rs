//! Lint fixture (passing): serving-plane code with no panic paths
//! outside a justified allowance. Never compiled — loaded via
//! `include_str!` by the rule self-tests.

use std::sync::{Mutex, PoisonError};

pub fn recover(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(PoisonError::into_inner)
}

pub fn allowed() -> u32 {
    // LINT-ALLOW(panic): fixture demonstrating a justified allowance.
    Some(1).unwrap()
}

pub fn message(msg: &str) -> String {
    // A panic pattern inside a string literal is data, not a panic
    // path — the classifier must not flag the next line.
    format!("{msg}: refusing to .unwrap() here")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        Some(3).unwrap();
        std::env::var("HOME").expect("test-only");
    }
}
