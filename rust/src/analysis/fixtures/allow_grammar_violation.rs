//! Lint fixture (violating): malformed allowance markers. Never
//! compiled — loaded via `include_str!` by the rule self-tests.

pub fn bad() -> u32 {
    // LINT-ALLOW(bogus): not a rule key arblint knows about.
    // LINT-ALLOW(panic):
    1
}
