//! Lint fixture (violating): two panic paths in non-test code. Never
//! compiled — loaded via `include_str!` by the rule self-tests.

pub fn brittle(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn message(v: Option<u32>) -> u32 {
    v.expect("value missing")
}
