//! Lint fixture (passing): every `unsafe` carries a justification.
//! Never compiled — loaded via `include_str!` by the rule self-tests.

/// Reads the first byte behind `p`.
///
/// # Safety
///
/// `p` must be non-null and valid for reads of one byte.
pub unsafe fn first_byte(p: *const u8) -> u8 {
    // SAFETY: the caller upholds validity per the function contract
    // spelled out in the doc comment above.
    unsafe { *p }
}

pub fn via_block(x: &[u8]) -> u8 {
    // SAFETY: `as_ptr` of a non-empty slice is valid for one read;
    // emptiness is checked by every caller in this fixture.
    unsafe { *x.as_ptr() }
}
