//! Lint fixture: map-view violations — a decode-direction function
//! that allocates from an untrusted length with no cap check, then
//! casts through a raw pointer with no `SAFETY:` justification.
//! Never compiled — loaded via `include_str!` by the rule self-tests.

pub fn read_view(bytes: &[u8], len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(len);
    let head = unsafe { bytes.as_ptr().cast::<f32>().read_unaligned() };
    out.push(head);
    out
}
