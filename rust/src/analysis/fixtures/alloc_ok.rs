//! Lint fixture (passing): decode-direction allocations behind a cap
//! check, and an encode-direction allocation that is exempt. Never
//! compiled — loaded via `include_str!` by the rule self-tests.

const CAP: usize = 4096;

fn checked_count(n: u32, cap: usize) -> Result<usize, String> {
    let n = n as usize;
    if n > cap {
        return Err(format!("count {n} exceeds cap {cap}"));
    }
    Ok(n)
}

pub fn decode_rows(n_raw: u32) -> Result<Vec<u64>, String> {
    let n = checked_count(n_raw, CAP)?;
    let mut rows = Vec::with_capacity(n);
    rows.resize(n, 0);
    Ok(rows)
}

pub fn encode_rows(rows: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * 8);
    for r in rows {
        out.extend_from_slice(&r.to_le_bytes());
    }
    out
}
