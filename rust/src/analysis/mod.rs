//! arblint — repo-native static analysis for invariants the compiler
//! cannot see.
//!
//! The serving plane carries several cross-cutting promises that live
//! half in code and half in documentation: every `unsafe` is
//! justified, every environment knob is in the README table, the wire
//! and `.arbf` constants match their format documents, untrusted
//! lengths are cap-checked before allocation, and the hot path has no
//! panic paths. Each of these has broken silently in other projects
//! precisely because nothing enforced it. This module enforces them
//! with a zero-dependency, line/token-level scanner — no rustc
//! plugin, no external crates — wired into tier-1 CI through the
//! `arblint` binary (`cargo run --bin arblint`) and into `cargo test`
//! through the [`tests`] meta-test, which fails whenever the live
//! tree is not lint-clean.
//!
//! Architecture: [`source`] classifies each line of a file into code,
//! comment and string-literal views (plus `#[cfg(test)]` region
//! marking); [`rules`] implements the checks as pure functions over
//! those views so fixtures under `fixtures/` (excluded from the live
//! walk) can exercise every rule in both the passing and the
//! violating direction. [`run_all`] walks the tree and runs
//! everything. Rule catalog, allowance grammar and known limitations
//! are documented in `docs/ANALYSIS.md`.

pub mod rules;
pub mod source;

use std::fmt;
use std::path::{Path, PathBuf};

use source::SourceFile;

/// One lint finding, printed as `file:line: rule: message`.
pub struct Diagnostic {
    /// Repo-relative path (`/`-separated).
    pub file: String,
    /// 1-based line; 0 for file-level findings.
    pub line: usize,
    /// Rule id, e.g. `no-panic`.
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Source roots scanned for `.rs` files, relative to the repo root.
const SCAN_ROOTS: [&str; 4] =
    ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Directory names never descended into: `vendor` holds third-party
/// stub code with its own conventions, `fixtures` holds deliberately
/// violating lint-test snippets.
const SKIP_DIRS: [&str; 2] = ["vendor", "fixtures"];

/// Run every rule against the repo rooted at `root`. Returns
/// diagnostics sorted by file and line; `Err` means the tree itself
/// could not be read (missing README/docs is a hard error — the
/// cross-check rules have nothing to check against).
pub fn run_all(root: &Path) -> Result<Vec<Diagnostic>, String> {
    let mut paths = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            collect_rs(&dir, &mut paths)?;
        }
    }
    paths.sort();

    let mut files = Vec::with_capacity(paths.len());
    for path in &paths {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        files.push(SourceFile::parse(&rel_path(root, path), &text));
    }

    let read_doc = |rel: &str| {
        std::fs::read_to_string(root.join(rel))
            .map_err(|e| format!("read {rel}: {e}"))
    };
    let readme = read_doc("README.md")?;
    let wire_md = read_doc("docs/WIRE.md")?;
    let formats_md = read_doc("docs/FORMATS.md")?;

    let mut diags = Vec::new();
    for f in &files {
        diags.extend(rules::check_safety(f));
        diags.extend(rules::check_allow_grammar(f));
        if rules::no_panic_scope(&f.rel) {
            diags.extend(rules::check_no_panic(f));
        }
        if rules::alloc_scope(&f.rel) {
            diags.extend(rules::check_alloc_guard(f));
        }
    }
    diags.extend(rules::check_env_doc(&files, "README.md", &readme));

    let find = |rel: &str| {
        files
            .iter()
            .find(|f| f.rel == rel)
            .ok_or_else(|| format!("{rel} not found under {SCAN_ROOTS:?}"))
    };
    diags.extend(rules::check_doc_sync(
        find("rust/src/net/wire.rs")?,
        "docs/WIRE.md",
        &wire_md,
        find("rust/src/registry/binfmt.rs")?,
        "docs/FORMATS.md",
        &formats_md,
    ));

    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(diags)
}

/// Number of files [`run_all`] would scan — reported by the binary so
/// "clean" is distinguishable from "scanned nothing".
pub fn scanned_file_count(root: &Path) -> usize {
    let mut paths = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() && collect_rs(&dir, &mut paths).is_err() {
            return 0;
        }
    }
    paths.len()
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry =
            entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_ref()) {
                collect_rs(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `root`-relative `/`-separated form of `path`.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The meta-test: `cargo test` fails whenever the live tree has
    /// any arblint finding, so tier-1 enforces lint cleanliness even
    /// where CI forgets to invoke the binary.
    #[test]
    fn live_tree_is_lint_clean() {
        let root =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let diags = run_all(&root).expect("walk the live tree");
        if !diags.is_empty() {
            let mut report = String::new();
            for d in &diags {
                report.push_str(&format!("{d}\n"));
            }
            panic!(
                "arblint found {} violation(s) in the live tree:\n\
                 {report}",
                diags.len()
            );
        }
    }

    #[test]
    fn walker_skips_fixtures_and_vendor() {
        let root =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let mut paths = Vec::new();
        for scan in SCAN_ROOTS {
            let dir = root.join(scan);
            if dir.is_dir() {
                collect_rs(&dir, &mut paths).expect("walk");
            }
        }
        assert!(!paths.is_empty());
        for p in &paths {
            let s = p.to_string_lossy();
            assert!(
                !s.contains("fixtures") && !s.contains("vendor"),
                "walker descended into an excluded dir: {s}"
            );
        }
        // The files the doc-sync rule needs must be in the walk.
        let rels: Vec<String> =
            paths.iter().map(|p| rel_path(&root, p)).collect();
        assert!(rels.iter().any(|r| r == "rust/src/net/wire.rs"));
        assert!(rels
            .iter()
            .any(|r| r == "rust/src/registry/binfmt.rs"));
    }
}
