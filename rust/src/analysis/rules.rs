//! The five arblint rules. Each is a pure function from classified
//! sources (and, where relevant, documentation text) to diagnostics,
//! so the fixture suite can drive every rule on synthetic inputs and
//! the meta-test can drive them all on the live tree.
//!
//! Catalog (ids as printed in diagnostics; details in
//! `docs/ANALYSIS.md`):
//!
//! * `safety` — every `unsafe` token carries an adjacent
//!   justification: a `SAFETY:` comment or a `# Safety` doc section.
//! * `env-doc` — the set of `APPROXRBF_*` names appearing anywhere in
//!   the scanned sources equals the set documented in the README's
//!   "Environment variables" table, in both directions.
//! * `doc-sync` — wire message-kind constants match the table in
//!   `docs/WIRE.md`; `.arbf` record-kind, flag and container-format
//!   constants (`FORMAT_V*`, `PAYLOAD_ALIGN`) match `docs/FORMATS.md`.
//! * `alloc-guard` — decode-direction functions in the binary-format,
//!   map-backing and wire modules show cap-check evidence before
//!   allocating from a length that untrusted bytes control.
//! * `no-panic` — no `.unwrap()` / `.expect(` / `panic!`-family
//!   macros in non-test serving-plane code.
//!
//! A sixth internal rule, `allow-grammar`, rejects malformed or
//! unknown allowance markers so a typo cannot silently disable a rule.

use super::source::{
    allows, find_word, parse_allow, Allow, SourceFile, ALLOW_KEYS,
};
use super::Diagnostic;

/// Environment-variable prefix this repo owns. Built by concatenation
/// so the scanner does not count its own definition as a usage site.
fn env_prefix() -> String {
    format!("{}_", "APPROXRBF")
}

fn diag(file: &str, line: usize, rule: &'static str, message: String) -> Diagnostic {
    Diagnostic { file: file.to_string(), line, rule, message }
}

// ---------------------------------------------------------------------
// scope routing
// ---------------------------------------------------------------------

/// Files the `no-panic` rule covers: the serving plane, where a panic
/// takes down a coordinator or shard thread mid-request.
pub fn no_panic_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/coordinator/")
        || rel.starts_with("rust/src/net/")
        || rel == "rust/src/predictor.rs"
}

/// Files the `alloc-guard` rule covers: the modules that parse
/// attacker-controllable bytes (model files, their mapped backing, and
/// wire frames).
pub fn alloc_scope(rel: &str) -> bool {
    rel == "rust/src/registry/binfmt.rs"
        || rel == "rust/src/registry/mapfile.rs"
        || rel == "rust/src/net/wire.rs"
}

// ---------------------------------------------------------------------
// rule: safety
// ---------------------------------------------------------------------

/// Flag `unsafe` tokens with no adjacent justification. Evidence is a
/// `SAFETY` marker or `# Safety` doc heading on the same line's
/// comment or in the contiguous comment/attribute block directly
/// above (doc block and attributes of the item count; a blank line
/// breaks adjacency so stale justifications cannot drift far away).
pub fn check_safety(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        if find_word(&line.code, "unsafe").is_none() {
            continue;
        }
        if comment_justifies(&line.comment) || allows(line, "safety") {
            continue;
        }
        let mut justified = false;
        let mut k = idx;
        while k > 0 {
            k -= 1;
            let above = &f.lines[k];
            let code = above.code.trim();
            if code.is_empty() && !above.comment.trim().is_empty() {
                if comment_justifies(&above.comment) || allows(above, "safety") {
                    justified = true;
                    break;
                }
            } else if code.starts_with("#[") || code.starts_with("#!") {
                // Attributes sit between a doc block and the item.
            } else {
                break;
            }
        }
        if !justified {
            out.push(diag(
                &f.rel,
                idx + 1,
                "safety",
                "`unsafe` without an adjacent `SAFETY:` comment or \
                 `# Safety` doc section"
                    .to_string(),
            ));
        }
    }
    out
}

fn comment_justifies(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

// ---------------------------------------------------------------------
// rule: no-panic
// ---------------------------------------------------------------------

const PANIC_PATTERNS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Flag panic paths in non-test code. An allowance marker on the same
/// line or on a comment-only line directly above silences one site.
pub fn check_no_panic(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let Some(pat) =
            PANIC_PATTERNS.iter().find(|p| line.code.contains(*p))
        else {
            continue;
        };
        let allowed = allows(line, "panic")
            || (idx > 0
                && f.lines[idx - 1].code.trim().is_empty()
                && allows(&f.lines[idx - 1], "panic"));
        if !allowed {
            out.push(diag(
                &f.rel,
                idx + 1,
                "no-panic",
                format!(
                    "`{pat}` in serving-plane code — return an error \
                     or recover (poisoned locks: \
                     `crate::util::sync`); if genuinely unreachable, \
                     annotate with an allowance marker"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule: alloc-guard
// ---------------------------------------------------------------------

/// Does this function name sit on the decode (untrusted-input) side?
fn decode_direction(name: &str) -> bool {
    name.starts_with("decode")
        || name.starts_with("read")
        || name.starts_with("peek")
        || name == "record_frames"
}

/// Flag allocations sized by a runtime value inside decode-direction
/// functions unless the function shows cap-check evidence first: a
/// call to `checked_count`/`check_*` (element-count caps) or
/// `peek_header`/`parse_header` (which bound counts before any caller
/// allocates). Encode-direction functions size allocations from data
/// the process already holds, so they are exempt; `collect()`-based
/// allocations are bounded by the `Reader::take` slice length by
/// construction and are not pattern-matched here (see
/// `docs/ANALYSIS.md` for both limitations).
pub fn check_alloc_guard(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut cur_fn: Option<(String, usize)> = None;
    for (idx, line) in f.lines.iter().enumerate() {
        if let Some(name) = fn_decl_name(&line.code) {
            cur_fn = Some((name, idx));
        }
        if line.in_test {
            continue;
        }
        let Some((name, start)) = &cur_fn else { continue };
        if !decode_direction(name) {
            continue;
        }
        for expr in alloc_size_exprs(&line.code) {
            if !expr_is_dynamic(&expr) {
                continue;
            }
            let evidence = f.lines[*start..=idx]
                .iter()
                .any(|l| has_guard_evidence(&l.code));
            let allowed = allows(line, "alloc")
                || (idx > 0
                    && f.lines[idx - 1].code.trim().is_empty()
                    && allows(&f.lines[idx - 1], "alloc"));
            if !evidence && !allowed {
                out.push(diag(
                    &f.rel,
                    idx + 1,
                    "alloc-guard",
                    format!(
                        "allocation sized by `{}` in decode-direction \
                         fn `{name}` with no cap-check call \
                         (`checked_count`/`check_*`/`peek_header`/\
                         `parse_header`) earlier in the function",
                        expr.trim()
                    ),
                ));
            }
        }
    }
    out
}

/// Extract the name of a `fn` declared on this line, if any.
fn fn_decl_name(code: &str) -> Option<String> {
    let pos = find_word(code, "fn")?;
    let rest = code[pos + 2..].trim_start();
    let name: String = rest
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Size expressions of explicit allocations on this line:
/// `with_capacity(E)`, `vec![_; E]`, `.resize(E, …)`, `.reserve(E)`.
fn alloc_size_exprs(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    for pat in ["with_capacity(", ".reserve("] {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            let open = from + p + pat.len() - 1;
            if let Some(inner) = balanced(code, open, '(', ')') {
                out.push(inner);
            }
            from += p + pat.len();
        }
    }
    let mut from = 0;
    while let Some(p) = code[from..].find(".resize(") {
        let open = from + p + ".resize(".len() - 1;
        if let Some(inner) = balanced(code, open, '(', ')') {
            out.push(top_level_head(&inner, ','));
        }
        from += p + ".resize(".len();
    }
    let mut from = 0;
    while let Some(p) = code[from..].find("vec![") {
        let open = from + p + "vec![".len() - 1;
        if let Some(inner) = balanced(code, open, '[', ']') {
            if let Some(size) = top_level_tail(&inner, ';') {
                out.push(size);
            }
        }
        from += p + "vec![".len();
    }
    out
}

/// Contents of the bracket pair opening at byte `open` (exclusive of
/// the delimiters); `None` if it does not close on this line.
fn balanced(code: &str, open: usize, lhs: char, rhs: char) -> Option<String> {
    let mut depth = 0i64;
    for (off, c) in code[open..].char_indices() {
        if c == lhs {
            depth += 1;
        } else if c == rhs {
            depth -= 1;
            if depth == 0 {
                return Some(code[open + 1..open + off].to_string());
            }
        }
    }
    None
}

/// `expr` up to its first top-level `sep` (whole expr if none).
fn top_level_head(expr: &str, sep: char) -> String {
    match split_top_level(expr, sep) {
        Some(at) => expr[..at].to_string(),
        None => expr.to_string(),
    }
}

/// `expr` after its first top-level `sep`, if present.
fn top_level_tail(expr: &str, sep: char) -> Option<String> {
    split_top_level(expr, sep).map(|at| expr[at + 1..].to_string())
}

fn split_top_level(expr: &str, sep: char) -> Option<usize> {
    let mut depth = 0i64;
    for (off, c) in expr.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            c if c == sep && depth == 0 => return Some(off),
            _ => {}
        }
    }
    None
}

/// A size expression is dynamic when it mentions any lowercase-leading
/// identifier other than primitive-type/keyword noise — numeric
/// literals and `SCREAMING_CASE` constants are compile-time facts.
fn expr_is_dynamic(expr: &str) -> bool {
    const KEYWORDS: [&str; 14] = [
        "as", "usize", "isize", "u8", "u16", "u32", "u64", "i8", "i16",
        "i32", "i64", "f32", "f64", "const",
    ];
    let mut token = String::new();
    let mut tokens = Vec::new();
    for c in expr.chars().chain(std::iter::once(' ')) {
        if c.is_alphanumeric() || c == '_' {
            token.push(c);
        } else if !token.is_empty() {
            tokens.push(std::mem::take(&mut token));
        }
    }
    tokens.iter().any(|t| {
        t.chars().next().is_some_and(|c| {
            c.is_lowercase() || c == '_'
        }) && !KEYWORDS.contains(&t.as_str())
    })
}

fn has_guard_evidence(code: &str) -> bool {
    for pat in ["checked_count(", "peek_header(", "parse_header("] {
        if code.contains(pat) {
            return true;
        }
    }
    // Any `check_…(` call counts: the element-cap helpers in binfmt
    // follow this naming scheme and new ones should too.
    let mut from = 0;
    while let Some(p) = code[from..].find("check_") {
        let at = from + p;
        let prev_is_ident = at > 0 && {
            let b = code.as_bytes()[at - 1];
            b.is_ascii_alphanumeric() || b == b'_'
        };
        if !prev_is_ident {
            let rest = &code[at + "check_".len()..];
            let ident_end = rest
                .find(|c: char| !c.is_alphanumeric() && c != '_')
                .unwrap_or(rest.len());
            if rest[ident_end..].starts_with('(') {
                return true;
            }
        }
        from = at + "check_".len();
    }
    false
}

// ---------------------------------------------------------------------
// rule: env-doc
// ---------------------------------------------------------------------

/// Section heading the README table must live under.
pub const ENV_SECTION: &str = "## Environment variables";

/// Cross-check environment-variable usage against the README table.
/// Both directions are errors: an undocumented variable and a stale
/// table row. Scans raw lines — the names appear inside string
/// literals at their read sites and inside backticks in docs.
pub fn check_env_doc(files: &[SourceFile], readme_rel: &str, readme: &str) -> Vec<Diagnostic> {
    let prefix = env_prefix();
    let mut used: Vec<(String, String, usize)> = Vec::new();
    for f in files {
        for (idx, line) in f.lines.iter().enumerate() {
            // Unit-test regions are skipped: tests pin variables that
            // non-test code reads, and lint fixtures referenced from
            // test modules may name variables that exist nowhere else.
            if line.in_test {
                continue;
            }
            for var in scan_env_vars(&line.raw, &prefix) {
                used.push((var, f.rel.clone(), idx + 1));
            }
        }
    }

    let mut out = Vec::new();
    let mut documented: Vec<(String, usize)> = Vec::new();
    let mut in_section = false;
    let mut section_seen = false;
    for (idx, line) in readme.lines().enumerate() {
        let t = line.trim();
        if t == ENV_SECTION {
            in_section = true;
            section_seen = true;
            continue;
        }
        if in_section && t.starts_with("## ") {
            in_section = false;
        }
        if in_section && t.starts_with('|') {
            if let Some(cell) = t.trim_start_matches('|').split('|').next() {
                for var in scan_env_vars(cell, &prefix) {
                    documented.push((var, idx + 1));
                }
            }
        }
    }
    if !section_seen {
        out.push(diag(
            readme_rel,
            0,
            "env-doc",
            format!("README has no `{ENV_SECTION}` section"),
        ));
        return out;
    }

    // Report each undocumented variable once, at its first occurrence
    // (files arrive sorted, so "first" is deterministic).
    let mut reported: Vec<&str> = Vec::new();
    for (var, rel, line) in &used {
        if documented.iter().any(|(d, _)| d == var) || reported.iter().any(|r| r == var) {
            continue;
        }
        reported.push(var);
        out.push(diag(
            rel,
            *line,
            "env-doc",
            format!(
                "`{var}` is read here but missing from the README \
                 `{ENV_SECTION}` table"
            ),
        ));
    }
    for (var, line) in &documented {
        if !used.iter().any(|(u, _, _)| u == var) {
            out.push(diag(
                readme_rel,
                *line,
                "env-doc",
                format!(
                    "`{var}` is documented but no longer read \
                     anywhere — stale table row"
                ),
            ));
        }
    }
    out
}

/// All `PREFIX…` names in `text` (at least one name char after the
/// prefix, so prose like a bare glob pattern does not count).
fn scan_env_vars(text: &str, prefix: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = text[from..].find(prefix) {
        let at = from + p;
        let rest = &text[at + prefix.len()..];
        let name_len = rest
            .find(|c: char| !c.is_ascii_uppercase() && !c.is_ascii_digit() && c != '_')
            .unwrap_or(rest.len());
        if name_len > 0 {
            let full = &text[at..at + prefix.len() + name_len];
            out.push(full.trim_end_matches('_').to_string());
        }
        from = at + prefix.len();
    }
    out
}

// ---------------------------------------------------------------------
// rule: doc-sync
// ---------------------------------------------------------------------

/// Cross-check protocol/format constants against their documentation
/// tables. Four legs: wire message kinds vs. `docs/WIRE.md`, `.arbf`
/// record-kind tags vs. `docs/FORMATS.md`, `.arbf` header flag bits
/// vs. `docs/FORMATS.md`, and the container-format constants
/// (`FORMAT_V*`, `PAYLOAD_ALIGN`) vs. the FORMATS.md
/// `` `NAME` = N `` annotations. Any drift — missing, extra, or a
/// value mismatch — is a hard error in both directions.
pub fn check_doc_sync(
    wire: &SourceFile,
    wire_md_rel: &str,
    wire_md: &str,
    binfmt: &SourceFile,
    formats_md_rel: &str,
    formats_md: &str,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // Leg 1: `const K_*: u16` vs. the WIRE.md message-kind table.
    let code_kinds = scan_u16_consts(wire, "K_");
    let doc_kinds = wire_md_kinds(wire_md);
    if doc_kinds.is_empty() {
        out.push(diag(
            wire_md_rel,
            0,
            "doc-sync",
            "no message-kind table found under `## Message kinds`"
                .to_string(),
        ));
    }
    for (name, value, line) in &code_kinds {
        match doc_kinds.iter().find(|(n, _, _)| n == name) {
            None => out.push(diag(
                &wire.rel,
                *line,
                "doc-sync",
                format!(
                    "`{name}` = {value} is not in the \
                     `{wire_md_rel}` message-kind table"
                ),
            )),
            Some((_, doc_value, doc_line)) if doc_value != value => {
                out.push(diag(
                    wire_md_rel,
                    *doc_line,
                    "doc-sync",
                    format!(
                        "table says `{name}` = {doc_value}, code says \
                         {value}"
                    ),
                ));
            }
            Some(_) => {}
        }
    }
    for (name, value, line) in &doc_kinds {
        if !code_kinds.iter().any(|(n, _, _)| n == name) {
            out.push(diag(
                wire_md_rel,
                *line,
                "doc-sync",
                format!(
                    "table lists `{name}` = {value} but no such \
                     constant exists in `{}`",
                    wire.rel
                ),
            ));
        }
    }

    // Leg 2: `const KIND_*: u16` values vs. the FORMATS.md record
    // framing row. The docs name kinds in prose, so this leg compares
    // the tag-value sets.
    let code_tags = scan_u16_consts(binfmt, "KIND_");
    match formats_kind_row(formats_md) {
        None => out.push(diag(
            formats_md_rel,
            0,
            "doc-sync",
            "no record-kind row (`| kind |` with `u16:` tags) found"
                .to_string(),
        )),
        Some((doc_tags, doc_line)) => {
            for (name, value, line) in &code_tags {
                if !doc_tags.contains(value) {
                    out.push(diag(
                        &binfmt.rel,
                        *line,
                        "doc-sync",
                        format!(
                            "`{name}` = {value} is not listed in the \
                             `{formats_md_rel}` record-kind row"
                        ),
                    ));
                }
            }
            for tag in &doc_tags {
                if !code_tags.iter().any(|(_, v, _)| v == tag) {
                    out.push(diag(
                        formats_md_rel,
                        doc_line,
                        "doc-sync",
                        format!(
                            "record-kind row lists tag `{tag}` but no \
                             `KIND_*` constant has that value in `{}`",
                            binfmt.rel
                        ),
                    ));
                }
            }
        }
    }

    // Leg 3: `const FLAG_*: u64` bit positions vs. the FORMATS.md
    // `bit N (`FLAG_X`)` annotations.
    let code_flags = scan_flag_bits(binfmt);
    let doc_flags = formats_flag_bits(formats_md);
    if doc_flags.is_empty() {
        out.push(diag(
            formats_md_rel,
            0,
            "doc-sync",
            "no flag-bit annotations (`bit N (\u{60}FLAG_X\u{60})`) \
             found"
                .to_string(),
        ));
    }
    for (name, bit, line) in &code_flags {
        match doc_flags.iter().find(|(n, _, _)| n == name) {
            None => out.push(diag(
                &binfmt.rel,
                *line,
                "doc-sync",
                format!(
                    "`{name}` (bit {bit}) is not documented in \
                     `{formats_md_rel}`"
                ),
            )),
            Some((_, doc_bit, doc_line)) if doc_bit != bit => {
                out.push(diag(
                    formats_md_rel,
                    *doc_line,
                    "doc-sync",
                    format!(
                        "docs put `{name}` at bit {doc_bit}, code at \
                         bit {bit}"
                    ),
                ));
            }
            Some(_) => {}
        }
    }
    for (name, bit, line) in &doc_flags {
        if !code_flags.iter().any(|(n, _, _)| n == name) {
            out.push(diag(
                formats_md_rel,
                *line,
                "doc-sync",
                format!(
                    "docs document `{name}` (bit {bit}) but no such \
                     constant exists in `{}`",
                    binfmt.rel
                ),
            ));
        }
    }

    // Leg 4: container-format constants (`FORMAT_V*` version tags and
    // the `PAYLOAD_ALIGN` alignment) vs. the FORMATS.md
    // `` `NAME` = N `` annotations. The leg is skipped entirely when
    // the code declares no such constants, so single-format trees and
    // the snippet fixtures predating v2 stay silent.
    let mut fmt_consts: Vec<(String, u64, usize)> =
        scan_u16_consts(binfmt, "FORMAT_V")
            .into_iter()
            .map(|(n, v, l)| (n, u64::from(v), l))
            .collect();
    if let Some((v, l)) = scan_usize_const(binfmt, "PAYLOAD_ALIGN") {
        fmt_consts.push(("PAYLOAD_ALIGN".to_string(), v, l));
    }
    if !fmt_consts.is_empty() {
        let doc_vals = formats_named_values(formats_md);
        for (name, value, line) in &fmt_consts {
            match doc_vals.iter().find(|(n, _, _)| n == name) {
                None => out.push(diag(
                    &binfmt.rel,
                    *line,
                    "doc-sync",
                    format!(
                        "`{name}` = {value} has no `\u{60}{name}\u{60} \
                         = {value}` annotation in `{formats_md_rel}`"
                    ),
                )),
                Some((_, doc_value, doc_line)) if doc_value != value => {
                    out.push(diag(
                        formats_md_rel,
                        *doc_line,
                        "doc-sync",
                        format!(
                            "docs say `{name}` = {doc_value}, code \
                             says {value}"
                        ),
                    ));
                }
                Some(_) => {}
            }
        }
        for (name, value, line) in &doc_vals {
            if !fmt_consts.iter().any(|(n, _, _)| n == name) {
                out.push(diag(
                    formats_md_rel,
                    *line,
                    "doc-sync",
                    format!(
                        "docs annotate `{name}` = {value} but no such \
                         constant exists in `{}`",
                        binfmt.rel
                    ),
                ));
            }
        }
    }
    out
}

/// `const PREFIX…: u16 = N;` declarations in non-test code:
/// `(name, value, 1-based line)`.
fn scan_u16_consts(f: &SourceFile, prefix: &str) -> Vec<(String, u16, usize)> {
    let mut out = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        let Some(p) = code.find("const ") else { continue };
        let rest = &code[p + "const ".len()..];
        if !rest.starts_with(prefix) {
            continue;
        }
        let name_len = rest
            .find(|c: char| !c.is_alphanumeric() && c != '_')
            .unwrap_or(rest.len());
        let name = &rest[..name_len];
        let Some((ty, value)) = rest[name_len..].split_once('=') else {
            continue;
        };
        if !ty.contains("u16") {
            continue;
        }
        let digits: String = value
            .chars()
            .skip_while(|c| c.is_whitespace())
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if let Ok(v) = digits.parse::<u16>() {
            out.push((name.to_string(), v, idx + 1));
        }
    }
    out
}

/// Rows of the WIRE.md message-kind table, as
/// `(K_SNAKE_NAME, tag, 1-based line)`.
fn wire_md_kinds(md: &str) -> Vec<(String, u16, usize)> {
    let mut out = Vec::new();
    let mut in_section = false;
    for (idx, line) in md.lines().enumerate() {
        let t = line.trim();
        if t == "## Message kinds" {
            in_section = true;
            continue;
        }
        if in_section && (t.starts_with("## ") || t.starts_with("### ")) {
            break;
        }
        if !in_section || !t.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> =
            t.trim_matches('|').split('|').map(str::trim).collect();
        if cells.len() < 2 {
            continue;
        }
        let Ok(tag) = cells[0].parse::<u16>() else { continue };
        let Some(name) = backticked(cells[1]) else { continue };
        out.push((camel_to_kind(&name), tag, idx + 1));
    }
    out
}

/// `HelloAck` → `K_HELLO_ACK`.
fn camel_to_kind(name: &str) -> String {
    let mut out = String::from("K_");
    for (i, c) in name.chars().enumerate() {
        if c.is_uppercase() && i > 0 {
            out.push('_');
        }
        for u in c.to_uppercase() {
            out.push(u);
        }
    }
    out
}

/// First backticked span in `text`.
fn backticked(text: &str) -> Option<String> {
    let open = text.find('\u{60}')?;
    let rest = &text[open + 1..];
    let close = rest.find('\u{60}')?;
    Some(rest[..close].to_string())
}

/// The FORMATS.md record-kind row: the set of backticked integer tags
/// on the `| kind |` table line, plus that line's number.
fn formats_kind_row(md: &str) -> Option<(Vec<u16>, usize)> {
    for (idx, line) in md.lines().enumerate() {
        if !(line.contains("| kind |") && line.contains("u16:")) {
            continue;
        }
        let mut tags = Vec::new();
        let mut rest = line;
        while let Some(open) = rest.find('\u{60}') {
            let after = &rest[open + 1..];
            let Some(close) = after.find('\u{60}') else { break };
            if let Ok(v) = after[..close].parse::<u16>() {
                tags.push(v);
            }
            rest = &after[close + 1..];
        }
        if !tags.is_empty() {
            return Some((tags, idx + 1));
        }
    }
    None
}

/// `const FLAG_*: u64 = 1;` / `= 1 << N;` as `(name, bit, line)`.
fn scan_flag_bits(f: &SourceFile) -> Vec<(String, u32, usize)> {
    let mut out = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        let Some(p) = code.find("const FLAG_") else { continue };
        let rest = &code[p + "const ".len()..];
        let name_len = rest
            .find(|c: char| !c.is_alphanumeric() && c != '_')
            .unwrap_or(rest.len());
        let name = rest[..name_len].to_string();
        let Some((_, value)) = rest.split_once('=') else { continue };
        let value = value.trim().trim_end_matches(';').trim();
        let bit = if value == "1" {
            Some(0)
        } else {
            value.split_once("<<").and_then(|(one, shift)| {
                (one.trim() == "1")
                    .then(|| shift.trim().parse::<u32>().ok())
                    .flatten()
            })
        };
        if let Some(bit) = bit {
            out.push((name, bit, idx + 1));
        }
    }
    out
}

/// `bit N (`FLAG_X`)` annotations anywhere in FORMATS.md, as
/// `(name, bit, line)`.
fn formats_flag_bits(md: &str) -> Vec<(String, u32, usize)> {
    let mut out = Vec::new();
    for (idx, line) in md.lines().enumerate() {
        let mut rest: &str = line;
        while let Some(p) = rest.find("bit ") {
            let after = &rest[p + "bit ".len()..];
            let digit_len = after
                .find(|c: char| !c.is_ascii_digit())
                .unwrap_or(after.len());
            if digit_len == 0 {
                rest = after;
                continue;
            }
            let Ok(bit) = after[..digit_len].parse::<u32>() else {
                rest = after;
                continue;
            };
            let tail = &after[digit_len..];
            if let Some(name_part) = tail.strip_prefix(" (\u{60}") {
                if let Some(close) = name_part.find('\u{60}') {
                    let name = &name_part[..close];
                    if name.starts_with("FLAG_") {
                        out.push((name.to_string(), bit, idx + 1));
                    }
                }
            }
            rest = tail;
        }
    }
    out
}

/// `const NAME: usize = N;` in non-test code, matched by exact name:
/// `(value, 1-based line)`.
fn scan_usize_const(f: &SourceFile, name: &str) -> Option<(u64, usize)> {
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        let Some(p) = code.find("const ") else { continue };
        let Some(rest) = code[p + "const ".len()..].strip_prefix(name)
        else {
            continue;
        };
        let Some((ty, value)) = rest.split_once('=') else { continue };
        // `ty` must open with the type annotation, so a longer name
        // sharing this prefix (e.g. PAYLOAD_ALIGN_MAX) never matches.
        if !ty.trim_start().starts_with(':') || !ty.contains("usize") {
            continue;
        }
        let digits: String = value
            .chars()
            .skip_while(|c| c.is_whitespace())
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if let Ok(v) = digits.parse::<u64>() {
            return Some((v, idx + 1));
        }
    }
    None
}

/// `` `FORMAT_V*` = N `` / `` `PAYLOAD_ALIGN` = N `` annotations
/// anywhere in FORMATS.md, as `(name, value, line)`.
fn formats_named_values(md: &str) -> Vec<(String, u64, usize)> {
    let mut out = Vec::new();
    for (idx, line) in md.lines().enumerate() {
        let mut rest: &str = line;
        while let Some(open) = rest.find('\u{60}') {
            let after = &rest[open + 1..];
            let Some(close) = after.find('\u{60}') else { break };
            let name = &after[..close];
            let tail = &after[close + 1..];
            if name.starts_with("FORMAT_V") || name == "PAYLOAD_ALIGN" {
                if let Some(value) = tail.strip_prefix(" = ") {
                    let digits: String = value
                        .chars()
                        .take_while(|c| c.is_ascii_digit())
                        .collect();
                    if let Ok(v) = digits.parse::<u64>() {
                        out.push((name.to_string(), v, idx + 1));
                    }
                }
            }
            rest = tail;
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule: allow-grammar
// ---------------------------------------------------------------------

/// Reject malformed allowance markers and unknown rule keys, so a
/// typo can never silently disable a rule.
pub fn check_allow_grammar(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        if !line.comment.contains("LINT-ALLOW") {
            continue;
        }
        match parse_allow(&line.comment) {
            Allow::None => {}
            Allow::Malformed(why) => out.push(diag(
                &f.rel,
                idx + 1,
                "allow-grammar",
                format!("malformed allowance marker: {why}"),
            )),
            Allow::Key(key, _) => {
                if !ALLOW_KEYS.iter().any(|(k, _)| *k == key) {
                    let known: Vec<&str> =
                        ALLOW_KEYS.iter().map(|(k, _)| *k).collect();
                    out.push(diag(
                        &f.rel,
                        idx + 1,
                        "allow-grammar",
                        format!(
                            "unknown allowance key `{key}` (known: \
                             {})",
                            known.join(", ")
                        ),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::source::SourceFile;

    fn sf(rel: &str, text: &str) -> SourceFile {
        SourceFile::parse(rel, text)
    }

    // ---- rule: safety ------------------------------------------------

    #[test]
    fn safety_fixture_passes() {
        let f = sf(
            "rust/src/linalg/fixture.rs",
            include_str!("fixtures/safety_ok.rs"),
        );
        let diags = check_safety(&f);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn safety_fixture_flags_naked_unsafe() {
        let f = sf(
            "rust/src/linalg/fixture.rs",
            include_str!("fixtures/safety_violation.rs"),
        );
        let diags = check_safety(&f);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, "safety");
    }

    // ---- rule: no-panic ----------------------------------------------

    #[test]
    fn no_panic_fixture_passes() {
        let f = sf(
            "rust/src/net/fixture.rs",
            include_str!("fixtures/panic_ok.rs"),
        );
        let diags = check_no_panic(&f);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn no_panic_fixture_flags_unwrap_and_expect() {
        let f = sf(
            "rust/src/net/fixture.rs",
            include_str!("fixtures/panic_violation.rs"),
        );
        let diags = check_no_panic(&f);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.rule == "no-panic"));
    }

    #[test]
    fn no_panic_scope_covers_serving_plane_only() {
        assert!(no_panic_scope("rust/src/coordinator/server.rs"));
        assert!(no_panic_scope("rust/src/net/router.rs"));
        assert!(no_panic_scope("rust/src/predictor.rs"));
        assert!(!no_panic_scope("rust/src/registry/binfmt.rs"));
        assert!(!no_panic_scope("rust/tests/shard_test.rs"));
    }

    // ---- rule: alloc-guard -------------------------------------------

    #[test]
    fn alloc_fixture_passes() {
        let f = sf(
            "rust/src/net/wire.rs",
            include_str!("fixtures/alloc_ok.rs"),
        );
        let diags = check_alloc_guard(&f);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn alloc_fixture_flags_unguarded_decode() {
        let f = sf(
            "rust/src/net/wire.rs",
            include_str!("fixtures/alloc_violation.rs"),
        );
        let diags = check_alloc_guard(&f);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.rule == "alloc-guard"));
    }

    #[test]
    fn mapview_fixture_passes_safety_and_alloc() {
        assert!(alloc_scope("rust/src/registry/mapfile.rs"));
        let f = sf(
            "rust/src/registry/mapfile.rs",
            include_str!("fixtures/mapview_ok.rs"),
        );
        let mut diags = check_safety(&f);
        diags.extend(check_alloc_guard(&f));
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn mapview_fixture_flags_naked_cast_and_unguarded_alloc() {
        let f = sf(
            "rust/src/registry/mapfile.rs",
            include_str!("fixtures/mapview_violation.rs"),
        );
        let safety = check_safety(&f);
        assert_eq!(safety.len(), 1, "{safety:?}");
        assert_eq!(safety[0].rule, "safety");
        let alloc = check_alloc_guard(&f);
        assert_eq!(alloc.len(), 1, "{alloc:?}");
        assert_eq!(alloc[0].rule, "alloc-guard");
        assert!(alloc[0].message.contains("read_view"), "{alloc:?}");
    }

    // ---- rule: env-doc -----------------------------------------------

    const FAKE_README: &str = "\
# fixture\n\n## Environment variables\n\n\
| variable | values |\n|---|---|\n\
| \u{60}APPROXRBF_FIXTURE_DOCUMENTED\u{60} | any |\n\
| \u{60}APPROXRBF_FIXTURE_REMOVED\u{60} | any |\n\n## Next\n";

    #[test]
    fn env_doc_flags_both_directions() {
        let files = [sf(
            "rust/src/fixture.rs",
            include_str!("fixtures/envdoc_snippet.rs"),
        )];
        let diags = check_env_doc(&files, "README.md", FAKE_README);
        assert_eq!(diags.len(), 2, "{diags:?}");
        let messages: Vec<&str> =
            diags.iter().map(|d| d.message.as_str()).collect();
        assert!(messages
            .iter()
            .any(|m| m.contains("APPROXRBF_FIXTURE_SECRET")));
        assert!(messages
            .iter()
            .any(|m| m.contains("APPROXRBF_FIXTURE_REMOVED")));
    }

    #[test]
    fn env_doc_requires_the_section() {
        let files = [sf(
            "rust/src/fixture.rs",
            include_str!("fixtures/envdoc_snippet.rs"),
        )];
        let diags = check_env_doc(&files, "README.md", "# no table\n");
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].message.contains("no"));
    }

    // ---- rule: doc-sync ----------------------------------------------

    const SNIPPET_WIRE_MD: &str = "\
# wire\n\n## Message kinds\n\n\
| tag | message |\n|---|---|\n\
| 1 | \u{60}Hello\u{60} |\n| 2 | \u{60}DataRow\u{60} |\n\n## Next\n";

    const SNIPPET_FORMATS_MD: &str = "\
# formats\n\n\
| 0 | 2 | kind | u16: \u{60}1\u{60} = a, \u{60}2\u{60} = b |\n\
flags: bit 0 (\u{60}FLAG_ALPHA\u{60}); bit 1 (\u{60}FLAG_BETA\u{60})\n\
versions: \u{60}FORMAT_V1\u{60} = 1, \u{60}FORMAT_V2\u{60} = 2; \
payloads land on \u{60}PAYLOAD_ALIGN\u{60} = 64 boundaries\n";

    fn snippet_sources() -> (SourceFile, SourceFile) {
        let wire = sf(
            "rust/src/net/wire.rs",
            include_str!("fixtures/docsync_snippet.rs"),
        );
        let binfmt = sf(
            "rust/src/registry/binfmt.rs",
            include_str!("fixtures/docsync_snippet.rs"),
        );
        (wire, binfmt)
    }

    #[test]
    fn doc_sync_snippet_in_sync_is_clean() {
        let (wire, binfmt) = snippet_sources();
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            SNIPPET_WIRE_MD,
            &binfmt,
            "docs/FORMATS.md",
            SNIPPET_FORMATS_MD,
        );
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn doc_sync_flags_tag_value_drift() {
        let (wire, binfmt) = snippet_sources();
        let tampered = SNIPPET_WIRE_MD
            .replace("| 2 | \u{60}DataRow\u{60} |", "| 7 | \u{60}DataRow\u{60} |");
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            &tampered,
            &binfmt,
            "docs/FORMATS.md",
            SNIPPET_FORMATS_MD,
        );
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].message.contains("K_DATA_ROW"));
    }

    #[test]
    fn doc_sync_flags_missing_row_and_flag_drift() {
        let (wire, binfmt) = snippet_sources();
        let no_row = SNIPPET_WIRE_MD
            .replace("| 2 | \u{60}DataRow\u{60} |\n", "");
        let flag_moved = SNIPPET_FORMATS_MD
            .replace("bit 1 (\u{60}FLAG_BETA\u{60})", "bit 5 (\u{60}FLAG_BETA\u{60})");
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            &no_row,
            &binfmt,
            "docs/FORMATS.md",
            &flag_moved,
        );
        assert_eq!(diags.len(), 2, "{diags:?}");
    }

    #[test]
    fn doc_sync_flags_format_const_drift() {
        let (wire, binfmt) = snippet_sources();
        let tampered = SNIPPET_FORMATS_MD.replace(
            "\u{60}FORMAT_V2\u{60} = 2",
            "\u{60}FORMAT_V2\u{60} = 9",
        );
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            SNIPPET_WIRE_MD,
            &binfmt,
            "docs/FORMATS.md",
            &tampered,
        );
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].message.contains("FORMAT_V2"), "{diags:?}");
    }

    #[test]
    fn doc_sync_flags_missing_and_stale_format_annotations() {
        let (wire, binfmt) = snippet_sources();
        // Dropping the alignment annotation while documenting a
        // `FORMAT_V3` the code never declares must fail once in each
        // direction.
        let tampered = SNIPPET_FORMATS_MD.replace(
            "payloads land on \u{60}PAYLOAD_ALIGN\u{60} = 64 boundaries",
            "\u{60}FORMAT_V3\u{60} = 3",
        );
        assert_ne!(tampered, SNIPPET_FORMATS_MD);
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            SNIPPET_WIRE_MD,
            &binfmt,
            "docs/FORMATS.md",
            &tampered,
        );
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(
            diags.iter().any(|d| d.message.contains("PAYLOAD_ALIGN")),
            "{diags:?}"
        );
        assert!(
            diags.iter().any(|d| d.message.contains("FORMAT_V3")),
            "{diags:?}"
        );
    }

    #[test]
    fn doc_sync_format_leg_is_silent_without_the_consts() {
        // A binfmt without `FORMAT_V*`/`PAYLOAD_ALIGN` (the pre-v2
        // shape) is not checked against annotations the docs carry.
        let wire = sf(
            "rust/src/net/wire.rs",
            include_str!("fixtures/docsync_snippet.rs"),
        );
        let binfmt = sf(
            "rust/src/registry/binfmt.rs",
            "const KIND_A: u16 = 1;\nconst KIND_B: u16 = 2;\n\
             pub const FLAG_ALPHA: u64 = 1;\n\
             pub const FLAG_BETA: u64 = 1 << 1;\n",
        );
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            SNIPPET_WIRE_MD,
            &binfmt,
            "docs/FORMATS.md",
            SNIPPET_FORMATS_MD,
        );
        assert!(diags.is_empty(), "{diags:?}");
    }

    /// Acceptance check: desyncing a live kind constant from the live
    /// docs must fail the lint. Loads the real sources and tampers the
    /// in-memory copy of `docs/WIRE.md`.
    #[test]
    fn doc_sync_catches_drift_against_live_docs() {
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
        let read = |p: &str| {
            std::fs::read_to_string(format!("{root}/{p}"))
                .unwrap_or_else(|e| panic!("read {p}: {e}"))
        };
        let wire =
            sf("rust/src/net/wire.rs", &read("rust/src/net/wire.rs"));
        let binfmt = sf(
            "rust/src/registry/binfmt.rs",
            &read("rust/src/registry/binfmt.rs"),
        );
        let wire_md = read("docs/WIRE.md");
        let formats_md = read("docs/FORMATS.md");

        let clean = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            &wire_md,
            &binfmt,
            "docs/FORMATS.md",
            &formats_md,
        );
        assert!(clean.is_empty(), "{clean:?}");

        let tampered = wire_md.replace(
            "| 3 | \u{60}Request\u{60} |",
            "| 12 | \u{60}Request\u{60} |",
        );
        assert_ne!(tampered, wire_md, "tamper pattern went stale");
        let diags = check_doc_sync(
            &wire,
            "docs/WIRE.md",
            &tampered,
            &binfmt,
            "docs/FORMATS.md",
            &formats_md,
        );
        assert!(
            diags.iter().any(|d| d.message.contains("K_REQUEST")),
            "{diags:?}"
        );
    }

    // ---- rule: allow-grammar -----------------------------------------

    #[test]
    fn allow_grammar_flags_unknown_key_and_missing_reason() {
        let f = sf(
            "rust/src/net/fixture.rs",
            include_str!("fixtures/allow_grammar_violation.rs"),
        );
        let diags = check_allow_grammar(&f);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.rule == "allow-grammar"));
    }

    #[test]
    fn allow_grammar_accepts_known_keys() {
        let f = sf(
            "rust/src/net/fixture.rs",
            include_str!("fixtures/panic_ok.rs"),
        );
        let diags = check_allow_grammar(&f);
        assert!(diags.is_empty(), "{diags:?}");
    }
}
