//! approxrbf CLI — the L3 leader entrypoint.
//!
//! Subcommands (svm-train/svm-predict-style workflow plus the serving
//! and benchmark drivers):
//!
//! ```text
//! approxrbf gen-data    --profile adult-like --out data.txt [--test out2]
//! approxrbf train       --data data.txt --gamma 0.05 [--cost 1] --out m.model
//! approxrbf approximate --model m.model --out m.approx [--backend blocked]
//! approxrbf predict     --model m.model|--approx m.approx --data t.txt
//! approxrbf bound-check --data data.txt [--gamma 0.05]
//! approxrbf serve       --profile control-like [--policy hybrid]
//!                       [--shards N] [--xla]
//! approxrbf registry    publish|list|serve|rollback|migrate --store dir
//!                       [--id name] [--model m.model] [--approx m.approx]
//!                       [--warm] [--quantize f16|int8] [--format v1|v2]
//!                       [--substrate maclaurin|rff] [--rff-features D]
//!                       [--route hybrid] [--tenant-max-batch N]
//!                       [--tenant-max-wait-us N] [--resident-hint N]
//!                       [--drift-tol T] [--shards N] [--to v1|v2]
//! approxrbf serve-shard --listen ADDR --store dir [--shards N]
//! approxrbf serve-plane --shards N --store dir [--lanes N]
//! approxrbf route       --shards ADDR,ADDR... [--store dir]
//! approxrbf bench       table1|table2|table3|fig1|ablations|ann|all
//!                       [--scale full|quick] [--artifacts artifacts]
//! approxrbf inspect     --model m.model|--approx m.approx|--arbf m.arbf
//! ```

#![forbid(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::ApproxModel;
use approxrbf::benchsuite::{self, BenchContext, Scale};
use approxrbf::coordinator::{
    Coordinator, ExecSpec, RoutePolicy, TenantPolicy,
};
use approxrbf::data::{libsvm_format, SynthProfile};
use approxrbf::linalg::MathBackend;
use approxrbf::net::{
    Router, RouterConfig, ShardServer, ShardServerConfig, Supervisor,
    SupervisorConfig,
};
use approxrbf::registry::{
    binfmt, FormatVersion, ModelStore, PayloadKind, PublishOptions,
    Substrate,
};
use approxrbf::svm::predict::{labels_from_decisions, ExactPredictor};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::bench::markdown_table;
use approxrbf::util::stats::accuracy;
use approxrbf::util::{Args, Rng};
use approxrbf::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand.is_none() {
        print!("{}", usage());
        return;
    }
    let result = match args.subcommand.as_deref().unwrap() {
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&args),
        "approximate" => cmd_approximate(&args),
        "predict" => cmd_predict(&args),
        "bound-check" => cmd_bound_check(&args),
        "serve" => cmd_serve(&args),
        "serve-shard" => cmd_serve_shard(&args),
        "serve-plane" => cmd_serve_plane(&args),
        "route" => cmd_route(&args),
        "registry" => cmd_registry(&args),
        "bench" => cmd_bench(&args),
        "inspect" => cmd_inspect(&args),
        other => Err(Error::InvalidArg(format!(
            "unknown subcommand '{other}'\n{}",
            usage()
        ))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    let doc = "approxrbf — fast prediction for RBF-kernel SVMs \
               (Claesen et al., 2014)\n\n\
               subcommands:\n  \
               gen-data    generate a synthetic dataset profile\n  \
               train       train a C-SVC with SMO (LIBSVM role)\n  \
               approximate build the O(d²) approximated model (Eq. 3.8)\n  \
               predict     predict with an exact or approximated model\n  \
               bound-check report γ_MAX for a dataset (Eq. 3.11)\n  \
               serve       run the bound-aware serving coordinator\n              \
               (--shards N spreads tenants over N executor lanes)\n  \
               registry    publish/list/serve/rollback/migrate .arbf bundles\n              \
               (publish --store dir --id name --model m.model\n               \
               [--warm] [--quantize f16|int8] [--format v1|v2]\n               \
               [--substrate maclaurin|rff] [--rff-features D]\n               \
               [--route hybrid]\n               \
               [--tenant-max-batch N] [--tenant-max-wait-us N]\n               \
               [--resident-hint N] [--drift-tol T];\n              \
               rollback --store dir --id name;\n              \
               migrate --store dir --id name [--to v1|v2])\n  \
               serve-shard expose a registry coordinator over TCP\n              \
               (--listen 127.0.0.1:7070 --store dir [--shards N]\n               \
               [--shard-id I] [--drift-tol T])\n  \
               serve-plane supervise N serve-shard processes\n              \
               (--shards N --store dir [--lanes N] [--policy P]\n               \
               [--drift-tol T]; health-checks over the wire,\n               \
               restarts crashed shards with capped backoff)\n  \
               route       rendezvous-route tenants over shard servers\n              \
               (--shards HOST:PORT,HOST:PORT… [--requests N])\n  \
               bench       regenerate the paper's tables/figures\n  \
               inspect     describe a model file (text or .arbf)\n";
    doc.to_string()
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let profile = SynthProfile::parse(args.get_or("profile", "control-like"))?;
    let seed = args.get_u64("seed", 42)?;
    let (dtr, dte) = profile.default_sizes();
    let n_train = args.get_usize("train", dtr)?;
    let n_test = args.get_usize("test", dte)?;
    let out = args.require("out")?;
    let (train, test) = profile.generate(seed, n_train, n_test);
    libsvm_format::save(&train, Path::new(out))?;
    println!(
        "wrote {} train instances (d={}) to {out}",
        train.len(),
        train.dim()
    );
    if let Some(test_out) = args.get("test-out") {
        libsvm_format::save(&test, Path::new(test_out))?;
        println!("wrote {} test instances to {test_out}", test.len());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let data = libsvm_format::load(Path::new(args.require("data")?), None)?;
    let gamma = args.get_f64("gamma", f64::from(gamma_max_for_data(&data)))? as f32;
    let cost = args.get_f64("cost", 1.0)? as f32;
    let out = args.require("out")?;
    let t0 = std::time::Instant::now();
    let (model, stats) = train_csvc(
        &data,
        Kernel::Rbf { gamma },
        SmoParams { c: cost, ..Default::default() },
    )?;
    model.save(Path::new(out))?;
    println!(
        "trained on {} instances (d={}): n_sv={} iters={} converged={} \
         in {:.1}s -> {out}",
        data.len(),
        data.dim(),
        stats.n_sv,
        stats.iterations,
        stats.converged,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_approximate(args: &Args) -> Result<()> {
    let model = SvmModel::load(Path::new(args.require("model")?))?;
    let backend: MathBackend = args.get_or("backend", "blocked").parse()?;
    let out = args.require("out")?;
    let t0 = std::time::Instant::now();
    let am = if backend == MathBackend::Xla {
        build_approx_via_engine(&model, args.get_or("artifacts", "artifacts"))?
    } else {
        build_approx_model(&model, backend)?
    };
    am.save(Path::new(out))?;
    println!(
        "approximated {} SVs (d={}) in {:.3}s; sizes: exact {} B, \
         approx {} B (ratio {:.1}) -> {out}",
        model.n_sv(),
        model.dim(),
        t0.elapsed().as_secs_f64(),
        model.text_size_bytes(),
        am.text_size_bytes(),
        model.text_size_bytes() as f64 / am.text_size_bytes() as f64
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let data = libsvm_format::load(Path::new(args.require("data")?), None)?;
    let t0 = std::time::Instant::now();
    let (dec, what) = if let Some(mp) = args.get("model") {
        let model = SvmModel::load(Path::new(mp))?;
        let backend: MathBackend = args.get_or("backend", "blocked").parse()?;
        let pred = ExactPredictor::new(&model, backend)?;
        (pred.decision_batch(&data.x)?, "exact")
    } else if let Some(ap) = args.get("approx") {
        let am = ApproxModel::load(Path::new(ap))?;
        let backend: MathBackend = args.get_or("backend", "blocked").parse()?;
        let (dec, norms) = am.decision_batch(&data.x, backend)?;
        let budget = am.znorm_sq_budget();
        let oob = norms.iter().filter(|&&n| n >= budget).count();
        if oob > 0 {
            eprintln!(
                "warning: {oob}/{} instances violate the validity bound \
                 (Eq. 3.11); their approximation error is unbounded",
                norms.len()
            );
        }
        (dec, "approx")
    } else {
        return Err(Error::InvalidArg("need --model or --approx".into()));
    };
    let dt = t0.elapsed().as_secs_f64();
    let labels = labels_from_decisions(&dec);
    let acc = accuracy(&labels, &data.y);
    println!(
        "{what} prediction: {} instances in {dt:.3}s ({:.0}/s), acc {:.2}%",
        data.len(),
        data.len() as f64 / dt,
        acc * 100.0
    );
    if let Some(out) = args.get("out") {
        let text: String = dec
            .iter()
            .map(|d| format!("{d}\n"))
            .collect();
        std::fs::write(out, text)?;
        println!("decision values -> {out}");
    }
    Ok(())
}

fn cmd_bound_check(args: &Args) -> Result<()> {
    let data = libsvm_format::load(Path::new(args.require("data")?), None)?;
    let gmax = gamma_max_for_data(&data);
    println!(
        "dataset: {} instances, d={}, max ‖x‖² = {:.4}",
        data.len(),
        data.dim(),
        data.max_norm_sq()
    );
    println!("γ_MAX = {gmax:.6}  (Eq. 3.11; approximation guaranteed \
              term-wise <3.05% error for γ below this)");
    if let Some(g) = args.get("gamma") {
        let g: f32 = g
            .parse()
            .map_err(|_| Error::InvalidArg("bad --gamma".into()))?;
        let rep = approxrbf::approx::BoundReport::evaluate(
            &data,
            g,
            data.max_norm_sq(),
        );
        println!(
            "at γ = {g}: γ/γ_MAX = {:.2}; {:.1}% of instances in bound",
            rep.gamma_ratio,
            rep.fraction_in_bound() * 100.0
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let profile = SynthProfile::parse(args.get_or("profile", "control-like"))?;
    let policy: RoutePolicy = args.get_or("policy", "hybrid").parse()?;
    let seed = args.get_u64("seed", 42)?;
    let requests = args.get_usize("requests", 20_000)?;
    let scale = Scale::parse(args.get_or("scale", "quick"))?;
    let ctx = BenchContext::new(scale, seed);
    let mult = benchsuite::context::gamma_multipliers(profile)[0];
    println!("training {} model (scale={scale:?})…", profile.name());
    let case = ctx.trained(profile, mult)?;
    let am = build_approx_model(&case.model, MathBackend::Blocked)?;
    let exec = if args.has_flag("xla") {
        xla_exec_spec(args.get_or("artifacts", "artifacts"))?
    } else {
        ExecSpec::Native(MathBackend::Blocked)
    };
    let shards = args.get_usize("shards", 1)?;
    let coord = Coordinator::builder()
        .policy(policy)
        .exec(exec)
        .shards(shards)
        .start(case.model.clone(), am)?;
    let client = coord.client();
    println!(
        "serving {requests} requests through policy={policy} on {} \
         shard(s)…",
        coord.shard_count()
    );
    let mut served = 0usize;
    let t0 = std::time::Instant::now();
    let mut row = 0usize;
    while served < requests {
        client
            .submit(case.test.x.row(row % case.test.len()).to_vec())
            .map_err(Error::from)?;
        row += 1;
        // Drain opportunistically to keep the pipeline flowing;
        // completions are typed, so a failure aborts with its cause
        // instead of timing out.
        while let Some(c) = client.recv(Duration::from_micros(0)) {
            c.map_err(Error::from)?;
            served += 1;
        }
        if row >= requests {
            while served < requests {
                match client.recv(Duration::from_millis(100)) {
                    None => {
                        return Err(Error::Other("lost responses".into()))
                    }
                    Some(c) => {
                        c.map_err(Error::from)?;
                        served += 1;
                    }
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "done in {wall:.2}s: {:.0} req/s, approx/exact = {}/{}, \
         mean batch {:.1}, out-of-bound {}",
        requests as f64 / wall,
        m.served_approx,
        m.served_exact,
        m.mean_batch_size,
        m.out_of_bound
    );
    println!("{}", m.to_json().to_string_pretty());
    coord.shutdown()
}

/// `serve-shard`: expose one registry-backed coordinator process over
/// the `ARBW` wire protocol. Runs until killed.
fn cmd_serve_shard(args: &Args) -> Result<()> {
    let listen = args.require("listen")?;
    let store = Arc::new(ModelStore::open(args.get_or("store", "registry"))?);
    let policy: RoutePolicy = args.get_or("policy", "hybrid").parse()?;
    let shards = args.get_usize("shards", 1)?;
    let shard_id = args.get_usize("shard-id", 0)? as u32;
    let mut builder = Coordinator::builder()
        .policy(policy)
        .shards(shards)
        .warm_start(true);
    if let Some(s) = args.get("drift-tol") {
        let tol = s.parse::<f32>().map_err(|_| {
            Error::InvalidArg(format!("bad --drift-tol '{s}'"))
        })?;
        builder = builder.quant_drift_tol(tol);
    }
    let coord = builder.start_registry(store.clone())?;
    let config = ShardServerConfig {
        shard_id,
        max_in_flight: args.get_usize("max-in-flight", 1024)?,
        read_timeout: Duration::from_secs(
            args.get_u64("read-timeout-s", 30)?,
        ),
    };
    let server = ShardServer::bind(listen, coord, store, config)?;
    // The supervising process (e2e tests, orchestrators) scrapes this
    // line for the resolved port, so flush it out immediately.
    println!(
        "shard {shard_id} serving on {} ({shards} lane(s))",
        server.local_addr()
    );
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// `serve-plane`: supervise N `serve-shard` processes — spawn them on
/// ephemeral loopback ports, health-check over the wire, restart
/// crashes with capped backoff on pinned addresses. Runs until
/// killed; prints the address list routers should connect to.
fn cmd_serve_plane(args: &Args) -> Result<()> {
    let shards = args.get_usize("shards", 2)?;
    let store = args.get_or("store", "registry").to_string();
    let lanes = args.get_usize("lanes", 1)?;
    let binary = std::env::current_exe().map_err(Error::Io)?;
    let mut config = SupervisorConfig {
        shards,
        store: store.clone().into(),
        binary,
        lanes,
        ..SupervisorConfig::default()
    };
    if let Some(p) = args.get("policy") {
        config.policy = Some(p.to_string());
    }
    if let Some(s) = args.get("drift-tol") {
        let tol = s.parse::<f32>().map_err(|_| {
            Error::InvalidArg(format!("bad --drift-tol '{s}'"))
        })?;
        config.drift_tol = Some(tol);
    }
    let supervisor = Supervisor::start(config)?;
    let addrs = supervisor.addrs();
    // Orchestrators scrape this line, mirroring the serve-shard
    // banner contract.
    println!(
        "plane: {shards} shard(s) over {store} serving on {}",
        addrs.join(",")
    );
    println!("route with: approxrbf route --shards {}", addrs.join(","));
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    let mut last_restarts = vec![0u64; shards];
    loop {
        std::thread::sleep(Duration::from_secs(5));
        let restarts = supervisor.restarts();
        for (shard, (&now, last)) in restarts
            .iter()
            .zip(last_restarts.iter_mut())
            .enumerate()
        {
            if now > *last {
                println!(
                    "plane: shard {shard} restarted ({now} total)"
                );
                let _ = std::io::stdout().flush();
                *last = now;
            }
        }
    }
}

/// `route`: stand up a router over shard-server processes and drive
/// synthetic traffic at the models they advertise — the remote
/// counterpart of `registry serve`.
fn cmd_route(args: &Args) -> Result<()> {
    let addrs: Vec<String> = args
        .require("shards")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let requests = args.get_usize("requests", 10_000)?;
    let seed = args.get_u64("seed", 42)?;
    let router = Router::connect(&addrs, RouterConfig::default())?;
    let mut models: Vec<(String, u32)> =
        router.model_dims().into_iter().collect();
    models.sort();
    if models.is_empty() {
        router.shutdown();
        return Err(Error::InvalidArg(
            "shard servers advertise no models: publish to their \
             registries first"
                .into(),
        ));
    }
    println!(
        "routing {requests} requests over {} shard(s), {} model(s)…",
        router.shard_count(),
        models.len()
    );
    let client = router.client();
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    let mut served = 0usize;
    while served < requests {
        if submitted < requests {
            let (id, dim) = &models[submitted % models.len()];
            let scale = 1.0 / (*dim as f64).sqrt();
            let z: Vec<f32> = (0..*dim)
                .map(|_| (rng.normal() * scale) as f32)
                .collect();
            client.submit_to(id, z).map_err(Error::from)?;
            submitted += 1;
        }
        while let Some(c) = client.recv(Duration::from_micros(0)) {
            c.map_err(Error::from)?;
            served += 1;
        }
        if submitted >= requests {
            while served < requests {
                match client.recv(Duration::from_millis(100)) {
                    None => {
                        return Err(Error::Other("lost responses".into()))
                    }
                    Some(c) => {
                        c.map_err(Error::from)?;
                        served += 1;
                    }
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = router.metrics();
    println!(
        "done in {wall:.2}s: {:.0} req/s, mean batch {:.1}\n",
        requests as f64 / wall,
        m.mean_batch_size
    );
    print!("{}", m.per_model_table());
    router.shutdown();
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = Scale::parse(args.get_or("scale", "full"))?;
    let seed = args.get_u64("seed", 42)?;
    let artifacts = Path::new(args.get_or("artifacts", "artifacts"));
    let ctx = BenchContext::new(scale, seed);
    let mut outputs = Vec::new();
    match which {
        "table1" => outputs.push(benchsuite::table1::run(&ctx)?),
        "table2" => {
            outputs.push(benchsuite::table2::run(&ctx, Some(artifacts))?)
        }
        "table3" => outputs.push(benchsuite::table3::run(&ctx)?),
        "fig1" => outputs.push(benchsuite::fig1::run()?),
        "ablations" => outputs.push(benchsuite::ablations::run(&ctx)?),
        "ann" => outputs.push(benchsuite::ann::run(&ctx)?),
        "all" => {
            outputs.push(benchsuite::fig1::run()?);
            outputs.push(benchsuite::table1::run(&ctx)?);
            outputs.push(benchsuite::table2::run(&ctx, Some(artifacts))?);
            outputs.push(benchsuite::table3::run(&ctx)?);
            outputs.push(benchsuite::ablations::run(&ctx)?);
            outputs.push(benchsuite::ann::run(&ctx)?);
        }
        other => {
            return Err(Error::InvalidArg(format!(
                "unknown bench '{other}' \
                 (table1|table2|table3|fig1|ablations|ann|all)"
            )))
        }
    }
    for o in outputs {
        println!("{o}");
    }
    Ok(())
}

/// Render a decision-drift bound for CLI output: finite bounds in
/// scientific notation, unavailable bounds (∞ — non-RBF kernels, see
/// `ExactQuantErr::decision_error`) as `n/a` so the output never
/// prints `inf` and stays machine-parseable.
fn fmt_bound(bound: f32) -> String {
    if bound.is_finite() {
        format!("{bound:.2e}")
    } else {
        "n/a".to_string()
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if let Some(mp) = args.get("model") {
        let m = SvmModel::load(Path::new(mp))?;
        println!(
            "exact SVM model: kernel={} d={} n_sv={} b={:.4} \
             max‖x‖²={:.4} text={} B",
            m.kernel.name(),
            m.dim(),
            m.n_sv(),
            m.b,
            m.max_sv_norm_sq(),
            m.text_size_bytes()
        );
    } else if let Some(ap) = args.get("approx") {
        let a = ApproxModel::load(Path::new(ap))?;
        println!(
            "approx model: d={} γ={:.4} b={:.4} c={:.4} ‖x_M‖²={:.4} \
             ‖z‖² budget={:.4} text={} B",
            a.dim(),
            a.gamma,
            a.b,
            a.c,
            a.max_sv_norm_sq,
            a.znorm_sq_budget(),
            a.text_size_bytes()
        );
    } else if let Some(bp) = args.get("arbf") {
        let bytes = std::fs::read(Path::new(bp))?;
        let hdr = binfmt::peek_header(&bytes)?;
        println!(
            "arbf v{} bundle: {} record(s), generation {}, d={}, n_sv={}, \
             substrate={}, payload={}, {} B",
            hdr.version,
            hdr.n_records,
            hdr.generation,
            hdr.dim,
            hdr.n_sv,
            if hdr.has_rff() { "rff" } else { "maclaurin" },
            hdr.payload(),
            bytes.len()
        );
        let frames = binfmt::record_frames(&bytes)?;
        let records = binfmt::decode(&bytes)?.1;
        for (frame, rec) in frames.iter().zip(records) {
            let footprint = format!(
                "kind={} payload={} B",
                frame.kind, frame.payload_len
            );
            match rec {
                binfmt::ModelRecord::Svm(m) => println!(
                    "  exact : kernel={} n_sv={} b={:.4} [{footprint}]",
                    m.kernel.name(),
                    m.n_sv(),
                    m.b
                ),
                binfmt::ModelRecord::Approx(a) => println!(
                    "  approx: γ={:.4} ‖z‖² budget={:.4} [{footprint}]",
                    a.gamma,
                    a.znorm_sq_budget()
                ),
                binfmt::ModelRecord::QuantSvm(m) => println!(
                    "  exact : kernel={} n_sv={} b={:.4} quant={} \
                     resident={} B drift≤{} [{footprint}]",
                    m.kernel.name(),
                    m.n_sv(),
                    m.b,
                    m.payload(),
                    m.resident_bytes(),
                    fmt_bound(m.quant_err().decision_error())
                ),
                binfmt::ModelRecord::QuantApprox(a) => {
                    let err = a.quant_err();
                    println!(
                        "  approx: γ={:.4} ‖z‖² budget={:.4} quant={} \
                         resident={} B eps_v={:.2e} eps_m={:.2e} \
                         [{footprint}]",
                        a.gamma,
                        a.znorm_sq_budget(),
                        a.payload(),
                        a.resident_bytes(),
                        err.eps_v,
                        err.eps_m
                    )
                }
                binfmt::ModelRecord::Rff(r) => println!(
                    "  rff   : D={} seed={:#018x} γ={:.4} err≈{} \
                     resident={} B [{footprint}]",
                    r.n_features(),
                    r.seed,
                    r.gamma,
                    fmt_bound(r.err_est),
                    r.resident_bytes()
                ),
                binfmt::ModelRecord::Policy(p) => println!(
                    "  policy: route={} max_batch={} max_wait={} \
                     resident_hint={} drift_tol={} [{footprint}]",
                    p.route.map(|r| r.name()).unwrap_or("(default)"),
                    p.max_batch
                        .map(|n| n.to_string())
                        .unwrap_or_else(|| "(default)".into()),
                    p.max_wait
                        .map(|w| format!("{}µs", w.as_micros()))
                        .unwrap_or_else(|| "(default)".into()),
                    p.max_resident_hint,
                    p.quant_drift_tol
                        .map(|t| t.to_string())
                        .unwrap_or_else(|| "(default)".into())
                ),
            }
        }
    } else {
        return Err(Error::InvalidArg(
            "need --model, --approx or --arbf".into(),
        ));
    }
    Ok(())
}

/// Assemble a [`TenantPolicy`] from `registry publish` flags; `None`
/// when no policy flag was given (the bundle then carries no kind-3
/// record).
fn tenant_policy_from_args(args: &Args) -> Result<Option<TenantPolicy>> {
    let route = match args.get("route") {
        Some(s) => Some(s.parse::<RoutePolicy>()?),
        None => None,
    };
    let max_batch = match args.get_usize("tenant-max-batch", 0)? {
        0 => None,
        n => Some(n),
    };
    let max_wait = match args.get_u64("tenant-max-wait-us", 0)? {
        0 => None,
        us => Some(Duration::from_micros(us)),
    };
    let max_resident_hint = args.get_u64("resident-hint", 0)? as u32;
    let quant_drift_tol = match args.get("drift-tol") {
        Some(s) => Some(s.parse::<f32>().map_err(|_| {
            Error::InvalidArg(format!("bad --drift-tol '{s}'"))
        })?),
        None => None,
    };
    let policy = TenantPolicy {
        route,
        max_batch,
        max_wait,
        max_resident_hint,
        quant_drift_tol,
    };
    Ok(if policy.is_default() { None } else { Some(policy) })
}

/// `registry publish|list|serve|rollback|migrate` — manage and serve
/// `.arbf` bundles.
fn cmd_registry(args: &Args) -> Result<()> {
    let action = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("list");
    let store = Arc::new(ModelStore::open(args.get_or("store", "registry"))?);
    match action {
        "publish" => {
            let id = args.require("id")?;
            let model = SvmModel::load(Path::new(args.require("model")?))?;
            let am = match args.get("approx") {
                Some(p) => ApproxModel::load(Path::new(p))?,
                None => {
                    println!("(no --approx given: building Eq. 3.8 model)");
                    build_approx_model(&model, MathBackend::Blocked)?
                }
            };
            let quantize = match args.get("quantize") {
                Some(s) => Some(s.parse::<PayloadKind>()?),
                None => None,
            };
            let substrate = match args.get("substrate") {
                Some(s) => Some(s.parse::<Substrate>()?),
                None => None,
            };
            let rff_features = match args.get_usize("rff-features", 0)? {
                0 => None,
                n => Some(n),
            };
            let format = match args.get("format") {
                Some(s) => Some(s.parse::<FormatVersion>()?),
                None => None,
            };
            let opts = PublishOptions {
                policy: tenant_policy_from_args(args)?,
                warm: args.has_flag("warm"),
                quantize,
                substrate,
                rff_features,
                format,
            };
            let described = match &opts.policy {
                Some(p) => format!(" policy={p:?}"),
                None => String::new(),
            };
            let generation = store.publish_with(id, &model, &am, opts)?;
            let info = store.peek(id)?;
            println!(
                "published '{id}' generation {generation}: d={} n_sv={} \
                 substrate={} payload={} format={} {} B{described} -> {}",
                info.dim,
                info.n_sv,
                if info.has_rff { "rff" } else { "maclaurin" },
                info.payload,
                info.format,
                info.size_bytes,
                store.root().join(format!("{id}.arbf")).display()
            );
        }
        "list" => {
            let infos = store.list()?;
            if infos.is_empty() {
                println!("(registry at {} is empty)", store.root().display());
                return Ok(());
            }
            let mut rows = vec![vec![
                "id".to_string(),
                "generation".to_string(),
                "d".to_string(),
                "n_sv".to_string(),
                "substrate".to_string(),
                "payload".to_string(),
                "format".to_string(),
                "drift".to_string(),
                "bytes".to_string(),
                "policy".to_string(),
                "archived".to_string(),
            ]];
            let archived_counts =
                store.archived_counts().unwrap_or_default();
            for i in &infos {
                let archived =
                    archived_counts.get(&i.id).copied().unwrap_or(0);
                // Drift column: for quantized entries the exact-side
                // decision-drift bound, for rff entries the stored
                // Monte-Carlo error estimate (both decode the bundle;
                // `-` for f32 Maclaurin, `n/a` when no finite bound
                // exists, `?` when the bundle fails to decode).
                let drift = if i.has_rff {
                    match store.load(&i.id) {
                        Ok(entry) => entry
                            .models
                            .rff()
                            .map(|r| fmt_bound(r.err_est))
                            .unwrap_or_else(|| "-".to_string()),
                        Err(_) => "?".to_string(),
                    }
                } else if i.payload == PayloadKind::F32 {
                    "-".to_string()
                } else {
                    match store.load(&i.id) {
                        Ok(entry) => entry
                            .quant_info()
                            .map(|q| fmt_bound(q.exact_err.decision_error()))
                            .unwrap_or_else(|| "-".to_string()),
                        Err(_) => "?".to_string(),
                    }
                };
                rows.push(vec![
                    i.id.clone(),
                    i.generation.to_string(),
                    i.dim.to_string(),
                    i.n_sv.to_string(),
                    if i.has_rff { "rff" } else { "maclaurin" }.to_string(),
                    i.payload.to_string(),
                    i.format.to_string(),
                    drift,
                    i.size_bytes.to_string(),
                    if i.has_policy { "yes" } else { "-" }.to_string(),
                    archived.to_string(),
                ]);
            }
            print!("{}", markdown_table(&rows));
        }
        "rollback" => {
            let id = args
                .get("id")
                .or_else(|| args.positionals.get(1).map(|s| s.as_str()))
                .ok_or_else(|| {
                    Error::InvalidArg(
                        "registry rollback needs --id (or a positional id)"
                            .into(),
                    )
                })?;
            let before = store.peek(id)?.generation;
            let generation = store.rollback(id)?;
            println!(
                "rolled '{id}' back: generation {before} -> {generation} \
                 (payload of the newest archive; serving nodes pick it up \
                 as an ordinary hot swap)"
            );
        }
        "serve" => {
            let policy: RoutePolicy =
                args.get_or("policy", "hybrid").parse()?;
            let requests = args.get_usize("requests", 10_000)?;
            let seed = args.get_u64("seed", 42)?;
            let shards = args.get_usize("shards", 1)?;
            let infos = store.list()?;
            if infos.is_empty() {
                return Err(Error::InvalidArg(
                    "registry is empty: publish models first".into(),
                ));
            }
            println!(
                "serving {requests} synthetic requests across {} model(s), \
                 policy={policy}, shards={shards}…",
                infos.len()
            );
            let coord = Coordinator::builder()
                .policy(policy)
                .shards(shards)
                .warm_start(true)
                .start_registry(store.clone())?;
            let client = coord.client();
            let mut rng = Rng::new(seed);
            let t0 = std::time::Instant::now();
            let mut submitted = 0usize;
            let mut served = 0usize;
            while served < requests {
                if submitted < requests {
                    let info = &infos[submitted % infos.len()];
                    let scale = 1.0 / (info.dim as f64).sqrt();
                    let z: Vec<f32> = (0..info.dim)
                        .map(|_| (rng.normal() * scale) as f32)
                        .collect();
                    client.submit_to(&info.id, z).map_err(Error::from)?;
                    submitted += 1;
                }
                while let Some(c) = client.recv(Duration::from_micros(0)) {
                    c.map_err(Error::from)?;
                    served += 1;
                }
                if submitted >= requests {
                    while served < requests {
                        match client.recv(Duration::from_millis(100)) {
                            None => {
                                return Err(Error::Other(
                                    "lost responses".into(),
                                ))
                            }
                            Some(c) => {
                                c.map_err(Error::from)?;
                                served += 1;
                            }
                        }
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let m = coord.metrics();
            println!(
                "done in {wall:.2}s: {:.0} req/s, mean batch {:.1}\n",
                requests as f64 / wall,
                m.mean_batch_size
            );
            print!("{}", m.per_model_table());
            coord.shutdown()?;
        }
        "migrate" => {
            let id = args
                .get("id")
                .or_else(|| args.positionals.get(1).map(|s| s.as_str()))
                .ok_or_else(|| {
                    Error::InvalidArg(
                        "registry migrate needs --id (or a positional id)"
                            .into(),
                    )
                })?;
            let to: FormatVersion = args.get_or("to", "v2").parse()?;
            let before = store.peek(id)?;
            let generation = store.migrate(id, to)?;
            if generation == before.generation {
                println!(
                    "'{id}' already stores format {to}; nothing to migrate"
                );
            } else {
                println!(
                    "migrated '{id}' from {} to {to}: generation {} -> \
                     {generation} (same stored values, decisions \
                     bit-identical; serving nodes pick it up as an \
                     ordinary hot swap)",
                    before.format, before.generation
                );
            }
        }
        other => {
            return Err(Error::InvalidArg(format!(
                "unknown registry action '{other}' \
                 (publish|list|serve|rollback|migrate)"
            )))
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn build_approx_via_engine(
    model: &SvmModel,
    artifacts: &str,
) -> Result<ApproxModel> {
    let engine = approxrbf::runtime::Engine::load(Path::new(artifacts))?;
    engine.build_approx(model)
}

#[cfg(not(feature = "pjrt"))]
fn build_approx_via_engine(
    _model: &SvmModel,
    _artifacts: &str,
) -> Result<ApproxModel> {
    Err(Error::InvalidArg(
        "the xla backend requires a build with `--features pjrt`".into(),
    ))
}

#[cfg(feature = "pjrt")]
fn xla_exec_spec(artifacts: &str) -> Result<ExecSpec> {
    Ok(ExecSpec::Xla {
        artifacts_dir: Path::new(artifacts).to_path_buf(),
    })
}

#[cfg(not(feature = "pjrt"))]
fn xla_exec_spec(_artifacts: &str) -> Result<ExecSpec> {
    Err(Error::InvalidArg(
        "--xla requires a build with `--features pjrt`".into(),
    ))
}
