//! Quantized payload codecs and storage for `.arbf` model records.
//!
//! Two precisions, both with *advertised per-element error bounds* so
//! the serving layer can fold dequantization error into the paper's
//! Eq. 3.11 routing budget (see [`crate::approx::bounds`]):
//!
//! * **f16** (IEEE 754 binary16, round-to-nearest-even): relative error
//!   ≤ 2⁻¹¹ per element in the normal range plus a 2⁻²⁵ subnormal
//!   floor; values beyond ±65504 are rejected at quantize time.
//! * **int8** (symmetric per-row, stored f32 scales): each row is
//!   quantized as `q = round(x / scale)` with `scale = max|row| / 127`,
//!   so the per-element error is bounded by [`int8_eps`]` = 0.5001 ×
//!   scale` (half a quantization step plus float dequant rounding).
//!   All-zero rows encode `scale = 0` and dequantize to exact zeros.
//!
//! Quantized tensors stay in **native storage** at serving time
//! ([`QuantSvmModel`] / [`QuantApproxModel`] inside
//! [`TenantModels::Quantized`]) and are evaluated by the blocked/SIMD
//! kernels in [`crate::linalg::quantblas`] (arm dispatch via
//! `APPROXRBF_QUANT_KERNEL`): f16 rows block-dequantize into FMA
//! loops; int8 rows are dotted against an i16-quantized query in exact
//! integer arithmetic, which makes int8 decisions bit-identical across
//! dispatch arms. This native evaluation is what delivers the
//! resident-memory reduction (int8 ≈ ¼ of f32 for SV payloads, ≈ ⅛
//! for the packed `M` upper triangle vs the mirrored f32 matrix)
//! measured by `serving_bench`'s `BENCH_quant.json` leg — and, since
//! PR 5, without the scalar-loop throughput penalty it used to cost.
//! Scalars (`γ`, `b`, `c`, `‖x_M‖²`, per-row scales) always stay f32:
//! they are O(1)/O(d) bytes and quantizing them would perturb the
//! bound arithmetic itself.
//!
//! The byte-level record layouts (kind 4 = f16, kind 5 = int8) live in
//! [`super::binfmt`]; this module owns the value-level transforms and
//! the in-memory quantized model types.
//!
//! Since format v2, every quantized tensor holds its elements behind
//! [`TensorData`]: decoded onto the heap (v1 bundles) or borrowed as a
//! view over a memory-mapped bundle file (v2) — the arithmetic above
//! is storage-agnostic, and [`TensorData`]'s heap/mapped accounting is
//! what `registry list` and the serving metrics report.

#![forbid(unsafe_code)]

use super::mapfile::TensorData;
use crate::approx::bounds::{ExactQuantErr, QuantErrorBound};
use crate::approx::{ApproxModel, RffModel};
use crate::linalg::quantblas::{self, KernelArm, QuantZ};
use crate::linalg::{vecops, Mat};
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

// The scalar f16 codec moved to `linalg::quantblas` (the kernels
// convert inline); re-exported here so codec users keep one import
// path next to the storage types.
pub use crate::linalg::quantblas::{
    f16_bits_to_f32, f16_eps, f32_to_f16_bits, F16_MAX, F16_REL_EPS,
    F16_SUBNORMAL_EPS,
};

// ---------------------------------------------------------------------
// payload kinds
// ---------------------------------------------------------------------

/// Precision of a bundle's model payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// Full-precision records (kinds 1–2).
    F32,
    /// IEEE 754 binary16 records (kind 4).
    F16,
    /// Symmetric per-row int8 records with f32 scales (kind 5).
    Int8,
}

impl PayloadKind {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            PayloadKind::F32 => "f32",
            PayloadKind::F16 => "f16",
            PayloadKind::Int8 => "int8",
        }
    }
}

impl std::fmt::Display for PayloadKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PayloadKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<PayloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "none" | "off" => Ok(PayloadKind::F32),
            "f16" | "half" => Ok(PayloadKind::F16),
            "int8" | "i8" => Ok(PayloadKind::Int8),
            other => Err(Error::InvalidArg(format!(
                "unknown payload kind '{other}' (f32|f16|int8)"
            ))),
        }
    }
}

// ---------------------------------------------------------------------
// int8 row codec
// ---------------------------------------------------------------------

/// Per-element error bound of a symmetric int8 row with stored `scale`:
/// half a quantization step, padded 0.02% for the float rounding of
/// `scale × q` on dequantize and the clamp edge.
#[inline]
pub fn int8_eps(scale: f32) -> f32 {
    0.5001 * scale
}

/// Quantize one row symmetrically: `scale = max|row|/127`,
/// `q = round(x/scale)` clamped to ±127. All-zero rows get
/// `scale = 0` (dequantizing to exact zeros); when `max/127` lands in
/// the f32 subnormal range (where division is too imprecise to honor
/// the bound — or underflows to zero outright) the row falls back to
/// `scale = max` (q ∈ {−1, 0, 1}), which keeps the [`int8_eps`] bound
/// intact at the cost of resolution. Non-finite inputs are rejected.
pub fn int8_quantize_row(row: &[f32]) -> Result<(f32, Vec<i8>)> {
    let mut max = 0.0f32;
    for &x in row {
        if !x.is_finite() {
            return Err(Error::InvalidArg(format!(
                "cannot quantize non-finite value {x}"
            )));
        }
        max = max.max(x.abs());
    }
    if max == 0.0 {
        return Ok((0.0, vec![0; row.len()]));
    }
    let mut scale = max / 127.0;
    if scale < f32::MIN_POSITIVE {
        scale = max; // subnormal scale: q collapses to {-1, 0, 1}
    }
    let q = row
        .iter()
        .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    Ok((scale, q))
}

#[inline]
fn int8_dequant(scale: f32, q: i8) -> f32 {
    scale * f32::from(q)
}

// ---------------------------------------------------------------------
// quantized tensor storage
// ---------------------------------------------------------------------

/// A quantized dense vector (one int8 scale for the whole vector).
#[derive(Clone, Debug)]
pub enum QuantVec {
    F16(TensorData<u16>),
    Int8 { scale: f32, q: TensorData<i8> },
}

impl QuantVec {
    pub fn quantize(v: &[f32], kind: PayloadKind) -> Result<QuantVec> {
        match kind {
            PayloadKind::F16 => {
                check_f16_range(v)?;
                Ok(QuantVec::F16(
                    v.iter().map(|&x| f32_to_f16_bits(x)).collect(),
                ))
            }
            PayloadKind::Int8 => {
                let (scale, q) = int8_quantize_row(v)?;
                Ok(QuantVec::Int8 { scale, q: q.into() })
            }
            PayloadKind::F32 => Err(Error::InvalidArg(
                "QuantVec::quantize: f32 is not a quantized kind".into(),
            )),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            QuantVec::F16(h) => h.len(),
            QuantVec::Int8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn payload(&self) -> PayloadKind {
        match self {
            QuantVec::F16(_) => PayloadKind::F16,
            QuantVec::Int8 { .. } => PayloadKind::Int8,
        }
    }

    /// Dequantized element `i`.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self {
            QuantVec::F16(h) => f16_bits_to_f32(h[i]),
            QuantVec::Int8 { scale, q } => int8_dequant(*scale, q[i]),
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Contiguous f16 storage, when this vector is f16.
    pub fn as_f16(&self) -> Option<&[u16]> {
        match self {
            QuantVec::F16(h) => Some(&h[..]),
            QuantVec::Int8 { .. } => None,
        }
    }

    /// `(scale, codes)` of the contiguous int8 storage, when int8.
    pub fn as_i8(&self) -> Option<(f32, &[i8])> {
        match self {
            QuantVec::F16(_) => None,
            QuantVec::Int8 { scale, q } => Some((*scale, &q[..])),
        }
    }

    /// Dequantized dot product with `z` through the process-wide
    /// kernel arm. int8 storage quantizes `z` to i16 per call — batch
    /// evaluators quantize once per query row instead
    /// ([`QuantApproxModel::decision_one_with`] /
    /// [`QuantSvmModel::decision_with_norms`]).
    #[inline]
    pub fn dot(&self, z: &[f32]) -> f32 {
        let arm = quantblas::active_arm();
        match self {
            QuantVec::F16(h) => quantblas::dot_f16(arm, h, z),
            QuantVec::Int8 { scale, q } => {
                quantblas::dot_i8(arm, q, *scale, &QuantZ::from_f32(z))
            }
        }
    }

    /// Max per-element dequantization error bound.
    pub fn eps(&self) -> f32 {
        match self {
            QuantVec::F16(h) => h
                .iter()
                .map(|&hi| f16_eps(f16_bits_to_f32(hi)))
                .fold(0.0, f32::max),
            QuantVec::Int8 { scale, .. } => int8_eps(*scale),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match self {
            QuantVec::F16(h) => 2 * h.len(),
            QuantVec::Int8 { q, .. } => q.len() + 4,
        }
    }

    /// The heap-resident share of [`QuantVec::resident_bytes`] (the
    /// whole thing for owned storage; just the scale scalar when the
    /// codes are served from a mapped file).
    pub fn heap_bytes(&self) -> usize {
        match self {
            QuantVec::F16(h) => h.heap_bytes(),
            QuantVec::Int8 { q, .. } => q.heap_bytes() + 4,
        }
    }

    /// The mapped-file share of [`QuantVec::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        match self {
            QuantVec::F16(h) => h.mapped_bytes(),
            QuantVec::Int8 { q, .. } => q.mapped_bytes(),
        }
    }

    fn check(&self, what: &str) -> std::result::Result<(), String> {
        match self {
            QuantVec::F16(h) => check_f16_finite(h, what),
            QuantVec::Int8 { scale, .. } => check_scale(*scale, what),
        }
    }
}

/// A quantized dense rectangular matrix (SV rows), row-major, with
/// per-row int8 scales.
#[derive(Clone, Debug)]
pub enum QuantMat {
    F16 { rows: usize, cols: usize, h: TensorData<u16> },
    Int8 {
        rows: usize,
        cols: usize,
        scales: TensorData<f32>,
        q: TensorData<i8>,
    },
}

impl QuantMat {
    pub fn quantize(m: &Mat, kind: PayloadKind) -> Result<QuantMat> {
        let (rows, cols) = (m.rows(), m.cols());
        match kind {
            PayloadKind::F16 => {
                check_f16_range(m.as_slice())?;
                Ok(QuantMat::F16 {
                    rows,
                    cols,
                    h: m.as_slice()
                        .iter()
                        .map(|&x| f32_to_f16_bits(x))
                        .collect(),
                })
            }
            PayloadKind::Int8 => {
                let mut scales = Vec::with_capacity(rows);
                let mut q = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    let (s, rq) = int8_quantize_row(m.row(r))?;
                    scales.push(s);
                    q.extend_from_slice(&rq);
                }
                Ok(QuantMat::Int8 {
                    rows,
                    cols,
                    scales: scales.into(),
                    q: q.into(),
                })
            }
            PayloadKind::F32 => Err(Error::InvalidArg(
                "QuantMat::quantize: f32 is not a quantized kind".into(),
            )),
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            QuantMat::F16 { rows, .. } | QuantMat::Int8 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            QuantMat::F16 { cols, .. } | QuantMat::Int8 { cols, .. } => *cols,
        }
    }

    pub fn payload(&self) -> PayloadKind {
        match self {
            QuantMat::F16 { .. } => PayloadKind::F16,
            QuantMat::Int8 { .. } => PayloadKind::Int8,
        }
    }

    /// Dequantized element (r, c).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        match self {
            QuantMat::F16 { cols, h, .. } => {
                f16_bits_to_f32(h[r * cols + c])
            }
            QuantMat::Int8 { cols, scales, q, .. } => {
                int8_dequant(scales[r], q[r * cols + c])
            }
        }
    }

    /// Contiguous row-major f16 storage, when this matrix is f16.
    pub fn as_f16(&self) -> Option<&[u16]> {
        match self {
            QuantMat::F16 { h, .. } => Some(&h[..]),
            QuantMat::Int8 { .. } => None,
        }
    }

    /// `(per-row scales, row-major codes)` when this matrix is int8 —
    /// the contiguous views the blocked/SIMD GEMV kernels stream.
    pub fn as_i8(&self) -> Option<(&[f32], &[i8])> {
        match self {
            QuantMat::F16 { .. } => None,
            QuantMat::Int8 { scales, q, .. } => {
                Some((&scales[..], &q[..]))
            }
        }
    }

    /// Dequantized dot of row `r` with `z` through the process-wide
    /// kernel arm. int8 storage quantizes `z` per call — batch
    /// evaluators quantize once ([`QuantSvmModel::decision_with_norms`]).
    #[inline]
    pub fn row_dot(&self, r: usize, z: &[f32]) -> f32 {
        let arm = quantblas::active_arm();
        match self {
            QuantMat::F16 { cols, h, .. } => {
                quantblas::dot_f16(arm, &h[r * cols..(r + 1) * cols], z)
            }
            QuantMat::Int8 { cols, scales, q, .. } => quantblas::dot_i8(
                arm,
                &q[r * cols..(r + 1) * cols],
                scales[r],
                &QuantZ::from_f32(z),
            ),
        }
    }

    /// Squared L2 norm of dequantized row `r`.
    pub fn row_norm_sq(&self, r: usize) -> f32 {
        match self {
            QuantMat::F16 { cols, h, .. } => h[r * cols..(r + 1) * cols]
                .iter()
                .map(|&hi| {
                    let x = f16_bits_to_f32(hi);
                    x * x
                })
                .sum(),
            QuantMat::Int8 { cols, scales, q, .. } => {
                let s: f32 = q[r * cols..(r + 1) * cols]
                    .iter()
                    .map(|&qi| f32::from(qi) * f32::from(qi))
                    .sum();
                scales[r] * scales[r] * s
            }
        }
    }

    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows(), self.cols());
        for r in 0..self.rows() {
            for c in 0..self.cols() {
                *out.at_mut(r, c) = self.get(r, c);
            }
        }
        out
    }

    /// Max per-element dequantization error bound over every row.
    pub fn eps(&self) -> f32 {
        match self {
            QuantMat::F16 { h, .. } => h
                .iter()
                .map(|&hi| f16_eps(f16_bits_to_f32(hi)))
                .fold(0.0, f32::max),
            QuantMat::Int8 { scales, .. } => scales
                .iter()
                .map(|&s| int8_eps(s))
                .fold(0.0, f32::max),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match self {
            QuantMat::F16 { h, .. } => 2 * h.len(),
            QuantMat::Int8 { scales, q, .. } => q.len() + 4 * scales.len(),
        }
    }

    /// The heap-resident share of [`QuantMat::resident_bytes`].
    pub fn heap_bytes(&self) -> usize {
        match self {
            QuantMat::F16 { h, .. } => h.heap_bytes(),
            QuantMat::Int8 { scales, q, .. } => {
                q.heap_bytes() + scales.heap_bytes()
            }
        }
    }

    /// The mapped-file share of [`QuantMat::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        match self {
            QuantMat::F16 { h, .. } => h.mapped_bytes(),
            QuantMat::Int8 { scales, q, .. } => {
                q.mapped_bytes() + scales.mapped_bytes()
            }
        }
    }

    fn check(&self, what: &str) -> std::result::Result<(), String> {
        let want = self.rows() * self.cols();
        match self {
            QuantMat::F16 { h, .. } => {
                if h.len() != want {
                    return Err(format!("{what}: storage length mismatch"));
                }
                check_f16_finite(h, what)
            }
            QuantMat::Int8 { scales, q, .. } => {
                if q.len() != want || scales.len() != self.rows() {
                    return Err(format!("{what}: storage length mismatch"));
                }
                for &s in scales.iter() {
                    check_scale(s, what)?;
                }
                Ok(())
            }
        }
    }
}

/// A quantized symmetric matrix stored as the packed upper triangle,
/// row-wise (packed row `r` holds `M[r][r..d]`, length `d − r`), with
/// per-packed-row int8 scales. This is both the wire layout (kind-4/5
/// approx records) and the resident layout — `d(d+1)/2` elements vs
/// the `d²` of the mirrored f32 [`Mat`].
#[derive(Clone, Debug)]
pub struct QuantSymMat {
    pub d: usize,
    pub data: QuantSymData,
}

#[derive(Clone, Debug)]
pub enum QuantSymData {
    F16(TensorData<u16>),
    Int8 { scales: TensorData<f32>, q: TensorData<i8> },
}

impl QuantSymMat {
    /// Packed length for dimension `d`.
    pub fn packed_len(d: usize) -> usize {
        d * (d + 1) / 2
    }

    /// Offset of packed row `r` (rows have lengths d, d−1, …, 1).
    #[inline]
    fn row_offset(&self, r: usize) -> usize {
        // Σ_{k<r} (d − k) = r·(2d − r + 1)/2, underflow-safe at r = 0.
        r * (2 * self.d - r + 1) / 2
    }

    /// Quantize the upper triangle of a symmetric `d × d` matrix.
    pub fn quantize(m: &Mat, kind: PayloadKind) -> Result<QuantSymMat> {
        let d = m.rows();
        if m.cols() != d {
            return Err(Error::Shape(format!(
                "QuantSymMat: {}×{} is not square",
                m.rows(),
                m.cols()
            )));
        }
        let mut packed = Vec::with_capacity(Self::packed_len(d));
        for r in 0..d {
            for c in r..d {
                packed.push(m.at(r, c));
            }
        }
        let data = match kind {
            PayloadKind::F16 => {
                check_f16_range(&packed)?;
                QuantSymData::F16(
                    packed.iter().map(|&x| f32_to_f16_bits(x)).collect(),
                )
            }
            PayloadKind::Int8 => {
                let mut scales = Vec::with_capacity(d);
                let mut q = Vec::with_capacity(packed.len());
                let mut off = 0;
                for r in 0..d {
                    let len = d - r;
                    let (s, rq) =
                        int8_quantize_row(&packed[off..off + len])?;
                    scales.push(s);
                    q.extend_from_slice(&rq);
                    off += len;
                }
                QuantSymData::Int8 { scales: scales.into(), q: q.into() }
            }
            PayloadKind::F32 => {
                return Err(Error::InvalidArg(
                    "QuantSymMat::quantize: f32 is not a quantized kind"
                        .into(),
                ))
            }
        };
        Ok(QuantSymMat { d, data })
    }

    pub fn payload(&self) -> PayloadKind {
        match &self.data {
            QuantSymData::F16(_) => PayloadKind::F16,
            QuantSymData::Int8 { .. } => PayloadKind::Int8,
        }
    }

    /// Dequantized element (r, c) of the mirrored matrix. Off-diagonal
    /// elements take the scale of the packed row they are stored in
    /// (`min(r, c)`).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (r, c) = if r <= c { (r, c) } else { (c, r) };
        let i = self.row_offset(r) + (c - r);
        match &self.data {
            QuantSymData::F16(h) => f16_bits_to_f32(h[i]),
            QuantSymData::Int8 { scales, q } => {
                int8_dequant(scales[r], q[i])
            }
        }
    }

    /// Contiguous packed-triangle f16 storage, when f16.
    pub fn as_f16(&self) -> Option<&[u16]> {
        match &self.data {
            QuantSymData::F16(h) => Some(&h[..]),
            QuantSymData::Int8 { .. } => None,
        }
    }

    /// `(per-packed-row scales, packed codes)` when int8 — the
    /// contiguous triangle views the quadratic-form kernels stream.
    pub fn as_i8(&self) -> Option<(&[f32], &[i8])> {
        match &self.data {
            QuantSymData::F16(_) => None,
            QuantSymData::Int8 { scales, q } => {
                Some((&scales[..], &q[..]))
            }
        }
    }

    /// Dequantized quadratic form `zᵀMz` over the packed triangle:
    /// `Σ_r z_r · (M_rr·z_r + 2·Σ_{c>r} M_rc·z_c)`, through the
    /// process-wide kernel arm (int8 quantizes `z` per call; batch
    /// evaluators quantize once and use [`QuantSymMat::quadform_with`]).
    pub fn quadform(&self, z: &[f32]) -> f32 {
        self.quadform_with(quantblas::active_arm(), z, None)
    }

    /// Quadratic form with an explicit kernel arm and, for int8, an
    /// optional pre-quantized query (quantized from `z` when absent).
    pub fn quadform_with(
        &self,
        arm: KernelArm,
        z: &[f32],
        qz: Option<&QuantZ>,
    ) -> f32 {
        debug_assert_eq!(z.len(), self.d);
        match &self.data {
            QuantSymData::F16(h) => quantblas::quadform_f16(arm, h, self.d, z),
            QuantSymData::Int8 { scales, q } => match qz {
                Some(qz) => quantblas::quadform_i8(arm, scales, q, self.d, qz),
                None => quantblas::quadform_i8(
                    arm,
                    scales,
                    q,
                    self.d,
                    &QuantZ::from_f32(z),
                ),
            },
        }
    }

    /// Mirror back into a dense f32 [`Mat`].
    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.d);
        for r in 0..self.d {
            for c in r..self.d {
                let v = self.get(r, c);
                *m.at_mut(r, c) = v;
                *m.at_mut(c, r) = v;
            }
        }
        m
    }

    /// Max per-element dequantization error bound over the triangle.
    pub fn eps(&self) -> f32 {
        match &self.data {
            QuantSymData::F16(h) => h
                .iter()
                .map(|&hi| f16_eps(f16_bits_to_f32(hi)))
                .fold(0.0, f32::max),
            QuantSymData::Int8 { scales, .. } => scales
                .iter()
                .map(|&s| int8_eps(s))
                .fold(0.0, f32::max),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            QuantSymData::F16(h) => 2 * h.len(),
            QuantSymData::Int8 { scales, q } => q.len() + 4 * scales.len(),
        }
    }

    /// The heap-resident share of [`QuantSymMat::resident_bytes`].
    pub fn heap_bytes(&self) -> usize {
        match &self.data {
            QuantSymData::F16(h) => h.heap_bytes(),
            QuantSymData::Int8 { scales, q } => {
                q.heap_bytes() + scales.heap_bytes()
            }
        }
    }

    /// The mapped-file share of [`QuantSymMat::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        match &self.data {
            QuantSymData::F16(h) => h.mapped_bytes(),
            QuantSymData::Int8 { scales, q } => {
                q.mapped_bytes() + scales.mapped_bytes()
            }
        }
    }

    fn check(&self, what: &str) -> std::result::Result<(), String> {
        let want = Self::packed_len(self.d);
        match &self.data {
            QuantSymData::F16(h) => {
                if h.len() != want {
                    return Err(format!("{what}: storage length mismatch"));
                }
                check_f16_finite(h, what)
            }
            QuantSymData::Int8 { scales, q } => {
                if q.len() != want || scales.len() != self.d {
                    return Err(format!("{what}: storage length mismatch"));
                }
                for &s in scales.iter() {
                    check_scale(s, what)?;
                }
                Ok(())
            }
        }
    }
}

fn check_f16_range(xs: &[f32]) -> Result<()> {
    for &x in xs {
        if !x.is_finite() {
            return Err(Error::InvalidArg(format!(
                "cannot quantize non-finite value {x}"
            )));
        }
        if x.abs() > F16_MAX {
            return Err(Error::InvalidArg(format!(
                "value {x} exceeds the f16 range (±{F16_MAX}); \
                 quantize as int8 or keep f32"
            )));
        }
    }
    Ok(())
}

fn check_f16_finite(h: &[u16], what: &str) -> std::result::Result<(), String> {
    match h.iter().position(|&hi| (hi >> 10) & 0x1f == 0x1f) {
        Some(i) => Err(format!("{what}: non-finite f16 at index {i}")),
        None => Ok(()),
    }
}

fn check_scale(s: f32, what: &str) -> std::result::Result<(), String> {
    if !s.is_finite() || s < 0.0 {
        Err(format!("{what}: invalid int8 scale {s}"))
    } else {
        Ok(())
    }
}

// ---------------------------------------------------------------------
// quantized models
// ---------------------------------------------------------------------

/// An exact SVM model whose coefficient vector and SV matrix stay in
/// quantized storage (kind-4/5 role-1 records).
#[derive(Clone, Debug)]
pub struct QuantSvmModel {
    pub kernel: Kernel,
    pub b: f32,
    pub coef: QuantVec,
    pub sv: QuantMat,
}

impl QuantSvmModel {
    /// Quantize an f32 model (publish path).
    pub fn quantize(m: &SvmModel, kind: PayloadKind) -> Result<QuantSvmModel> {
        m.check_finite().map_err(Error::InvalidArg)?;
        Ok(QuantSvmModel {
            kernel: m.kernel,
            b: m.b,
            coef: QuantVec::quantize(&m.coef, kind)?,
            sv: QuantMat::quantize(&m.sv, kind)?,
        })
    }

    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    pub fn dim(&self) -> usize {
        self.sv.cols()
    }

    pub fn payload(&self) -> PayloadKind {
        self.sv.payload()
    }

    /// Squared norms of the dequantized SV rows (cached per generation
    /// by the serving executor, exactly like the f32 path).
    pub fn sv_row_norms_sq(&self) -> Vec<f32> {
        (0..self.n_sv()).map(|r| self.sv.row_norm_sq(r)).collect()
    }

    /// Exact decision value on the native quantized storage through
    /// the process-wide kernel arm (reference path; the batched
    /// evaluator in [`crate::predictor`] calls the same
    /// [`QuantSvmModel::decision_with_norms`], so served batches are
    /// bit-identical to this per-row form).
    pub fn decision_one(&self, z: &[f32]) -> f32 {
        self.decision_with_norms(quantblas::active_arm(), z, None)
    }

    /// Decision value with an explicit kernel arm.
    pub fn decision_one_with(&self, arm: KernelArm, z: &[f32]) -> f32 {
        self.decision_with_norms(arm, z, None)
    }

    /// Decision value with an explicit arm and, optionally, cached
    /// dequantized SV norms (the serving executor caches them per
    /// generation; `None` recomputes — identical values either way).
    ///
    /// f16 storage streams the f32 query; int8 storage quantizes the
    /// query once to i16 and runs the exact-integer kernels, so the
    /// result is bit-identical across arms. The RBF distance then uses
    /// the *quantized* query's own norm (`K(x̂, ẑ)` exactly), keeping
    /// the Lipschitz error analysis of
    /// [`ExactQuantErr::decision_error_at`] tight.
    pub fn decision_with_norms(
        &self,
        arm: KernelArm,
        z: &[f32],
        norms: Option<&[f32]>,
    ) -> f32 {
        let xn_of = |r: usize| match norms {
            Some(n) => n[r],
            None => self.sv.row_norm_sq(r),
        };
        let mut acc = self.b;
        match &self.sv {
            QuantMat::F16 { cols, h, .. } => {
                let zn = vecops::norm_sq(z);
                for r in 0..self.n_sv() {
                    let row = &h[r * cols..(r + 1) * cols];
                    let cross = quantblas::dot_f16(arm, row, z);
                    acc += self.coef.get(r)
                        * self.kernel.eval_precomp(xn_of(r), zn, cross);
                }
            }
            QuantMat::Int8 { cols, scales, q, .. } => {
                let qz = QuantZ::from_f32(z);
                let zn = qz.norm_sq;
                for r in 0..self.n_sv() {
                    let row = &q[r * cols..(r + 1) * cols];
                    let cross = quantblas::dot_i8(arm, row, scales[r], &qz);
                    acc += self.coef.get(r)
                        * self.kernel.eval_precomp(xn_of(r), zn, cross);
                }
            }
        }
        acc
    }

    /// Materialize the dequantized f32 model (PJRT preparation, tests).
    pub fn dequantize(&self) -> SvmModel {
        SvmModel {
            kernel: self.kernel,
            sv: self.sv.dequantize(),
            coef: self.coef.dequantize(),
            b: self.b,
        }
    }

    /// Dequantization error metadata for
    /// [`crate::approx::bounds::ExactQuantErr::decision_error`]. The
    /// decision bound is derived from the RBF kernel's `K ∈ (0, 1]`
    /// range and global Lipschitz constant, so non-RBF kernels
    /// (linear, poly2 — both unbounded in `x`) report `gamma = NaN`
    /// and the bound comes back as ∞ ("unavailable").
    pub fn quant_err(&self) -> ExactQuantErr {
        let coef_abs_sum =
            (0..self.n_sv()).map(|i| self.coef.get(i).abs()).sum();
        let gamma = match self.kernel {
            Kernel::Rbf { gamma } => gamma,
            Kernel::Linear | Kernel::Poly2 { .. } => f32::NAN,
        };
        ExactQuantErr {
            n_sv: self.n_sv(),
            dim: self.dim(),
            gamma,
            coef_abs_sum,
            eps_coef: self.coef.eps(),
            eps_sv: self.sv.eps(),
            // int8 SV rows are dotted against an i16-quantized query
            // (exact-integer kernels); f16 rows stream the f32 query.
            eps_z_rel: match self.sv {
                QuantMat::F16 { .. } => 0.0,
                QuantMat::Int8 { .. } => quantblas::Z16_REL_EPS,
            },
        }
    }

    /// Approximate resident footprint in bytes (storage only).
    pub fn resident_bytes(&self) -> usize {
        self.coef.resident_bytes() + self.sv.resident_bytes() + 16
    }

    /// Heap share of [`QuantSvmModel::resident_bytes`] (everything for
    /// a v1 decode; only scalars/scales when served from a mapped v2
    /// bundle).
    pub fn heap_bytes(&self) -> usize {
        self.coef.heap_bytes() + self.sv.heap_bytes() + 16
    }

    /// Mapped-file share of [`QuantSvmModel::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        self.coef.mapped_bytes() + self.sv.mapped_bytes()
    }

    /// Structural + value validation (shared by the binary decoder).
    pub fn check(&self) -> std::result::Result<(), String> {
        if self.sv.rows() != self.coef.len() {
            return Err(format!(
                "{} SVs vs {} quantized coefficients",
                self.sv.rows(),
                self.coef.len()
            ));
        }
        if !self.b.is_finite() {
            return Err(format!("non-finite b: {}", self.b));
        }
        let (gamma, beta) = match self.kernel {
            Kernel::Linear => (0.0, 0.0),
            Kernel::Rbf { gamma } => (gamma, 0.0),
            Kernel::Poly2 { gamma, beta } => (gamma, beta),
        };
        if !gamma.is_finite() || !beta.is_finite() {
            return Err("non-finite kernel parameter".into());
        }
        self.coef.check("coef")?;
        self.sv.check("sv")
    }
}

/// An approximated (Eq. 3.8) model whose `v` and `M` stay in quantized
/// storage (kind-4/5 role-2 records). Scalars are f32.
#[derive(Clone, Debug)]
pub struct QuantApproxModel {
    pub gamma: f32,
    pub b: f32,
    pub c: f32,
    pub max_sv_norm_sq: f32,
    pub v: QuantVec,
    pub m: QuantSymMat,
}

impl QuantApproxModel {
    /// Quantize an f32 approx model (publish path).
    pub fn quantize(
        am: &ApproxModel,
        kind: PayloadKind,
    ) -> Result<QuantApproxModel> {
        am.check_finite().map_err(Error::InvalidArg)?;
        Ok(QuantApproxModel {
            gamma: am.gamma,
            b: am.b,
            c: am.c,
            max_sv_norm_sq: am.max_sv_norm_sq,
            v: QuantVec::quantize(&am.v, kind)?,
            m: QuantSymMat::quantize(&am.m, kind)?,
        })
    }

    pub fn dim(&self) -> usize {
        self.v.len()
    }

    pub fn payload(&self) -> PayloadKind {
        self.v.payload()
    }

    /// The raw Eq. 3.11 budget of the dequantized model (quantization
    /// drift is folded in by
    /// [`super::ModelEntry::znorm_sq_budget_with`]).
    pub fn znorm_sq_budget(&self) -> f32 {
        1.0 / (16.0 * self.gamma * self.gamma * self.max_sv_norm_sq)
    }

    /// Decision value + ‖z‖² on the native quantized storage through
    /// the process-wide kernel arm. The reported ‖z‖² is always the
    /// f32 query's norm (it feeds the Eq. 3.11 routing check), and the
    /// exponential uses it too; only the linear/quadratic forms see
    /// the i16-quantized query on int8 payloads.
    pub fn decision_one(&self, z: &[f32]) -> (f32, f32) {
        self.decision_one_with(quantblas::active_arm(), z)
    }

    /// Decision value + ‖z‖² with an explicit kernel arm. int8
    /// payloads quantize the query once and run the exact-integer
    /// kernels, so the decision is bit-identical across arms.
    pub fn decision_one_with(&self, arm: KernelArm, z: &[f32]) -> (f32, f32) {
        debug_assert_eq!(z.len(), self.dim());
        let zn = vecops::norm_sq(z);
        let qz = match (&self.v, &self.m.data) {
            (QuantVec::Int8 { .. }, _) | (_, QuantSymData::Int8 { .. }) => {
                Some(QuantZ::from_f32(z))
            }
            _ => None,
        };
        let lin = match &self.v {
            QuantVec::F16(h) => quantblas::dot_f16(arm, h, z),
            QuantVec::Int8 { scale, q } => {
                quantblas::dot_i8(arm, q, *scale, qz.as_ref().unwrap())
            }
        };
        let quad = self.m.quadform_with(arm, z, qz.as_ref());
        ((-self.gamma * zn).exp() * (self.c + lin + quad) + self.b, zn)
    }

    /// Materialize the dequantized f32 model.
    pub fn dequantize(&self) -> ApproxModel {
        ApproxModel {
            gamma: self.gamma,
            b: self.b,
            c: self.c,
            v: self.v.dequantize(),
            m: self.m.dequantize(),
            max_sv_norm_sq: self.max_sv_norm_sq,
        }
    }

    /// Dequantization error bound metadata for the serving router:
    /// per-element weight bounds plus the query-quantization terms of
    /// the int8 integer kernels (dequantized |v|/|M| mass and the i16
    /// relative query error; zero for f16, whose kernels stream the
    /// f32 query).
    pub fn quant_err(&self) -> QuantErrorBound {
        let d = self.dim();
        let v_abs_sum = (0..d).map(|i| self.v.get(i).abs()).sum();
        // Mirrored |M̂| mass: packed row r holds M[r][r..d] — the
        // diagonal counts once, off-diagonal elements twice.
        let mut m_abs_sum = 0.0f32;
        for r in 0..d {
            m_abs_sum += self.m.get(r, r).abs();
            for c in r + 1..d {
                m_abs_sum += 2.0 * self.m.get(r, c).abs();
            }
        }
        let int8_query = matches!(self.v, QuantVec::Int8 { .. })
            || matches!(self.m.data, QuantSymData::Int8 { .. });
        QuantErrorBound {
            dim: d,
            eps_v: self.v.eps(),
            eps_m: self.m.eps(),
            eps_z_rel: if int8_query { quantblas::Z16_REL_EPS } else { 0.0 },
            v_abs_sum,
            m_abs_sum,
        }
    }

    /// Approximate resident footprint in bytes (storage only).
    pub fn resident_bytes(&self) -> usize {
        self.v.resident_bytes() + self.m.resident_bytes() + 20
    }

    /// Heap share of [`QuantApproxModel::resident_bytes`].
    pub fn heap_bytes(&self) -> usize {
        self.v.heap_bytes() + self.m.heap_bytes() + 20
    }

    /// Mapped-file share of [`QuantApproxModel::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        self.v.mapped_bytes() + self.m.mapped_bytes()
    }

    /// Structural + value validation (shared by the binary decoder).
    pub fn check(&self) -> std::result::Result<(), String> {
        if self.m.d != self.v.len() {
            return Err(format!(
                "quantized M is {0}×{0} but v has dim {1}",
                self.m.d,
                self.v.len()
            ));
        }
        for (name, val) in [
            ("gamma", self.gamma),
            ("b", self.b),
            ("c", self.c),
            ("max_sv_norm_sq", self.max_sv_norm_sq),
        ] {
            if !val.is_finite() {
                return Err(format!("non-finite {name}: {val}"));
            }
        }
        if self.max_sv_norm_sq < 0.0 {
            return Err(format!(
                "negative max_sv_norm_sq: {}",
                self.max_sv_norm_sq
            ));
        }
        self.v.check("v")?;
        self.m.check("M")
    }
}

// ---------------------------------------------------------------------
// the per-tenant model pair, in either precision
// ---------------------------------------------------------------------

/// The models a bundle decodes to — the full-precision f32 pair,
/// native quantized storage, or the random-feature substrate (the f32
/// pair plus the kind-6 [`RffModel`]; its fast path replaces the
/// Maclaurin model on the approx serving slot).
#[derive(Clone, Debug)]
pub enum TenantModels {
    F32 { exact: SvmModel, approx: ApproxModel },
    Quantized { exact: QuantSvmModel, approx: QuantApproxModel },
    Rff { exact: SvmModel, approx: ApproxModel, rff: RffModel },
}

impl TenantModels {
    pub fn dim(&self) -> usize {
        match self {
            TenantModels::F32 { approx, .. } => approx.dim(),
            TenantModels::Quantized { approx, .. } => approx.dim(),
            TenantModels::Rff { rff, .. } => rff.dim(),
        }
    }

    pub fn n_sv(&self) -> usize {
        match self {
            TenantModels::F32 { exact, .. } => exact.n_sv(),
            TenantModels::Quantized { exact, .. } => exact.n_sv(),
            TenantModels::Rff { exact, .. } => exact.n_sv(),
        }
    }

    /// Payload precision of the stored tensors. Rff bundles store f32
    /// (substrate and precision are orthogonal axes; the header's
    /// `FLAG_RFF` carries the substrate).
    pub fn payload(&self) -> PayloadKind {
        match self {
            TenantModels::F32 { .. } => PayloadKind::F32,
            TenantModels::Quantized { exact, .. } => exact.payload(),
            TenantModels::Rff { .. } => PayloadKind::F32,
        }
    }

    /// The random-feature model, when this tenant serves that substrate.
    pub fn rff(&self) -> Option<&RffModel> {
        match self {
            TenantModels::Rff { rff, .. } => Some(rff),
            _ => None,
        }
    }

    /// Raw Eq. 3.11 budget of the (dequantized) Maclaurin model. For
    /// rff tenants this is the retained twin's budget — the serving
    /// gate ([`super::ModelEntry::znorm_sq_budget_with`]) replaces it
    /// with the stored-error-estimate test, which has no ‖z‖² shape.
    pub fn approx_znorm_sq_budget(&self) -> f32 {
        match self {
            TenantModels::F32 { approx, .. } => approx.znorm_sq_budget(),
            TenantModels::Quantized { approx, .. } => {
                approx.znorm_sq_budget()
            }
            TenantModels::Rff { approx, .. } => approx.znorm_sq_budget(),
        }
    }

    /// Approx-side dequantization error bound (`None` for f32/rff).
    pub fn quant_error(&self) -> Option<QuantErrorBound> {
        match self {
            TenantModels::Quantized { approx, .. } => {
                Some(approx.quant_err())
            }
            TenantModels::F32 { .. } | TenantModels::Rff { .. } => None,
        }
    }

    /// Exact-side dequantization error bound (`None` for f32/rff).
    pub fn exact_quant_error(&self) -> Option<ExactQuantErr> {
        match self {
            TenantModels::Quantized { exact, .. } => Some(exact.quant_err()),
            TenantModels::F32 { .. } | TenantModels::Rff { .. } => None,
        }
    }

    /// SV row norms of the (dequantized) exact model.
    pub fn sv_row_norms_sq(&self) -> Vec<f32> {
        match self {
            TenantModels::F32 { exact, .. } => exact.sv.row_norms_sq(),
            TenantModels::Quantized { exact, .. } => exact.sv_row_norms_sq(),
            TenantModels::Rff { exact, .. } => exact.sv.row_norms_sq(),
        }
    }

    /// Reference approx-slot decision on whatever storage is served —
    /// the same per-row arithmetic the executor's batched evaluator
    /// uses, so tests can compare served decisions against this
    /// regardless of payload kind. For rff tenants the approx slot
    /// serves the random-feature model, never the Maclaurin twin.
    pub fn approx_decision_one(&self, z: &[f32]) -> f32 {
        match self {
            TenantModels::F32 { approx, .. } => approx.decision_one(z).0,
            TenantModels::Quantized { approx, .. } => {
                approx.decision_one(z).0
            }
            TenantModels::Rff { rff, .. } => rff.decision_one(z).0,
        }
    }

    /// Reference exact decision on whatever storage is served.
    pub fn exact_decision_one(&self, z: &[f32]) -> f32 {
        match self {
            TenantModels::F32 { exact, .. } => exact.decision_one(z),
            TenantModels::Quantized { exact, .. } => exact.decision_one(z),
            TenantModels::Rff { exact, .. } => exact.decision_one(z),
        }
    }

    /// Dequantized copies (PJRT preparation, tests; clones for f32/rff).
    pub fn exact_dequant(&self) -> SvmModel {
        match self {
            TenantModels::F32 { exact, .. } => exact.clone(),
            TenantModels::Quantized { exact, .. } => exact.dequantize(),
            TenantModels::Rff { exact, .. } => exact.clone(),
        }
    }

    pub fn approx_dequant(&self) -> ApproxModel {
        match self {
            TenantModels::F32 { approx, .. } => approx.clone(),
            TenantModels::Quantized { approx, .. } => approx.dequantize(),
            TenantModels::Rff { approx, .. } => approx.clone(),
        }
    }

    /// Approximate resident footprint of both models, in bytes —
    /// the quantity `BENCH_quant.json` reports per payload kind. The
    /// f32 accounting mirrors what is actually resident: a dense
    /// `n_sv×d` SV matrix and the *mirrored* `d×d` M. Rff tenants add
    /// the regenerated `D×d` feature map on top of the f32 pair.
    pub fn resident_bytes(&self) -> usize {
        match self {
            TenantModels::F32 { exact, approx } => {
                let e = 4 * (exact.n_sv() * exact.dim() + exact.n_sv()) + 16;
                let a = 4 * (approx.dim() * approx.dim() + approx.dim()) + 20;
                e + a
            }
            TenantModels::Quantized { exact, approx } => {
                exact.resident_bytes() + approx.resident_bytes()
            }
            TenantModels::Rff { exact, approx, rff } => {
                let e = 4 * (exact.n_sv() * exact.dim() + exact.n_sv()) + 16;
                let a = 4 * (approx.dim() * approx.dim() + approx.dim()) + 20;
                e + a + rff.resident_bytes()
            }
        }
    }

    /// The heap-resident share of [`TenantModels::resident_bytes`] —
    /// what the LRU budget and the metrics `per_model_table` should
    /// charge this tenant. Equal to `resident_bytes()` for v1 heap
    /// decodes; for a bundle served from a mapped v2 file only the
    /// scalars, scales and regenerated rff feature map stay on the
    /// heap.
    pub fn heap_bytes(&self) -> usize {
        match self {
            TenantModels::F32 { .. } => self.resident_bytes(),
            TenantModels::Quantized { exact, approx } => {
                exact.heap_bytes() + approx.heap_bytes()
            }
            TenantModels::Rff { exact, approx, rff } => {
                let e = 4 * (exact.n_sv() * exact.dim() + exact.n_sv()) + 16;
                let a = 4 * (approx.dim() * approx.dim() + approx.dim()) + 20;
                e + a + rff.heap_bytes()
            }
        }
    }

    /// The mapped-file share of [`TenantModels::resident_bytes`].
    pub fn mapped_bytes(&self) -> usize {
        match self {
            TenantModels::F32 { .. } => 0,
            TenantModels::Quantized { exact, approx } => {
                exact.mapped_bytes() + approx.mapped_bytes()
            }
            TenantModels::Rff { rff, .. } => rff.mapped_bytes(),
        }
    }
}

/// Summary of a quantized bundle's error metadata (carried by
/// [`super::ModelEntry`]-level accessors and the CLI `inspect` output).
#[derive(Clone, Copy, Debug)]
pub struct QuantInfo {
    pub payload: PayloadKind,
    pub approx_err: QuantErrorBound,
    pub exact_err: ExactQuantErr,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;

    // -- f16 scalar codec ---------------------------------------------

    #[test]
    fn f16_known_values() {
        // (f32, f16 bits) pairs exactly representable in binary16.
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (0.25, 0x3400),
            (0.75, 0x3a00),
            (65504.0, 0x7bff),
            (6.1035156e-5, 0x0400), // smallest normal
            (5.9604645e-8, 0x0001), // smallest subnormal
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "{x}");
            assert_eq!(f16_bits_to_f32(bits), x, "{bits:#06x}");
        }
        // An inexact value rounds to its nearest f16: 0.1 → 0x2e66,
        // which decodes to exactly 0.099975586.
        assert_eq!(f32_to_f16_bits(0.1), 0x2e66);
        assert_eq!(f16_bits_to_f32(0x2e66), 0.099975586);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2⁻¹¹ is exactly halfway between 1.0 and the next f16
        // (1 + 2⁻¹⁰): ties to even → 1.0 (mantissa 0 is even).
        let tie = 1.0 + 4.8828125e-4;
        assert_eq!(f32_to_f16_bits(tie), 0x3c00);
        // 1 + 3·2⁻¹¹ is halfway between 1+2⁻¹⁰ and 1+2⁻⁹: ties to
        // even → 1+2⁻⁹ (mantissa 2).
        let tie = 1.0 + 3.0 * 4.8828125e-4;
        assert_eq!(f32_to_f16_bits(tie), 0x3c02);
    }

    #[test]
    fn property_f16_roundtrip_within_advertised_bound() {
        prop_cases!("f16 roundtrip bound", 64, |rng| {
            for _ in 0..64 {
                // Magnitudes spanning subnormal to near-max range.
                let mag = 10f64.powf(rng.range(-9.0, 4.5));
                let x = (rng.normal() * mag) as f32;
                if x.abs() > F16_MAX {
                    continue;
                }
                let x_hat = f16_bits_to_f32(f32_to_f16_bits(x));
                assert!(x_hat.is_finite(), "{x} -> non-finite");
                assert!(
                    (x - x_hat).abs() <= f16_eps(x_hat),
                    "{x}: dequant {x_hat}, err {} > bound {}",
                    (x - x_hat).abs(),
                    f16_eps(x_hat)
                );
            }
        });
    }

    #[test]
    fn f16_out_of_range_rejected_by_quantize() {
        let err = QuantVec::quantize(&[1.0, 70000.0], PayloadKind::F16)
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArg(m) if m.contains("f16")));
        assert!(
            QuantVec::quantize(&[f32::NAN], PayloadKind::F16).is_err()
        );
    }

    // -- int8 row codec -----------------------------------------------

    #[test]
    fn int8_exact_multiples_roundtrip_exactly() {
        // max = 127·2⁻⁷ makes the scale exactly 2⁻⁷; multiples of the
        // scale quantize with zero error.
        let row = [0.9921875f32, -0.5, 0.25, 0.0078125, 0.0];
        let (scale, q) = int8_quantize_row(&row).unwrap();
        assert_eq!(scale, 0.0078125);
        assert_eq!(q, vec![127, -64, 32, 1, 0]);
        for (i, &x) in row.iter().enumerate() {
            assert_eq!(int8_dequant(scale, q[i]), x);
        }
    }

    #[test]
    fn property_int8_roundtrip_within_advertised_bound() {
        prop_cases!("int8 roundtrip bound", 64, |rng| {
            let n = 1 + rng.below(64);
            // Down to deep-subnormal magnitudes: the scale fallback
            // must uphold the bound across the whole f32 range.
            let mag = 10f64.powf(rng.range(-42.0, 6.0));
            let row: Vec<f32> =
                (0..n).map(|_| (rng.normal() * mag) as f32).collect();
            let (scale, q) = int8_quantize_row(&row).unwrap();
            let bound = int8_eps(scale);
            for (i, &x) in row.iter().enumerate() {
                let x_hat = int8_dequant(scale, q[i]);
                assert!(x_hat.is_finite());
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row[{i}]={x}: dequant {x_hat}, scale {scale}"
                );
            }
        });
    }

    #[test]
    fn int8_edge_cases_never_panic_or_go_nonfinite() {
        // All-zero row.
        let (s, q) = int8_quantize_row(&[0.0; 7]).unwrap();
        assert_eq!(s, 0.0);
        assert!(q.iter().all(|&x| x == 0));
        assert_eq!(int8_eps(s), 0.0);
        // Single-element rows, including negatives.
        for x in [1.0f32, -3.5, 1e-30, 1e30] {
            let (s, q) = int8_quantize_row(&[x]).unwrap();
            assert_eq!(q[0].unsigned_abs(), 127, "{x}");
            assert!((x - int8_dequant(s, q[0])).abs() <= int8_eps(s));
        }
        // Subnormal max: the scale fallback keeps everything finite.
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let (s, q) = int8_quantize_row(&[tiny, -tiny, 0.0]).unwrap();
        assert!(s.is_finite() && s > 0.0);
        for &qi in &q {
            assert!(int8_dequant(s, qi).is_finite());
        }
        // Rows whose max/127 would be a *nonzero subnormal* (imprecise
        // division) must take the scale = max fallback too, or the
        // advertised bound breaks: e.g. max = 178 ULPs of f32.
        for bits in [178u32, 300, 2_000, 100_000] {
            let big = f32::from_bits(bits);
            let small = f32::from_bits(bits / 3);
            let (s, q) = int8_quantize_row(&[big, -small]).unwrap();
            let bound = int8_eps(s);
            for (x, qi) in [(big, q[0]), (-small, q[1])] {
                assert!(
                    (x - int8_dequant(s, qi)).abs() <= bound,
                    "bits={bits}: {x} vs {}",
                    int8_dequant(s, qi)
                );
            }
        }
        // Extreme dynamic range: small values collapse to 0 but stay
        // within the advertised bound.
        let row = [1e30f32, 1e-30];
        let (s, q) = int8_quantize_row(&row).unwrap();
        assert_eq!(q[1], 0);
        assert!((row[1] - int8_dequant(s, q[1])).abs() <= int8_eps(s));
        // Non-finite rejected.
        assert!(int8_quantize_row(&[f32::INFINITY]).is_err());
        assert!(int8_quantize_row(&[f32::NAN, 1.0]).is_err());
    }

    // -- tensor storage -----------------------------------------------

    fn toy_sym() -> Mat {
        Mat::from_vec(
            3,
            3,
            vec![0.5, 0.25, -1.0, 0.25, -0.75, 2.0, -1.0, 2.0, 0.125],
        )
        .unwrap()
    }

    #[test]
    fn symmat_packed_indexing_matches_dense() {
        let m = toy_sym();
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let qm = QuantSymMat::quantize(&m, kind).unwrap();
            let dense = qm.dequantize();
            for r in 0..3 {
                for c in 0..3 {
                    assert_eq!(qm.get(r, c), dense.at(r, c), "{kind}");
                    assert_eq!(dense.at(r, c), dense.at(c, r));
                }
            }
        }
    }

    #[test]
    fn property_quadform_matches_dequantized_dense() {
        prop_cases!("quant quadform", 32, |rng| {
            let d = 1 + rng.below(12);
            let mut m = Mat::zeros(d, d);
            for r in 0..d {
                for c in r..d {
                    let val = rng.normal() as f32;
                    *m.at_mut(r, c) = val;
                    *m.at_mut(c, r) = val;
                }
            }
            let z: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            for kind in [PayloadKind::F16, PayloadKind::Int8] {
                let qm = QuantSymMat::quantize(&m, kind).unwrap();
                let dense = qm.dequantize();
                let want = crate::linalg::quadform::quadform_symmetric(
                    &dense, &z,
                );
                // int8 evaluates at the i16-quantized query: cover the
                // |ẑᵀM̂ẑ − zᵀM̂z| ≤ Σ|M̂|·(2‖z‖ + eps_z)·eps_z term.
                let zn = vecops::norm_sq(&z);
                let eps_z = match kind {
                    PayloadKind::Int8 => {
                        quantblas::Z16_REL_EPS * zn.sqrt()
                    }
                    _ => 0.0,
                };
                let m_abs: f32 =
                    dense.as_slice().iter().map(|x| x.abs()).sum();
                let tol = 1e-4 * (1.0 + want.abs())
                    + m_abs * (2.0 * zn.sqrt() + eps_z) * eps_z;
                for arm in quantblas::available_arms() {
                    let got = qm.quadform_with(arm, &z, None);
                    assert!(
                        (got - want).abs() <= tol,
                        "{kind}/{arm}: {got} vs {want}"
                    );
                }
            }
        });
    }

    #[test]
    fn quantized_models_roundtrip_within_decision_bound() {
        prop_cases!("quant model decisions", 16, |rng| {
            let d = 2 + rng.below(10);
            let mut m = Mat::zeros(d, d);
            for r in 0..d {
                for c in r..d {
                    let val = (rng.normal() * 0.3) as f32;
                    *m.at_mut(r, c) = val;
                    *m.at_mut(c, r) = val;
                }
            }
            let am = ApproxModel {
                gamma: rng.range(0.01, 0.5) as f32,
                b: rng.normal() as f32,
                c: rng.normal() as f32,
                v: (0..d).map(|_| rng.normal() as f32).collect(),
                m,
                max_sv_norm_sq: rng.range(0.5, 4.0) as f32,
            };
            let z: Vec<f32> =
                (0..d).map(|_| (rng.normal() * 0.5) as f32).collect();
            let zn = vecops::norm_sq(&z);
            let (want, _) = am.decision_one(&z);
            for kind in [PayloadKind::F16, PayloadKind::Int8] {
                let qa = QuantApproxModel::quantize(&am, kind).unwrap();
                qa.check().unwrap();
                let (got, got_zn) = qa.decision_one(&z);
                assert!((got_zn - zn).abs() < 1e-5);
                let bound = qa.quant_err().decision_error(zn);
                assert!(
                    (got - want).abs() <= bound,
                    "{kind}: |{got} - {want}| > bound {bound}"
                );
            }
        });
    }

    #[test]
    fn quantized_svm_decisions_within_exact_bound() {
        prop_cases!("quant svm decisions", 16, |rng| {
            let d = 2 + rng.below(8);
            let n_sv = 1 + rng.below(12);
            let mut sv = Mat::zeros(n_sv, d);
            for r in 0..n_sv {
                for c in 0..d {
                    if rng.chance(0.7) {
                        *sv.at_mut(r, c) = (rng.normal() * 0.4) as f32;
                    }
                }
            }
            let coef: Vec<f32> =
                (0..n_sv).map(|_| rng.normal() as f32).collect();
            let gamma = rng.range(0.05, 1.0) as f32;
            let m = SvmModel::new(
                Kernel::Rbf { gamma },
                sv,
                coef,
                rng.normal() as f32,
            )
            .unwrap();
            let z: Vec<f32> =
                (0..d).map(|_| (rng.normal() * 0.5) as f32).collect();
            let want = m.decision_one(&z);
            let zn = vecops::norm_sq(&z);
            for kind in [PayloadKind::F16, PayloadKind::Int8] {
                let qm = QuantSvmModel::quantize(&m, kind).unwrap();
                qm.check().unwrap();
                let got = qm.decision_one(&z);
                let bound = qm.quant_err().decision_error_at(zn);
                assert!(
                    (got - want).abs() <= bound,
                    "{kind}: |{got} - {want}| > bound {bound}"
                );
                // The z-independent weight bound stays the CLI summary
                // and is never above the served bound.
                assert!(bound >= qm.quant_err().decision_error());
                // Dequantized twin agrees with the native evaluation
                // far inside the bound (int8 adds only the marginal
                // i16 query-quantization drift).
                let deq = qm.dequantize().decision_one(&z);
                assert!((got - deq).abs() < 5e-3, "{kind}");
                // Every dispatch arm returns the same int8 bits.
                if kind == PayloadKind::Int8 {
                    for arm in quantblas::available_arms() {
                        let via = qm.decision_one_with(arm, &z);
                        assert_eq!(via.to_bits(), got.to_bits(), "{arm}");
                    }
                }
            }
        });
    }

    #[test]
    fn resident_bytes_shrink_at_least_2x() {
        let d = 24;
        let n_sv = 40;
        let mut sv = Mat::zeros(n_sv, d);
        let mut m = Mat::zeros(d, d);
        for r in 0..n_sv {
            for c in 0..d {
                *sv.at_mut(r, c) = ((r * 7 + c) % 13) as f32 * 0.05 - 0.25;
            }
        }
        for r in 0..d {
            for c in r..d {
                let val = ((r + 2 * c) % 9) as f32 * 0.1 - 0.4;
                *m.at_mut(r, c) = val;
                *m.at_mut(c, r) = val;
            }
        }
        let exact = SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            sv,
            vec![0.5; n_sv],
            0.1,
        )
        .unwrap();
        let approx = ApproxModel {
            gamma: 0.25,
            b: 0.1,
            c: 0.2,
            v: vec![0.125; d],
            m,
            max_sv_norm_sq: 2.0,
        };
        let f32_bytes = TenantModels::F32 {
            exact: exact.clone(),
            approx: approx.clone(),
        }
        .resident_bytes();
        for (kind, min_ratio) in
            [(PayloadKind::F16, 2.0f64), (PayloadKind::Int8, 3.5)]
        {
            let q = TenantModels::Quantized {
                exact: QuantSvmModel::quantize(&exact, kind).unwrap(),
                approx: QuantApproxModel::quantize(&approx, kind).unwrap(),
            };
            let ratio = f32_bytes as f64 / q.resident_bytes() as f64;
            assert!(
                ratio >= min_ratio,
                "{kind}: ratio {ratio:.2} < {min_ratio}"
            );
        }
    }

    #[test]
    fn payload_kind_parse_display_roundtrip() {
        for k in [PayloadKind::F32, PayloadKind::F16, PayloadKind::Int8] {
            assert_eq!(k.to_string().parse::<PayloadKind>().unwrap(), k);
        }
        assert_eq!("half".parse::<PayloadKind>().unwrap(), PayloadKind::F16);
        assert_eq!("i8".parse::<PayloadKind>().unwrap(), PayloadKind::Int8);
        assert!("f64".parse::<PayloadKind>().is_err());
    }
}
