//! Model registry: compact binary model artifacts + a directory-backed
//! multi-tenant store — the packaging layer that turns the paper's
//! "smaller memory footprint" result (Table 3: the approximated model
//! is `O(d²)` regardless of `n_SV`) into an operational property: one
//! serving node can host thousands of approximated models and swap
//! republished versions in place.
//!
//! Four pieces:
//!
//! * [`binfmt`] — the `.arbf` format: versioned little-endian records
//!   for [`crate::svm::SvmModel`] and [`crate::approx::ApproxModel`]
//!   with magic/CRC-32 framing, strict non-finite rejection and
//!   truncation-safe decoding (every failure is a typed
//!   [`crate::Error::Corrupt`]). Byte-exact layout: `docs/FORMATS.md`,
//!   pinned by the golden corpus under `rust/tests/data/`.
//! * [`quant`] — f16/int8 payload codecs (kind-4/5 records) with
//!   advertised per-element error bounds, and the native quantized
//!   model storage ([`QuantSvmModel`] / [`QuantApproxModel`]) the
//!   serving layer evaluates directly — ≥2× smaller resident models,
//!   with dequantization drift folded into the Eq. 3.11 routing budget
//!   (see [`crate::approx::bounds`]).
//! * [`store`] — [`ModelStore`]: one `<id>.arbf` bundle (exact +
//!   approx + optional [`TenantPolicy`]) per model id under a root
//!   directory, published atomically (tmp file + rename) with a
//!   monotonically increasing generation counter persisted in the file
//!   header, loaded lazily through an LRU-bounded in-memory cache.
//!   Replaced bundles are archived as `<id>.arbf.gen-<k>` for
//!   [`ModelStore::rollback`]; [`PublishOptions::warm`] pre-seeds the
//!   cache so a fresh tenant's first request skips the cold decode.
//! * The serving integration lives in [`crate::coordinator`]: requests
//!   carry a model id, each shard's executor resolves per-model state
//!   (weights *and* policy) through the store and re-checks generations
//!   so a republish hot-swaps without dropping in-flight requests —
//!   with the `.arbf` decode on a per-shard prefetch thread, off the
//!   request path. Shard placement is runtime-only (rendezvous hashing
//!   on the id): nothing about sharding is persisted in the format,
//!   and [`ModelStore::warm_where`] lets each shard pre-decode just
//!   the tenants it owns.
//! * [`mapfile`] — format-v2 zero-copy backing: a read-only
//!   `mmap(2)` of the bundle file (aligned-heap fallback elsewhere)
//!   whose 64-byte-aligned payloads the quantized tensors serve as
//!   borrowed views, so a v2 hot-swap is O(header) instead of
//!   O(payload). The only `unsafe` in the registry lives there; the
//!   codec/store modules each carry `#![forbid(unsafe_code)]`.

pub mod binfmt;
pub mod mapfile;
pub mod quant;
pub mod store;

/// Identifier a serving request uses to name a model. Cheap to clone;
/// compared by content.
pub type ModelId = std::sync::Arc<str>;

pub use binfmt::{
    ArbfHeader, Bundle, FormatVersion, ModelRecord, RffSummary,
};
pub use mapfile::{MapFile, TensorData};
pub use quant::{
    PayloadKind, QuantApproxModel, QuantInfo, QuantSvmModel, TenantModels,
};
pub use store::{
    ModelEntry, ModelStore, PublishOptions, StoreConfig, StoreEntryInfo,
    Substrate,
};

// Policies are defined next to the router that enforces them; re-export
// here because they are published and persisted through the registry.
pub use crate::coordinator::TenantPolicy;
