//! Directory-backed model store: one `<id>.arbf` bundle per model id.
//!
//! * **Atomic publish** — bundles are written to a temp file in the
//!   same directory, fsync'd, then `rename(2)`d over the target, so
//!   readers only ever observe a complete old or complete new file.
//! * **Generation counters** — each publish stamps `previous + 1` into
//!   the file header; generations survive process restarts because
//!   they live in the artifact itself, and [`ModelStore::peek`] reads
//!   them back from the fixed 32-byte header without deserializing
//!   payloads (the serving layer polls this for hot-swap detection).
//! * **Lazy load + LRU cache** — [`ModelStore::load`] decodes a bundle
//!   at most once per generation and shares it behind an `Arc`; the
//!   in-memory cache is bounded, evicting the least-recently-used
//!   entry, so a node can *register* thousands of tenants while only
//!   the hot set stays resident.
//! * **Generation GC + rollback** — each publish archives the replaced
//!   bundle as `<id>.arbf.gen-<k>` and prunes archives beyond
//!   [`StoreConfig::keep_generations`]; [`ModelStore::rollback`]
//!   republishes the newest archive as a fresh generation, so a bad
//!   push reverts through the same hot-swap path as any other publish.
//! * **Warm-on-publish** — [`ModelStore::publish_with`] with
//!   [`PublishOptions::warm`] seeds the decoded-entry cache at publish
//!   time, so a new tenant's first request skips the cold decode.
//! * **Shard-aware warm** — [`ModelStore::warm_where`] pre-decodes the
//!   subset of stored bundles a predicate claims; a sharded
//!   coordinator's lanes use it at startup to each warm only the
//!   tenants rendezvous placement assigns to them.
//! * **Zero-copy v2 serving** — bundles load through a read-only
//!   memory map ([`super::mapfile::MapFile`]); format-v2 payloads are
//!   64-byte aligned in the file, so quantized tensors (and the rff
//!   weight vector) become borrowed views over the mapped bytes and a
//!   load decodes O(header) instead of O(payload). Each view holds an
//!   `Arc` of the backing map, so the mapping lives exactly as long as
//!   the entry. [`PublishOptions::format`] (or the
//!   `APPROXRBF_TEST_FORMAT` environment override) selects the
//!   container format; [`ModelStore::migrate`] re-encodes a stored
//!   bundle across formats losslessly as a new generation.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL;
use crate::approx::{rff, ApproxModel};
use crate::coordinator::TenantPolicy;
use crate::log_warn;
use crate::svm::SvmModel;
use crate::{Error, Result};

use super::binfmt::{self, FormatVersion};
use super::mapfile::MapFile;
use super::quant::{PayloadKind, QuantInfo, TenantModels};
use super::ModelId;

/// File extension used for bundles.
pub const ARBF_EXT: &str = "arbf";

/// Default LRU capacity of the in-memory entry cache.
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

/// Default number of archived previous generations kept per id.
pub const DEFAULT_KEEP_GENERATIONS: usize = 2;

/// Store construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct StoreConfig {
    /// LRU capacity of the decoded-entry cache (≥ 1).
    pub cache_capacity: usize,
    /// How many replaced generations to keep as `<id>.arbf.gen-<k>`
    /// archives (0 disables archiving — and with it, rollback).
    pub keep_generations: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            keep_generations: DEFAULT_KEEP_GENERATIONS,
        }
    }
}

/// Approximation substrate a tenant's fast path is published on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Substrate {
    /// The paper's second-order Maclaurin model (kinds 2/4/5).
    Maclaurin,
    /// Random Fourier features (kind 6): `O(D·d)` evaluation routed by
    /// the stored Monte-Carlo error estimate — the large-γ / high-`d`
    /// regime where the Maclaurin bound collapses.
    Rff,
}

impl Substrate {
    /// Canonical name; [`std::fmt::Display`] delegates here.
    pub fn name(&self) -> &'static str {
        match self {
            Substrate::Maclaurin => "maclaurin",
            Substrate::Rff => "rff",
        }
    }
}

impl std::fmt::Display for Substrate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Substrate {
    type Err = Error;

    fn from_str(s: &str) -> Result<Substrate> {
        match s.to_ascii_lowercase().as_str() {
            "maclaurin" | "mac" => Ok(Substrate::Maclaurin),
            "rff" | "random-features" => Ok(Substrate::Rff),
            other => Err(Error::InvalidArg(format!(
                "unknown substrate '{other}' (maclaurin|rff)"
            ))),
        }
    }
}

/// Publish-time options (see [`ModelStore::publish_with`]).
#[derive(Clone, Debug, Default)]
pub struct PublishOptions {
    /// Per-tenant serving policy persisted as a kind-3 record in the
    /// bundle; the coordinator's executor applies it after the next
    /// (hot-)load.
    pub policy: Option<TenantPolicy>,
    /// Pre-decode the bundle into the store cache so the first request
    /// for this generation skips the cold load.
    pub warm: bool,
    /// Payload precision of the published bundle: `Some(kind)` forces
    /// it; `None` defers to the `APPROXRBF_TEST_QUANT` environment
    /// override (`f16`/`int8`; the CI `tier1-quant` job runs the whole
    /// suite with it set), defaulting to f32. Mirrors how
    /// `APPROXRBF_TEST_SHARDS` drives the default shard count.
    pub quantize: Option<PayloadKind>,
    /// Approximation substrate of the fast path: `Some` forces it;
    /// `None` defers to the `APPROXRBF_TEST_SUBSTRATE` environment
    /// override (`rff`; the CI `tier1-rff` job runs the whole suite
    /// with it set), defaulting to Maclaurin. An explicit quantized
    /// payload implies Maclaurin (rff bundles store f32).
    pub substrate: Option<Substrate>,
    /// Feature count `D` for rff publishes: `Some` pins it; `None`
    /// runs the adaptive ladder
    /// ([`crate::approx::rff::RffModel::fit`]).
    pub rff_features: Option<usize>,
    /// Container format of the published bundle: `Some` forces it;
    /// `None` defers to the `APPROXRBF_TEST_FORMAT` environment
    /// override (`v2`; the CI `tier1-v2` job runs the whole suite with
    /// it set), defaulting to v1. Format v2 lays payloads out
    /// 64-byte-aligned so loads serve them zero-copy from a memory
    /// map; decisions are bit-identical across formats either way.
    pub format: Option<FormatVersion>,
}

/// Default payload precision for publishes that don't pin one: the
/// `APPROXRBF_TEST_QUANT` environment variable when set (logged once),
/// else f32.
fn default_publish_payload() -> PayloadKind {
    let kind = std::env::var("APPROXRBF_TEST_QUANT")
        .ok()
        .and_then(|s| s.parse::<PayloadKind>().ok())
        .unwrap_or(PayloadKind::F32);
    if kind != PayloadKind::F32 {
        static ANNOUNCED: std::sync::Once = std::sync::Once::new();
        ANNOUNCED.call_once(|| {
            log_warn!(
                "registry: APPROXRBF_TEST_QUANT={kind} overrides the \
                 default publish payload (PublishOptions::quantize still \
                 wins)"
            );
        });
    }
    kind
}

/// Default container format for publishes that don't pin one: the
/// `APPROXRBF_TEST_FORMAT` environment variable when set (logged
/// once), else v1.
fn default_publish_format() -> FormatVersion {
    let format = std::env::var("APPROXRBF_TEST_FORMAT")
        .ok()
        .and_then(|s| s.parse::<FormatVersion>().ok())
        .unwrap_or(FormatVersion::V1);
    if format != FormatVersion::V1 {
        static ANNOUNCED: std::sync::Once = std::sync::Once::new();
        ANNOUNCED.call_once(|| {
            log_warn!(
                "registry: APPROXRBF_TEST_FORMAT={format} overrides the \
                 default publish format (PublishOptions::format still \
                 wins)"
            );
        });
    }
    format
}

/// Default substrate for publishes that don't pin one: the
/// `APPROXRBF_TEST_SUBSTRATE` environment variable when set (logged
/// once), else Maclaurin.
fn default_publish_substrate() -> Substrate {
    let substrate = std::env::var("APPROXRBF_TEST_SUBSTRATE")
        .ok()
        .and_then(|s| s.parse::<Substrate>().ok())
        .unwrap_or(Substrate::Maclaurin);
    if substrate != Substrate::Maclaurin {
        static ANNOUNCED: std::sync::Once = std::sync::Once::new();
        ANNOUNCED.call_once(|| {
            log_warn!(
                "registry: APPROXRBF_TEST_SUBSTRATE={substrate} overrides \
                 the default publish substrate (PublishOptions::substrate \
                 still wins)"
            );
        });
    }
    substrate
}

/// A loaded (exact, approx) pair at a specific generation — f32 or
/// native quantized storage, depending on the bundle's payload kind.
/// Shared immutably between the store cache and serving threads.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub id: ModelId,
    pub generation: u64,
    /// The served model pair in its native storage.
    pub models: TenantModels,
    /// Per-tenant serving policy carried by the bundle, if any.
    pub policy: Option<TenantPolicy>,
}

impl ModelEntry {
    /// Feature dimension (exact and approx agree by construction).
    pub fn dim(&self) -> usize {
        self.models.dim()
    }

    /// Payload precision this entry serves at.
    pub fn payload(&self) -> PayloadKind {
        self.models.payload()
    }

    /// The Eq. 3.11 routing budget with quantization drift folded in at
    /// the default tolerance
    /// ([`crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL`]).
    pub fn znorm_sq_budget(&self) -> f32 {
        self.znorm_sq_budget_with(DEFAULT_QUANT_DRIFT_TOL)
    }

    /// The served ‖z‖² budget: the Maclaurin Eq. 3.11 budget
    /// intersected with the largest ‖z‖² whose dequantization drift
    /// bound stays within `quant_drift_tol`
    /// ([`crate::approx::bounds::QuantErrorBound::drift_budget`]).
    /// For f32 entries this is exactly the Eq. 3.11 budget.
    ///
    /// Rff entries have no ‖z‖²-shaped validity region: the stored
    /// Monte-Carlo estimate either clears the tolerance (everything
    /// rides the fast path) or it doesn't (everything escorts to
    /// exact). All-or-nothing is still monotone in the tolerance, so
    /// the policy plane's min-intersection semantics carry over.
    pub fn znorm_sq_budget_with(&self, quant_drift_tol: f32) -> f32 {
        if let Some(rffm) = self.models.rff() {
            return if rffm.err_est <= quant_drift_tol {
                f32::MAX
            } else {
                0.0
            };
        }
        let base = self.models.approx_znorm_sq_budget();
        match self.models.quant_error() {
            None => base,
            Some(q) => base.min(q.drift_budget(quant_drift_tol)),
        }
    }

    /// Quantization error metadata (`None` for f32 entries).
    pub fn quant_info(&self) -> Option<QuantInfo> {
        match (
            self.models.quant_error(),
            self.models.exact_quant_error(),
        ) {
            (Some(approx_err), Some(exact_err)) => Some(QuantInfo {
                payload: self.payload(),
                approx_err,
                exact_err,
            }),
            _ => None,
        }
    }

    /// SV norms of the (dequantized) exact model — cached per
    /// generation by the serving executor.
    pub fn sv_row_norms_sq(&self) -> Vec<f32> {
        self.models.sv_row_norms_sq()
    }

    /// Reference decisions on the entry's native storage (what the
    /// serving executor computes); see [`TenantModels`].
    pub fn approx_decision_one(&self, z: &[f32]) -> f32 {
        self.models.approx_decision_one(z)
    }

    pub fn exact_decision_one(&self, z: &[f32]) -> f32 {
        self.models.exact_decision_one(z)
    }

    /// Dequantized copies (clones for f32 entries).
    pub fn exact_dequant(&self) -> SvmModel {
        self.models.exact_dequant()
    }

    pub fn approx_dequant(&self) -> ApproxModel {
        self.models.approx_dequant()
    }

    /// Approximate resident footprint of the model pair in bytes
    /// (heap + mapped; see [`ModelEntry::heap_bytes`]).
    pub fn resident_bytes(&self) -> usize {
        self.models.resident_bytes()
    }

    /// Bytes of the model pair actually resident on the heap. For a
    /// format-v2 entry served over a memory map the quantized tensors
    /// (and rff weights) are views, so this is just the scalar /
    /// metadata residue — the number the LRU budget and per-model
    /// metrics should charge, where [`ModelEntry::resident_bytes`]
    /// would overcount by the whole payload.
    pub fn heap_bytes(&self) -> usize {
        self.models.heap_bytes()
    }

    /// Bytes served as borrowed views over a mapped bundle file (0 for
    /// heap-decoded entries). `heap_bytes() + mapped_bytes() ==
    /// resident_bytes()` always holds.
    pub fn mapped_bytes(&self) -> usize {
        self.models.mapped_bytes()
    }
}

/// Header-level facts about a stored model (no payload decode).
#[derive(Clone, Debug)]
pub struct StoreEntryInfo {
    pub id: String,
    pub generation: u64,
    pub dim: usize,
    pub n_sv: usize,
    pub size_bytes: u64,
    /// True iff the bundle advertises a per-tenant policy record.
    pub has_policy: bool,
    /// Payload precision advertised by the header flags.
    pub payload: PayloadKind,
    /// True iff the header flags advertise an rff (kind-6) bundle.
    pub has_rff: bool,
    /// Container format stamped in the header (v1 heap-decoded, v2
    /// zero-copy mappable).
    pub format: FormatVersion,
}

struct Cache {
    capacity: usize,
    tick: u64,
    entries: HashMap<String, (u64, Arc<ModelEntry>)>,
}

impl Cache {
    /// Insert (or replace) an entry, evicting the LRU victim when the
    /// id is new and the cache is full.
    fn insert(&mut self, id: &str, entry: Arc<ModelEntry>) {
        self.tick += 1;
        let tick = self.tick;
        if !self.entries.contains_key(id)
            && self.entries.len() >= self.capacity
        {
            if let Some(victim) = self
                .entries
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&victim);
            }
        }
        self.entries.insert(id.to_string(), (tick, entry));
    }
}

/// The registry: a root directory of `.arbf` bundles plus a bounded
/// in-memory cache. Cheap to share behind an `Arc` across coordinators.
pub struct ModelStore {
    root: PathBuf,
    config: StoreConfig,
    cache: Mutex<Cache>,
    publish_lock: Mutex<()>,
    tmp_counter: AtomicU64,
}

impl ModelStore {
    /// Open (creating if needed) a store rooted at `root` with the
    /// default configuration.
    pub fn open(root: impl Into<PathBuf>) -> Result<ModelStore> {
        ModelStore::with_config(root, StoreConfig::default())
    }

    /// Open with an explicit LRU cache capacity (≥ 1).
    pub fn with_capacity(
        root: impl Into<PathBuf>,
        capacity: usize,
    ) -> Result<ModelStore> {
        ModelStore::with_config(
            root,
            StoreConfig { cache_capacity: capacity, ..Default::default() },
        )
    }

    /// Open with full [`StoreConfig`] control.
    pub fn with_config(
        root: impl Into<PathBuf>,
        config: StoreConfig,
    ) -> Result<ModelStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(ModelStore {
            root,
            config,
            cache: Mutex::new(Cache {
                capacity: config.cache_capacity.max(1),
                tick: 0,
                entries: HashMap::new(),
            }),
            publish_lock: Mutex::new(()),
            tmp_counter: AtomicU64::new(0),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Model ids become file names: restrict to a conservative charset.
    pub fn validate_id(id: &str) -> Result<()> {
        let ok = !id.is_empty()
            && id.len() <= 128
            && !id.starts_with('.')
            && id
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || "._-".contains(c));
        if ok {
            Ok(())
        } else {
            Err(Error::InvalidArg(format!(
                "invalid model id '{id}': use 1-128 chars from \
                 [A-Za-z0-9._-], not starting with '.'"
            )))
        }
    }

    fn path_of(&self, id: &str) -> PathBuf {
        self.root.join(format!("{id}.{ARBF_EXT}"))
    }

    fn gen_path_of(&self, id: &str, generation: u64) -> PathBuf {
        self.root
            .join(format!("{id}.{ARBF_EXT}.gen-{generation}"))
    }

    /// Write `bytes` to `<id>.arbf` atomically (tmp file in the same
    /// directory, fsync, rename).
    fn atomic_write(&self, id: &str, bytes: &[u8]) -> Result<()> {
        let path = self.path_of(id);
        let tmp = self.root.join(format!(
            "{id}.{ARBF_EXT}.tmp.{}.{}",
            std::process::id(),
            self.tmp_counter.fetch_add(1, Ordering::Relaxed)
        ));
        let write = (|| -> Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
            Ok(())
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e.into());
        }
        Ok(())
    }

    /// Archive the current bundle of `id` (generation `generation`) as
    /// `<id>.arbf.gen-<generation>` and prune archives beyond
    /// `keep_generations`. A copy (not a rename) so the live bundle is
    /// never absent. Best-effort: archival failure never blocks a
    /// publish.
    fn archive_current(&self, id: &str, generation: u64) {
        if self.config.keep_generations == 0 {
            return;
        }
        let dst = self.gen_path_of(id, generation);
        if let Err(e) = std::fs::copy(self.path_of(id), &dst) {
            log_warn!(
                "registry: could not archive '{id}' generation \
                 {generation}: {e}"
            );
            return;
        }
        match self.archived_generations(id) {
            Ok(gens) => {
                let keep = self.config.keep_generations;
                if gens.len() > keep {
                    for &g in &gens[..gens.len() - keep] {
                        let _ = std::fs::remove_file(self.gen_path_of(id, g));
                    }
                }
            }
            Err(e) => log_warn!(
                "registry: could not prune archives for '{id}': {e}"
            ),
        }
    }

    /// One directory pass counting archived generations per model id
    /// (the `registry list` CLI uses this instead of calling
    /// [`ModelStore::archived_generations`] per id, which would rescan
    /// the directory once per tenant).
    pub fn archived_counts(&self) -> Result<HashMap<String, usize>> {
        let marker = format!(".{ARBF_EXT}.gen-");
        let mut out: HashMap<String, usize> = HashMap::new();
        for dirent in std::fs::read_dir(&self.root)? {
            let path = dirent?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some((id, tail)) = name.split_once(marker.as_str()) else {
                continue;
            };
            if tail.parse::<u64>().is_ok() && Self::validate_id(id).is_ok() {
                *out.entry(id.to_string()).or_insert(0) += 1;
            }
        }
        Ok(out)
    }

    /// Archived (replaced) generation numbers for `id`, ascending.
    pub fn archived_generations(&self, id: &str) -> Result<Vec<u64>> {
        Self::validate_id(id)?;
        let prefix = format!("{id}.{ARBF_EXT}.gen-");
        let mut out = Vec::new();
        for dirent in std::fs::read_dir(&self.root)? {
            let path = dirent?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if let Some(tail) = name.strip_prefix(&prefix) {
                if let Ok(g) = tail.parse::<u64>() {
                    out.push(g);
                }
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Atomically publish a new generation of `id`. Returns the
    /// generation number the bundle was stamped with (previous + 1, or
    /// 1 for a new id). Readers holding the old generation keep it; the
    /// next [`ModelStore::load`] observes the new one. The replaced
    /// bundle is archived for [`ModelStore::rollback`].
    pub fn publish(
        &self,
        id: &str,
        exact: &SvmModel,
        approx: &ApproxModel,
    ) -> Result<u64> {
        self.publish_with(id, exact, approx, PublishOptions::default())
    }

    /// [`ModelStore::publish`] with a per-tenant [`TenantPolicy`] and/or
    /// cache warming (see [`PublishOptions`]).
    pub fn publish_with(
        &self,
        id: &str,
        exact: &SvmModel,
        approx: &ApproxModel,
        opts: PublishOptions,
    ) -> Result<u64> {
        Self::validate_id(id)?;
        // Serialize publishers so read-increment-write of the
        // generation counter is atomic within this process.
        let _publishing = self.publish_lock.lock().unwrap();
        let path = self.path_of(id);
        let mut replaced = None;
        let generation = if path.exists() {
            match self.peek(id) {
                Ok(info) => {
                    // Submit-side dimension checks are cached per id, so
                    // a republish must keep the feature space stable; a
                    // dim change needs an explicit remove() first.
                    if info.dim != exact.dim() {
                        return Err(Error::InvalidArg(format!(
                            "refusing to republish '{id}' with dim {} \
                             (current generation {} has dim {}); remove() \
                             the model first to change its feature space",
                            exact.dim(),
                            info.generation,
                            info.dim
                        )));
                    }
                    replaced = Some(info.generation);
                    info.generation + 1
                }
                Err(e) => {
                    log_warn!(
                        "registry: replacing unreadable bundle for '{id}' \
                         ({e}); restarting at generation 1"
                    );
                    1
                }
            }
        } else {
            1
        };
        // An explicit quantized payload pins the Maclaurin substrate;
        // otherwise an explicit substrate wins, then the environment
        // defaults (rff bundles always store f32, so the two overrides
        // cannot both apply).
        let substrate = match opts.substrate {
            Some(s) => s,
            None if opts
                .quantize
                .is_some_and(|k| k != PayloadKind::F32) =>
            {
                Substrate::Maclaurin
            }
            None => default_publish_substrate(),
        };
        let format = opts.format.unwrap_or_else(default_publish_format);
        let (payload, bytes) = match substrate {
            Substrate::Rff => {
                if let Some(kind) = opts.quantize {
                    if kind != PayloadKind::F32 {
                        return Err(Error::InvalidArg(format!(
                            "substrate rff stores f32 payloads; drop \
                             quantize={kind} or publish on maclaurin"
                        )));
                    }
                }
                let rffm = rff::RffModel::fit(
                    exact,
                    opts.rff_features,
                    rff::seed_for_id(id),
                )?;
                let bytes = binfmt::encode_bundle_rff_at(
                    generation,
                    exact,
                    approx,
                    &rffm,
                    opts.policy.as_ref(),
                    format,
                )?;
                (PayloadKind::F32, bytes)
            }
            Substrate::Maclaurin => {
                let payload =
                    opts.quantize.unwrap_or_else(default_publish_payload);
                let bytes = binfmt::encode_bundle_quantized_at(
                    generation,
                    exact,
                    approx,
                    opts.policy.as_ref(),
                    payload,
                    format,
                )?;
                (payload, bytes)
            }
        };
        if let Some(old) = replaced {
            self.archive_current(id, old);
        }
        self.atomic_write(id, &bytes)?;
        // Invalidate so the next load picks the new generation up —
        // or, when warming, seed the cache. An f32 Maclaurin warm seeds
        // the state already in memory (no decode, no disk read on first
        // request); a quantized or rff warm decodes the file just
        // renamed into place through the same mapped path load() takes,
        // so the warmed entry is exactly what any other lane loads from
        // disk — bit-identical decisions *and* the same borrowed-vs-heap
        // storage (sharded planes must stay decision-identical).
        let mut cache = self.cache.lock().unwrap();
        cache.entries.remove(id);
        if opts.warm {
            let models = if substrate == Substrate::Maclaurin
                && payload == PayloadKind::F32
            {
                TenantModels::F32 {
                    exact: exact.clone(),
                    approx: approx.clone(),
                }
            } else {
                let map = MapFile::open(&self.path_of(id))?;
                binfmt::decode_bundle_mapped(&map)?.models
            };
            let entry = Arc::new(ModelEntry {
                id: Arc::from(id),
                generation,
                models,
                policy: opts.policy,
            });
            cache.insert(id, entry);
        }
        Ok(generation)
    }

    /// Roll `id` back to its most recently archived generation: the
    /// archive's models and policy are republished as a *new*
    /// generation (current + 1), so serving nodes pick the revert up
    /// through the ordinary hot-swap path and generation numbers stay
    /// monotone. Returns the new generation number.
    pub fn rollback(&self, id: &str) -> Result<u64> {
        Self::validate_id(id)?;
        let _publishing = self.publish_lock.lock().unwrap();
        let current = self.peek(id)?;
        let archived = self.archived_generations(id)?;
        let Some(&source) = archived.last() else {
            return Err(Error::InvalidArg(format!(
                "no archived generations for '{id}' (keep_generations \
                 is {}; nothing to roll back to)",
                self.config.keep_generations
            )));
        };
        let bytes = std::fs::read(self.gen_path_of(id, source))?;
        let bundle = binfmt::decode_bundle_full(&bytes)?;
        if bundle.models.dim() != current.dim {
            return Err(Error::InvalidArg(format!(
                "archived generation {source} of '{id}' has dim {} but \
                 the current generation serves dim {}; refusing rollback",
                bundle.models.dim(),
                current.dim
            )));
        }
        let generation = current.generation + 1;
        // Native re-encode at the archive's own container format: an
        // archived quantized bundle reverts with its stored q-values
        // and scales verbatim — no requantization, no double
        // quantization error — and a v2 archive reverts to a v2 file.
        let out = binfmt::encode_bundle_native_at(
            generation,
            &bundle.models,
            bundle.policy.as_ref(),
            bundle.format,
        )?;
        self.archive_current(id, current.generation);
        self.atomic_write(id, &out)?;
        self.cache.lock().unwrap().entries.remove(id);
        Ok(generation)
    }

    /// Re-encode the current generation of `id` at container format
    /// `to`, published as a *new* generation through the ordinary
    /// archive + hot-swap path. The models are carried in their native
    /// storage (stored q-values and scales verbatim), so decisions are
    /// bit-identical across the migration in both directions. A no-op
    /// (returning the current generation) when the bundle is already at
    /// `to`.
    pub fn migrate(&self, id: &str, to: FormatVersion) -> Result<u64> {
        Self::validate_id(id)?;
        let _publishing = self.publish_lock.lock().unwrap();
        let current = self.peek(id)?;
        if current.format == to {
            return Ok(current.generation);
        }
        let bytes = std::fs::read(self.path_of(id))?;
        let bundle = binfmt::decode_bundle_full(&bytes)?;
        let generation = current.generation + 1;
        let out = binfmt::encode_bundle_native_at(
            generation,
            &bundle.models,
            bundle.policy.as_ref(),
            to,
        )?;
        self.archive_current(id, current.generation);
        self.atomic_write(id, &out)?;
        self.cache.lock().unwrap().entries.remove(id);
        Ok(generation)
    }

    /// Read header facts for `id` without decoding payloads. This is
    /// the hot-swap poll: ~32 bytes of I/O.
    pub fn peek(&self, id: &str) -> Result<StoreEntryInfo> {
        Self::validate_id(id)?;
        let path = self.path_of(id);
        let bytes = read_prefix(&path, binfmt::FILE_HEADER_LEN)
            .map_err(|e| not_found_to_invalid(e, id))?;
        let size_bytes = std::fs::metadata(&path)?.len();
        let hdr = binfmt::peek_header(&bytes)?;
        Ok(StoreEntryInfo {
            id: id.to_string(),
            generation: hdr.generation,
            dim: hdr.dim as usize,
            n_sv: hdr.n_sv as usize,
            size_bytes,
            has_policy: hdr.has_policy(),
            payload: hdr.payload(),
            has_rff: hdr.has_rff(),
            format: hdr.format(),
        })
    }

    /// Load (lazily) the current generation of `id`. Revalidates the
    /// on-disk generation against the cache, so a republished bundle is
    /// picked up; otherwise this is a pure in-memory hit.
    pub fn load(&self, id: &str) -> Result<Arc<ModelEntry>> {
        let info = self.peek(id)?;
        {
            let mut g = self.cache.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(slot) = g.entries.get_mut(id) {
                if slot.1.generation == info.generation {
                    slot.0 = tick;
                    return Ok(slot.1.clone());
                }
            }
        }
        // Decode outside the lock: large bundles should not serialize
        // unrelated tenants' cache hits. The map (not a read) is the
        // zero-copy seam: a v2 bundle's tensors come back as views over
        // it, each holding its own `Arc` of the mapping, so the backing
        // stays alive exactly as long as the entry; v1 bundles decode
        // onto the heap from the same bytes and the map drops here.
        let map = MapFile::open(&self.path_of(id))
            .map_err(|e| not_found_to_invalid(e, id))?;
        let bundle = binfmt::decode_bundle_mapped(&map)?;
        let entry = Arc::new(ModelEntry {
            id: Arc::from(id),
            generation: bundle.generation,
            models: bundle.models,
            policy: bundle.policy,
        });
        self.cache.lock().unwrap().insert(id, entry.clone());
        Ok(entry)
    }

    /// Enumerate stored models (header facts only), sorted by id.
    pub fn list(&self) -> Result<Vec<StoreEntryInfo>> {
        let mut out = Vec::new();
        for dirent in std::fs::read_dir(&self.root)? {
            let path = dirent?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some(id) = name.strip_suffix(&format!(".{ARBF_EXT}")) else {
                continue;
            };
            if Self::validate_id(id).is_err() {
                continue; // tmp files and strays
            }
            match self.peek(id) {
                Ok(info) => out.push(info),
                Err(e) => log_warn!("registry: skipping '{id}': {e}"),
            }
        }
        out.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(out)
    }

    /// Remove a model's bundle (and its archived generations) and drop
    /// it from the cache.
    pub fn remove(&self, id: &str) -> Result<()> {
        Self::validate_id(id)?;
        std::fs::remove_file(self.path_of(id))
            .map_err(|e| not_found_to_invalid(e.into(), id))?;
        if let Ok(gens) = self.archived_generations(id) {
            for g in gens {
                let _ = std::fs::remove_file(self.gen_path_of(id, g));
            }
        }
        self.cache.lock().unwrap().entries.remove(id);
        Ok(())
    }

    /// Pre-decode every stored bundle whose id satisfies `owned`,
    /// seeding the entry cache; returns how many were warmed. This is
    /// the shard-aware warm path: each shard of a sharded coordinator
    /// warms only the tenants rendezvous placement assigns to it, so
    /// `n` shards starting in parallel decode the registry once
    /// between them instead of `n` times over. Warming stops once the
    /// cache is full — decoding past capacity would only evict entries
    /// another shard just warmed — and logs how many ids were skipped.
    /// Unreadable bundles are skipped (they fail on first request
    /// instead).
    pub fn warm_where(
        &self,
        owned: impl Fn(&str) -> bool,
    ) -> Result<usize> {
        let capacity = self.config.cache_capacity.max(1);
        let mut warmed = 0usize;
        let mut skipped = 0usize;
        for info in self.list()? {
            if !owned(&info.id) {
                continue;
            }
            if self.cached_count() >= capacity {
                skipped += 1;
                continue;
            }
            match self.load(&info.id) {
                Ok(_) => warmed += 1,
                Err(e) => {
                    log_warn!("registry: warm skipped '{}': {e}", info.id)
                }
            }
        }
        if skipped > 0 {
            log_warn!(
                "registry: warm stopped at cache capacity {capacity}; \
                 {skipped} owned bundle(s) stay cold (raise \
                 StoreConfig::cache_capacity to warm them)"
            );
        }
        Ok(warmed)
    }

    /// Number of entries currently resident in the cache (tests).
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().entries.len()
    }
}

fn not_found_to_invalid(e: Error, id: &str) -> Error {
    match e {
        Error::Io(ref io) if io.kind() == std::io::ErrorKind::NotFound => {
            Error::InvalidArg(format!("model '{id}' not found in registry"))
        }
        other => other,
    }
}

fn read_prefix(path: &Path, n: usize) -> Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut buf = vec![0u8; n];
    let mut read = 0;
    while read < n {
        match f.read(&mut buf[read..])? {
            0 => break,
            k => read += k,
        }
    }
    buf.truncate(read);
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::svm::Kernel;

    fn pair(seed: f32) -> (SvmModel, ApproxModel) {
        let exact = SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            Mat::from_vec(2, 2, vec![1., seed, 0., 2.]).unwrap(),
            vec![0.5, -1.0],
            0.1,
        )
        .unwrap();
        let approx = ApproxModel {
            gamma: 0.25,
            b: 0.1,
            c: seed,
            v: vec![1.0, -2.0],
            m: Mat::from_vec(2, 2, vec![0.5, 0.25, 0.25, -0.75]).unwrap(),
            max_sv_norm_sq: 4.0,
        };
        (exact, approx)
    }

    fn temp_store(tag: &str) -> ModelStore {
        let dir = std::env::temp_dir()
            .join(format!("approxrbf_store_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        ModelStore::open(dir).unwrap()
    }

    #[test]
    fn publish_bumps_generation_and_load_follows() {
        let store = temp_store("gen");
        let (e, a) = pair(1.0);
        assert_eq!(store.publish("alpha", &e, &a).unwrap(), 1);
        let first = store.load("alpha").unwrap();
        assert_eq!(first.generation, 1);
        assert_eq!(first.dim(), 2);
        let (e2, a2) = pair(2.0);
        assert_eq!(store.publish("alpha", &e2, &a2).unwrap(), 2);
        let second = store.load("alpha").unwrap();
        assert_eq!(second.generation, 2);
        assert_eq!(second.approx_dequant().c, 2.0);
        // The old Arc is still intact (in-flight readers keep serving).
        assert_eq!(first.approx_dequant().c, 1.0);
    }

    #[test]
    fn load_is_cached_until_republish() {
        let store = temp_store("cache");
        let (e, a) = pair(1.0);
        store.publish("m", &e, &a).unwrap();
        let x = store.load("m").unwrap();
        let y = store.load("m").unwrap();
        assert!(Arc::ptr_eq(&x, &y));
        store.publish("m", &e, &a).unwrap();
        let z = store.load("m").unwrap();
        assert!(!Arc::ptr_eq(&x, &z));
        assert_eq!(z.generation, 2);
    }

    #[test]
    fn lru_cache_is_bounded() {
        let dir = std::env::temp_dir().join(format!(
            "approxrbf_store_test_lru_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ModelStore::with_capacity(dir, 2).unwrap();
        let (e, a) = pair(1.0);
        for id in ["a", "b", "c", "d"] {
            store.publish(id, &e, &a).unwrap();
            store.load(id).unwrap();
        }
        assert!(store.cached_count() <= 2);
        // Evicted entries still load (from disk).
        assert_eq!(store.load("a").unwrap().generation, 1);
    }

    #[test]
    fn list_and_remove() {
        let store = temp_store("list");
        let (e, a) = pair(1.0);
        store.publish("beta", &e, &a).unwrap();
        store.publish("alpha", &e, &a).unwrap();
        let infos = store.list().unwrap();
        assert_eq!(
            infos.iter().map(|i| i.id.as_str()).collect::<Vec<_>>(),
            vec!["alpha", "beta"]
        );
        assert!(infos.iter().all(|i| i.n_sv == 2 && i.dim == 2));
        store.remove("alpha").unwrap();
        assert_eq!(store.list().unwrap().len(), 1);
        assert!(store.load("alpha").is_err());
    }

    #[test]
    fn dim_change_requires_remove() {
        let store = temp_store("dimchange");
        let (e2, a2) = pair(1.0);
        store.publish("m", &e2, &a2).unwrap();
        // A 3-dim republish under the same id must be refused…
        let e3 = SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            Mat::from_vec(1, 3, vec![1., 0., 2.]).unwrap(),
            vec![0.5],
            0.1,
        )
        .unwrap();
        let a3 = ApproxModel {
            gamma: 0.25,
            b: 0.1,
            c: 0.0,
            v: vec![1.0, -2.0, 0.5],
            m: Mat::zeros(3, 3),
            max_sv_norm_sq: 4.0,
        };
        assert!(matches!(
            store.publish("m", &e3, &a3),
            Err(Error::InvalidArg(_))
        ));
        // …but allowed after an explicit remove.
        store.remove("m").unwrap();
        assert_eq!(store.publish("m", &e3, &a3).unwrap(), 1);
        assert_eq!(store.peek("m").unwrap().dim, 3);
    }

    #[test]
    fn bad_ids_rejected() {
        let store = temp_store("ids");
        let (e, a) = pair(1.0);
        let too_long = "x".repeat(200);
        for id in ["", "a/b", "..", ".hidden", "sp ace", too_long.as_str()] {
            assert!(store.publish(id, &e, &a).is_err(), "id '{id}'");
        }
    }

    #[test]
    fn peek_reports_without_decoding() {
        let store = temp_store("peek");
        let (e, a) = pair(1.0);
        store.publish("m", &e, &a).unwrap();
        let info = store.peek("m").unwrap();
        assert_eq!(info.generation, 1);
        assert_eq!(info.dim, 2);
        assert!(info.size_bytes > binfmt::FILE_HEADER_LEN as u64);
        assert_eq!(store.cached_count(), 0, "peek must not populate cache");
    }

    #[test]
    fn missing_model_is_invalid_arg() {
        let store = temp_store("missing");
        assert!(matches!(
            store.load("ghost"),
            Err(Error::InvalidArg(_))
        ));
    }

    #[test]
    fn publish_archives_previous_generations_and_prunes() {
        let store = temp_store("gc");
        // Default keep_generations = 2.
        for seed in 1..=4 {
            let (e, a) = pair(seed as f32);
            assert_eq!(store.publish("m", &e, &a).unwrap(), seed);
        }
        // Generations 1..=3 were replaced; only the last 2 survive.
        assert_eq!(store.archived_generations("m").unwrap(), vec![2, 3]);
        assert_eq!(store.archived_counts().unwrap().get("m"), Some(&2));
        assert_eq!(store.peek("m").unwrap().generation, 4);
        // Archives never leak into list().
        let infos = store.list().unwrap();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].generation, 4);
    }

    #[test]
    fn keep_generations_zero_disables_archiving() {
        let dir = std::env::temp_dir().join(format!(
            "approxrbf_store_test_nogc_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ModelStore::with_config(
            dir,
            StoreConfig { keep_generations: 0, ..Default::default() },
        )
        .unwrap();
        let (e, a) = pair(1.0);
        store.publish("m", &e, &a).unwrap();
        store.publish("m", &e, &a).unwrap();
        assert!(store.archived_generations("m").unwrap().is_empty());
        assert!(matches!(store.rollback("m"), Err(Error::InvalidArg(_))));
    }

    #[test]
    fn rollback_restores_previous_models_as_new_generation() {
        let store = temp_store("rollback");
        let (e1, a1) = pair(1.0);
        let (e2, a2) = pair(2.0);
        store.publish("m", &e1, &a1).unwrap();
        store.publish("m", &e2, &a2).unwrap();
        assert_eq!(store.load("m").unwrap().approx_dequant().c, 2.0);
        // Roll back: generation moves FORWARD (2 → 3) but the payload
        // is generation 1's.
        assert_eq!(store.rollback("m").unwrap(), 3);
        let entry = store.load("m").unwrap();
        assert_eq!(entry.generation, 3);
        assert_eq!(entry.approx_dequant().c, 1.0);
        // Rolling back again reverts the revert (gen 2's payload).
        assert_eq!(store.rollback("m").unwrap(), 4);
        assert_eq!(store.load("m").unwrap().approx_dequant().c, 2.0);
    }

    #[test]
    fn rollback_without_history_is_invalid_arg() {
        let store = temp_store("rollback_empty");
        let (e, a) = pair(1.0);
        store.publish("solo", &e, &a).unwrap();
        assert!(matches!(
            store.rollback("solo"),
            Err(Error::InvalidArg(_))
        ));
        assert!(store.rollback("ghost").is_err());
    }

    #[test]
    fn warm_where_decodes_only_owned_ids() {
        let store = temp_store("warmwhere");
        let (e, a) = pair(1.0);
        for id in ["a0", "a1", "b0", "b1"] {
            store.publish(id, &e, &a).unwrap();
        }
        assert_eq!(store.cached_count(), 0);
        let warmed = store.warm_where(|id| id.starts_with('a')).unwrap();
        assert_eq!(warmed, 2);
        assert_eq!(store.cached_count(), 2);
        // Warmed entries are in-memory hits (same Arc on load).
        let x = store.load("a0").unwrap();
        let y = store.load("a0").unwrap();
        assert!(Arc::ptr_eq(&x, &y));
    }

    #[test]
    fn warm_publish_seeds_cache() {
        let store = temp_store("warm");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "hot",
                &e,
                &a,
                PublishOptions { warm: true, ..Default::default() },
            )
            .unwrap();
        assert_eq!(store.cached_count(), 1, "warm publish must pre-seed");
        // The warmed entry is the one load() hands out (same Arc).
        let x = store.load("hot").unwrap();
        let y = store.load("hot").unwrap();
        assert!(Arc::ptr_eq(&x, &y));
        assert_eq!(x.generation, 1);
        // Cold publish does not seed.
        store.publish("cold", &e, &a).unwrap();
        assert_eq!(store.cached_count(), 1);
    }

    #[test]
    fn quantized_publish_roundtrips_and_reports_payload() {
        let store = temp_store("quant");
        let (e, a) = pair(1.0);
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let id = format!("q-{kind}");
            store
                .publish_with(
                    &id,
                    &e,
                    &a,
                    PublishOptions {
                        quantize: Some(kind),
                        ..Default::default()
                    },
                )
                .unwrap();
            let info = store.peek(&id).unwrap();
            assert_eq!(info.payload, kind);
            assert_eq!(info.dim, 2);
            assert_eq!(info.n_sv, 2);
            let entry = store.load(&id).unwrap();
            assert_eq!(entry.payload(), kind);
            // Scalars survive exactly; tensors within advertised eps.
            let deq = entry.approx_dequant();
            assert_eq!(deq.c, a.c);
            assert_eq!(deq.gamma, a.gamma);
            let q = entry.quant_info().expect("quantized entry");
            assert_eq!(q.payload, kind);
            assert!(deq.m.max_abs_diff(&a.m) <= q.approx_err.eps_m);
            // The folded budget never exceeds the raw Eq. 3.11 budget.
            assert!(entry.znorm_sq_budget() <= a.znorm_sq_budget());
            // Quantized resident footprint shrinks vs the f32 twin.
            store
                .publish_with(
                    "f32-twin",
                    &e,
                    &a,
                    PublishOptions {
                        quantize: Some(PayloadKind::F32),
                        ..Default::default()
                    },
                )
                .unwrap();
            let f32_entry = store.load("f32-twin").unwrap();
            assert!(f32_entry.quant_info().is_none());
            assert!(
                entry.resident_bytes() < f32_entry.resident_bytes(),
                "{kind}"
            );
        }
    }

    #[test]
    fn quantized_warm_publish_seeds_the_decoded_entry() {
        let store = temp_store("quantwarm");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "hot",
                &e,
                &a,
                PublishOptions {
                    warm: true,
                    quantize: Some(PayloadKind::Int8),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(store.cached_count(), 1);
        let warmed = store.load("hot").unwrap();
        // The warmed entry is the decoded quantized state — identical
        // to what a cold lane reads from disk — not the f32 originals.
        assert_eq!(warmed.payload(), PayloadKind::Int8);
        let fresh = ModelStore::open(store.root()).unwrap();
        let cold = fresh.load("hot").unwrap();
        assert_eq!(
            warmed.approx_decision_one(&[0.3, -0.7]).to_bits(),
            cold.approx_decision_one(&[0.3, -0.7]).to_bits()
        );
    }

    #[test]
    fn rollback_of_quantized_bundle_is_lossless() {
        let store = temp_store("quantrollback");
        let (e1, a1) = pair(1.0);
        let (e2, a2) = pair(2.0);
        store
            .publish_with(
                "m",
                &e1,
                &a1,
                PublishOptions {
                    quantize: Some(PayloadKind::Int8),
                    ..Default::default()
                },
            )
            .unwrap();
        let gen1 = store.load("m").unwrap();
        store.publish("m", &e2, &a2).unwrap();
        assert_eq!(store.rollback("m").unwrap(), 3);
        let entry = store.load("m").unwrap();
        assert_eq!(entry.generation, 3);
        assert_eq!(entry.payload(), PayloadKind::Int8);
        // Bit-identical decisions to the original quantized generation:
        // the rollback re-encoded stored q-values, never requantized.
        let z = [0.25f32, -0.5];
        assert_eq!(
            entry.approx_decision_one(&z).to_bits(),
            gen1.approx_decision_one(&z).to_bits()
        );
        assert_eq!(
            entry.exact_decision_one(&z).to_bits(),
            gen1.exact_decision_one(&z).to_bits()
        );
    }

    #[test]
    fn policy_roundtrips_through_publish_and_load() {
        let store = temp_store("policy");
        let (e, a) = pair(1.0);
        let policy = TenantPolicy {
            route: Some(crate::coordinator::RoutePolicy::AlwaysExact),
            max_batch: Some(16),
            max_wait: Some(std::time::Duration::from_micros(300)),
            max_resident_hint: 2,
            quant_drift_tol: Some(0.125),
        };
        store
            .publish_with(
                "p",
                &e,
                &a,
                PublishOptions {
                    policy: Some(policy),
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(store.peek("p").unwrap().has_policy);
        assert_eq!(store.load("p").unwrap().policy, Some(policy));
        // Republishing without a policy clears it (policy travels with
        // the bundle).
        store.publish("p", &e, &a).unwrap();
        assert!(!store.peek("p").unwrap().has_policy);
        assert_eq!(store.load("p").unwrap().policy, None);
    }

    #[test]
    fn substrate_parse_roundtrip() {
        for s in [Substrate::Maclaurin, Substrate::Rff] {
            assert_eq!(s.to_string().parse::<Substrate>().unwrap(), s);
        }
        assert_eq!("MAC".parse::<Substrate>().unwrap(), Substrate::Maclaurin);
        assert!("fastfood9".parse::<Substrate>().is_err());
    }

    #[test]
    fn rff_publish_roundtrips_and_gates_by_estimate() {
        let store = temp_store("rff");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "r",
                &e,
                &a,
                PublishOptions {
                    substrate: Some(Substrate::Rff),
                    rff_features: Some(64),
                    ..Default::default()
                },
            )
            .unwrap();
        let info = store.peek("r").unwrap();
        assert!(info.has_rff);
        assert_eq!(info.payload, PayloadKind::F32);
        let entry = store.load("r").unwrap();
        let rffm = entry.models.rff().expect("rff models");
        assert_eq!(rffm.n_features(), 64);
        // The publish path derives the map's seed from the id, so a
        // locally fitted twin is bit-identical.
        let twin =
            rff::RffModel::fit(&e, Some(64), rff::seed_for_id("r")).unwrap();
        assert_eq!(rffm.seed, twin.seed);
        let z = [0.3f32, -0.4];
        assert_eq!(
            entry.approx_decision_one(&z).to_bits(),
            twin.decision_one(&z).0.to_bits()
        );
        // All-or-nothing serving gate on the stored estimate.
        assert_eq!(entry.znorm_sq_budget_with(rffm.err_est), f32::MAX);
        assert_eq!(entry.znorm_sq_budget_with(0.0), 0.0);
        // The Maclaurin twin rides along for tooling/rollback paths.
        assert_eq!(entry.approx_dequant().c, a.c);
        // A plain publish does not advertise rff.
        store.publish("plain", &e, &a).unwrap();
        assert!(!store.peek("plain").unwrap().has_rff);
    }

    #[test]
    fn rff_substrate_refuses_quantized_payloads() {
        let store = temp_store("rffquant");
        let (e, a) = pair(1.0);
        let err = store
            .publish_with(
                "r",
                &e,
                &a,
                PublishOptions {
                    substrate: Some(Substrate::Rff),
                    quantize: Some(PayloadKind::Int8),
                    ..Default::default()
                },
            )
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
        // An explicit quantized payload with no explicit substrate is
        // simply a Maclaurin publish.
        store
            .publish_with(
                "q",
                &e,
                &a,
                PublishOptions {
                    quantize: Some(PayloadKind::Int8),
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(!store.peek("q").unwrap().has_rff);
    }

    #[test]
    fn rff_warm_publish_seeds_the_decoded_entry() {
        let store = temp_store("rffwarm");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "hot",
                &e,
                &a,
                PublishOptions {
                    warm: true,
                    substrate: Some(Substrate::Rff),
                    rff_features: Some(64),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(store.cached_count(), 1);
        let warmed = store.load("hot").unwrap();
        assert!(warmed.models.rff().is_some());
        // A cold lane regenerates W and b from the stored seed and must
        // land on bit-identical decisions (sharded planes rely on it).
        let fresh = ModelStore::open(store.root()).unwrap();
        let cold = fresh.load("hot").unwrap();
        for z in [[0.3f32, -0.7], [1.5, 0.25], [0.0, 0.0]] {
            assert_eq!(
                warmed.approx_decision_one(&z).to_bits(),
                cold.approx_decision_one(&z).to_bits()
            );
            assert_eq!(
                warmed.exact_decision_one(&z).to_bits(),
                cold.exact_decision_one(&z).to_bits()
            );
        }
    }

    #[test]
    fn v2_publish_loads_bit_identical_and_borrowed() {
        let store = temp_store("v2");
        let (e, a) = pair(1.0);
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let v1_id = format!("v1-{kind}");
            let v2_id = format!("v2-{kind}");
            for (id, format) in
                [(&v1_id, FormatVersion::V1), (&v2_id, FormatVersion::V2)]
            {
                store
                    .publish_with(
                        id,
                        &e,
                        &a,
                        PublishOptions {
                            quantize: Some(kind),
                            format: Some(format),
                            ..Default::default()
                        },
                    )
                    .unwrap();
                assert_eq!(store.peek(id).unwrap().format, format);
            }
            let h = store.load(&v1_id).unwrap();
            let m = store.load(&v2_id).unwrap();
            // v1 always decodes to the heap; v2 serves its tensors as
            // views over the map (little-endian hosts — elsewhere the
            // decoder falls back to the heap and stays bit-identical).
            assert_eq!(h.mapped_bytes(), 0, "{kind}");
            if cfg!(target_endian = "little") {
                assert!(m.mapped_bytes() > 0, "{kind}");
                assert!(m.heap_bytes() < h.heap_bytes(), "{kind}");
            }
            assert_eq!(
                m.heap_bytes() + m.mapped_bytes(),
                m.resident_bytes()
            );
            for z in [[0.3f32, -0.7], [1.5, 0.25], [0.0, 0.0]] {
                assert_eq!(
                    m.approx_decision_one(&z).to_bits(),
                    h.approx_decision_one(&z).to_bits(),
                    "{kind}"
                );
                assert_eq!(
                    m.exact_decision_one(&z).to_bits(),
                    h.exact_decision_one(&z).to_bits(),
                    "{kind}"
                );
            }
        }
        // f32 payloads serve from the heap even in a v2 container.
        store
            .publish_with(
                "f32-v2",
                &e,
                &a,
                PublishOptions {
                    quantize: Some(PayloadKind::F32),
                    substrate: Some(Substrate::Maclaurin),
                    format: Some(FormatVersion::V2),
                    ..Default::default()
                },
            )
            .unwrap();
        let info = store.peek("f32-v2").unwrap();
        assert_eq!(info.format, FormatVersion::V2);
        assert_eq!(info.payload, PayloadKind::F32);
        let entry = store.load("f32-v2").unwrap();
        assert_eq!(entry.mapped_bytes(), 0);
        assert_eq!(entry.approx_dequant().c, a.c);
    }

    #[test]
    fn rff_v2_republish_serves_mapped_weights_bit_identically() {
        let store = temp_store("rffv2");
        let (e, a) = pair(1.0);
        // Same id across both publishes: the rff map's seed derives
        // from the id, so the two generations carry the same weights
        // and their decisions are comparable bit-for-bit.
        let opts = |format| PublishOptions {
            substrate: Some(Substrate::Rff),
            rff_features: Some(64),
            format: Some(format),
            ..Default::default()
        };
        store
            .publish_with("r", &e, &a, opts(FormatVersion::V1))
            .unwrap();
        assert_eq!(store.peek("r").unwrap().format, FormatVersion::V1);
        let h = store.load("r").unwrap();
        assert_eq!(h.mapped_bytes(), 0);
        store
            .publish_with("r", &e, &a, opts(FormatVersion::V2))
            .unwrap();
        assert_eq!(store.peek("r").unwrap().format, FormatVersion::V2);
        let m = store.load("r").unwrap();
        assert!(m.models.rff().is_some());
        if cfg!(target_endian = "little") {
            assert!(m.mapped_bytes() > 0);
        }
        for z in [[0.3f32, -0.4], [1.0, 2.0], [0.0, 0.0]] {
            assert_eq!(
                m.approx_decision_one(&z).to_bits(),
                h.approx_decision_one(&z).to_bits()
            );
            assert_eq!(
                m.exact_decision_one(&z).to_bits(),
                h.exact_decision_one(&z).to_bits()
            );
        }
    }

    #[test]
    fn migrate_round_trips_bit_identically() {
        let store = temp_store("migrate");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "m",
                &e,
                &a,
                PublishOptions {
                    quantize: Some(PayloadKind::Int8),
                    format: Some(FormatVersion::V1),
                    ..Default::default()
                },
            )
            .unwrap();
        let gen1 = store.load("m").unwrap();
        // Migrating to the format already stored is a no-op.
        assert_eq!(store.migrate("m", FormatVersion::V1).unwrap(), 1);
        assert_eq!(store.peek("m").unwrap().generation, 1);
        // v1 → v2: a new generation, same stored q-values.
        assert_eq!(store.migrate("m", FormatVersion::V2).unwrap(), 2);
        let info = store.peek("m").unwrap();
        assert_eq!(info.generation, 2);
        assert_eq!(info.format, FormatVersion::V2);
        assert_eq!(info.payload, PayloadKind::Int8);
        let v2 = store.load("m").unwrap();
        // …and back. Both hops preserve decisions bit-for-bit.
        assert_eq!(store.migrate("m", FormatVersion::V1).unwrap(), 3);
        assert_eq!(store.peek("m").unwrap().format, FormatVersion::V1);
        let back = store.load("m").unwrap();
        let z = [0.25f32, -0.5];
        for entry in [&v2, &back] {
            assert_eq!(
                entry.approx_decision_one(&z).to_bits(),
                gen1.approx_decision_one(&z).to_bits()
            );
            assert_eq!(
                entry.exact_decision_one(&z).to_bits(),
                gen1.exact_decision_one(&z).to_bits()
            );
        }
        assert!(store.migrate("ghost", FormatVersion::V2).is_err());
    }

    #[test]
    fn rollback_preserves_the_archived_format() {
        let store = temp_store("fmtrollback");
        let (e1, a1) = pair(1.0);
        let (e2, a2) = pair(2.0);
        store
            .publish_with(
                "m",
                &e1,
                &a1,
                PublishOptions {
                    quantize: Some(PayloadKind::F16),
                    format: Some(FormatVersion::V2),
                    ..Default::default()
                },
            )
            .unwrap();
        let gen1 = store.load("m").unwrap();
        store
            .publish_with(
                "m",
                &e2,
                &a2,
                PublishOptions {
                    quantize: Some(PayloadKind::F32),
                    substrate: Some(Substrate::Maclaurin),
                    format: Some(FormatVersion::V1),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(store.peek("m").unwrap().format, FormatVersion::V1);
        // Rolling back republishes the v2 archive as a v2 file.
        assert_eq!(store.rollback("m").unwrap(), 3);
        let info = store.peek("m").unwrap();
        assert_eq!(info.format, FormatVersion::V2);
        assert_eq!(info.payload, PayloadKind::F16);
        let entry = store.load("m").unwrap();
        let z = [0.3f32, 0.6];
        assert_eq!(
            entry.approx_decision_one(&z).to_bits(),
            gen1.approx_decision_one(&z).to_bits()
        );
        assert_eq!(
            entry.exact_decision_one(&z).to_bits(),
            gen1.exact_decision_one(&z).to_bits()
        );
    }

    #[test]
    fn v2_warm_publish_seeds_the_mapped_entry() {
        let store = temp_store("v2warm");
        let (e, a) = pair(1.0);
        store
            .publish_with(
                "hot",
                &e,
                &a,
                PublishOptions {
                    warm: true,
                    quantize: Some(PayloadKind::Int8),
                    format: Some(FormatVersion::V2),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(store.cached_count(), 1);
        let warmed = store.load("hot").unwrap();
        // The warmed entry is borrowed over the published file exactly
        // like a cold lane's load — not a private heap decode.
        if cfg!(target_endian = "little") {
            assert!(warmed.mapped_bytes() > 0);
        }
        let fresh = ModelStore::open(store.root()).unwrap();
        let cold = fresh.load("hot").unwrap();
        assert_eq!(warmed.mapped_bytes(), cold.mapped_bytes());
        for z in [[0.3f32, -0.7], [1.5, 0.25]] {
            assert_eq!(
                warmed.approx_decision_one(&z).to_bits(),
                cold.approx_decision_one(&z).to_bits()
            );
            assert_eq!(
                warmed.exact_decision_one(&z).to_bits(),
                cold.exact_decision_one(&z).to_bits()
            );
        }
    }
}
