//! Memory-mapped backing storage for zero-copy `.arbf` (format v2)
//! serving.
//!
//! A [`MapFile`] owns the bytes of one bundle file — either a real
//! read-only `mmap(2)` of the file (64-bit unix targets) or a portable
//! heap fallback that reads the file into a 64-byte-aligned buffer
//! behind the same API. A [`MapSlice`] is a bounds- and
//! alignment-validated typed window into those bytes, and
//! [`TensorData`] is the storage enum the quantized tensor types hold:
//! `Owned` (the v1 heap-decode path) or `Mapped` (v2 served straight
//! from the file). Because every `MapSlice` holds an `Arc<MapFile>`,
//! the backing map stays alive exactly as long as any tensor view into
//! it — the model store never has to track map lifetimes separately.
//!
//! **This is the only module in the crate with `unsafe` on the serving
//! path.** The unsafe surface is three operations, each with its
//! SAFETY argument inline: the `mmap`/`munmap` FFI pair, the
//! `Send`/`Sync` promotion of the read-only mapping, and the
//! `from_raw_parts` view construction (whose preconditions are
//! enforced by [`MapSlice::new`], the only constructor). The heap
//! fallback allocates with safe code only, so the same view-handout
//! logic is exercised under Miri through [`MapFile::from_bytes`]
//! (`docs/ANALYSIS.md` records why the `mmap` arm itself is
//! `cfg`-excluded from Miri).
//!
//! **SIGBUS exclusion.** Reading a mapping whose file shrinks under it
//! faults. The store's publish discipline makes that unreachable:
//! bundles are only ever replaced by `rename(2)` of a complete temp
//! file ([`super::store::ModelStore`]), never truncated or rewritten
//! in place, so a mapped inode is immutable for the mapping's
//! lifetime — a republish swaps the directory entry while the old
//! inode lives on until the last `Arc<MapFile>` drops.

use std::path::Path;
use std::sync::Arc;

use crate::{Error, Result};

/// Committed payload alignment of `.arbf` format v2: every record
/// payload starts at a multiple of this within the file, so typed
/// views over `u16`/`i8`/`f32` tensors are always well aligned (and
/// cache-line aligned for the quantized GEMV kernels). `mmap`
/// placement is page-aligned (4096), a multiple of this, so in-file
/// alignment carries over to virtual addresses.
pub const PAYLOAD_ALIGN: usize = 64;

/// Cap on a mappable bundle file (1 GiB of payload elements at f32 is
/// the binfmt `MAX_MODEL_ELEMS` cap; 2 GiB of file leaves headroom for
/// framing while keeping a corrupt length from demanding an absurd
/// fallback allocation).
const MAX_MAP_LEN: u64 = 2 << 30;

/// Refuse to map (or heap-read) implausibly large files — the same
/// alloc-bomb discipline the binfmt decoders apply to element counts.
fn check_map_len(len: u64) -> Result<usize> {
    if len > MAX_MAP_LEN {
        return Err(Error::Corrupt(format!(
            "bundle file of {len} bytes exceeds the {MAX_MAP_LEN}-byte \
             map cap"
        )));
    }
    Ok(len as usize)
}

#[cfg(all(unix, target_pointer_width = "64", not(miri)))]
mod sys {
    //! Minimal raw-mmap FFI: the two libc symbols std already links.
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

#[derive(Debug)]
enum Backing {
    /// A live read-only `mmap(2)` of the whole file.
    #[cfg(all(unix, target_pointer_width = "64", not(miri)))]
    Mmap { ptr: *const u8 },
    /// Portable fallback: the file copied into a heap buffer whose
    /// payload start is 64-byte aligned (`off` skips to the first
    /// aligned byte, so views see the same alignment the mmap arm
    /// guarantees).
    Heap { buf: Vec<u8>, off: usize },
}

/// The immutable bytes of one `.arbf` file, mapped or heap-resident.
/// Shared behind an `Arc` by every [`MapSlice`] view into it.
#[derive(Debug)]
pub struct MapFile {
    backing: Backing,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and never mutated or remapped after
// construction; `Backing::Mmap::ptr` is only ever read through
// `bytes()`, and `munmap` runs exactly once, in `Drop`, when no other
// reference can exist. Immutable shared reads from any thread are
// therefore race-free, the same contract `&[u8]` itself has.
unsafe impl Send for MapFile {}
// SAFETY: as above — all access is read-only through `bytes()`.
unsafe impl Sync for MapFile {}

impl MapFile {
    /// Map `path` read-only, falling back to an aligned heap read when
    /// `mmap` is unavailable (non-unix, 32-bit, Miri) or fails. Empty
    /// files are always heap-backed (zero-length mappings are invalid).
    pub fn open(path: &Path) -> Result<Arc<MapFile>> {
        let file = std::fs::File::open(path)?;
        let len = check_map_len(file.metadata()?.len())?;
        #[cfg(all(unix, target_pointer_width = "64", not(miri)))]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            // SAFETY: mmap with a null addr hint, PROT_READ and
            // MAP_PRIVATE over a file descriptor we own is always
            // memory-safe: the kernel either returns a fresh mapping of
            // `len` bytes (valid for reads until the matching munmap in
            // Drop) or MAP_FAILED, which we check. `len > 0` and the
            // fd outliving the call are the only preconditions.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize != -1 {
                return Ok(Arc::new(MapFile {
                    backing: Backing::Mmap { ptr: ptr as *const u8 },
                    len,
                }));
            }
            // mmap refused (e.g. exotic filesystem): fall through to
            // the heap read, which serves identically.
        }
        let mut buf = Vec::new();
        {
            use std::io::Read;
            let mut f = file;
            f.read_to_end(&mut buf)?;
        }
        if buf.len() != len {
            return Err(Error::Corrupt(format!(
                "bundle file changed size during read ({} vs {len} \
                 bytes)",
                buf.len()
            )));
        }
        Ok(Arc::new(MapFile::from_bytes(buf)))
    }

    /// Heap-backed map over `bytes`, re-copied so the payload start is
    /// 64-byte aligned. The portable arm of [`MapFile::open`], and the
    /// constructor tests (including Miri) use to exercise the view
    /// handout without any FFI.
    pub fn from_bytes(bytes: Vec<u8>) -> MapFile {
        let len = bytes.len();
        let mut buf = vec![0u8; len + PAYLOAD_ALIGN - 1];
        let off = buf.as_ptr().align_offset(PAYLOAD_ALIGN);
        buf[off..off + len].copy_from_slice(&bytes);
        MapFile { backing: Backing::Heap { buf, off }, len }
    }

    /// The mapped (or heap-resident) file bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64", not(miri)))]
            Backing::Mmap { ptr } => {
                // SAFETY: `ptr` came from a successful mmap of exactly
                // `self.len` readable bytes that stays live until Drop;
                // the mapped inode is immutable under the store's
                // rename-only publish discipline (module docs), so the
                // bytes behind the slice never change or vanish.
                unsafe { std::slice::from_raw_parts(*ptr, self.len) }
            }
            Backing::Heap { buf, off } => &buf[*off..*off + self.len],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when backed by a real `mmap` (false on the heap fallback).
    pub fn is_mmap(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64", not(miri)))]
            Backing::Mmap { .. } => true,
            Backing::Heap { .. } => false,
        }
    }
}

impl Drop for MapFile {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64", not(miri)))]
        if let Backing::Mmap { ptr } = self.backing {
            // SAFETY: `ptr`/`self.len` are exactly what mmap returned,
            // unmapped exactly once (Drop), with no outstanding
            // references (dropping the MapFile requires no Arc clones
            // remain, and every view holds one).
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for u16 {}
    impl Sealed for i8 {}
    impl Sealed for f32 {}
}

/// Element types a [`MapSlice`] may reinterpret file bytes as. Sealed
/// to the three tensor element types of the format, all of which are
/// valid for every bit pattern (no padding, no niches) — the property
/// the `from_raw_parts` in [`MapSlice::as_slice`] relies on.
pub trait MapElem: sealed::Sealed + Copy + Send + Sync + 'static {}
impl MapElem for u16 {}
impl MapElem for i8 {}
impl MapElem for f32 {}

/// A typed, validated window into a [`MapFile`]: `len` elements of `T`
/// starting `off` bytes into the file. Constructing one checks bounds,
/// element alignment and byte order once; [`MapSlice::as_slice`] is
/// then a constant-time pointer cast. Cloning is cheap (an `Arc`
/// bump), and the clone keeps the whole backing map alive.
#[derive(Clone, Debug)]
pub struct MapSlice<T: MapElem> {
    map: Arc<MapFile>,
    off: usize,
    len: usize,
    _elem: std::marker::PhantomData<T>,
}

impl<T: MapElem> MapSlice<T> {
    /// Validate and build a view of `len` elements at byte offset
    /// `off`. Rejects out-of-bounds ranges, misaligned offsets and
    /// big-endian hosts (the file is little-endian; a multi-byte view
    /// would transpose every element), so `as_slice` has no failure
    /// modes left.
    pub fn new(
        map: &Arc<MapFile>,
        off: usize,
        len: usize,
        what: &str,
    ) -> Result<MapSlice<T>> {
        if cfg!(target_endian = "big") && std::mem::size_of::<T>() > 1 {
            return Err(Error::InvalidArg(format!(
                "{what}: mapped multi-byte views require a little-endian \
                 host (decode to the heap instead)"
            )));
        }
        let bytes =
            len.checked_mul(std::mem::size_of::<T>()).ok_or_else(|| {
                Error::Corrupt(format!("{what}: mapped length overflow"))
            })?;
        let end = off.checked_add(bytes).ok_or_else(|| {
            Error::Corrupt(format!("{what}: mapped offset overflow"))
        })?;
        if end > map.len() {
            return Err(Error::Corrupt(format!(
                "{what}: mapped view [{off}, {end}) exceeds the \
                 {}-byte file",
                map.len()
            )));
        }
        let addr = map.bytes().as_ptr() as usize + off;
        if addr % std::mem::align_of::<T>() != 0 {
            return Err(Error::Corrupt(format!(
                "{what}: mapped view at byte offset {off} is not \
                 {}-byte aligned",
                std::mem::align_of::<T>()
            )));
        }
        Ok(MapSlice {
            map: map.clone(),
            off,
            len,
            _elem: std::marker::PhantomData,
        })
    }

    pub fn as_slice(&self) -> &[T] {
        let ptr = self.map.bytes()[self.off..].as_ptr();
        // SAFETY: `new` (the only constructor) proved `off + len *
        // size_of::<T>()` lies inside the backing bytes, that the
        // address is aligned for T, and that the host is little-endian
        // for multi-byte T; `T: MapElem` is sealed to types valid for
        // every bit pattern. The backing `Arc<MapFile>` is immutable
        // and outlives `&self`, so the slice is valid for the returned
        // lifetime.
        unsafe { std::slice::from_raw_parts(ptr as *const T, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Storage behind every quantized tensor (and the rff weight vector):
/// decoded onto the heap (v1 bundles, or any decode without a backing
/// map) or served as a view over a mapped v2 file. Derefs to `[T]`, so
/// all element access is storage-agnostic; the only observable
/// difference is the heap/mapped accounting split.
#[derive(Clone, Debug)]
pub enum TensorData<T: MapElem> {
    Owned(Vec<T>),
    Mapped(MapSlice<T>),
}

impl<T: MapElem> std::ops::Deref for TensorData<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            TensorData::Owned(v) => v,
            TensorData::Mapped(s) => s.as_slice(),
        }
    }
}

impl<T: MapElem> From<Vec<T>> for TensorData<T> {
    fn from(v: Vec<T>) -> TensorData<T> {
        TensorData::Owned(v)
    }
}

impl<T: MapElem> From<MapSlice<T>> for TensorData<T> {
    fn from(s: MapSlice<T>) -> TensorData<T> {
        TensorData::Mapped(s)
    }
}

impl<T: MapElem> FromIterator<T> for TensorData<T> {
    fn from_iter<I: IntoIterator<Item = T>>(it: I) -> TensorData<T> {
        TensorData::Owned(it.into_iter().collect())
    }
}

impl<T: MapElem + PartialEq> PartialEq for TensorData<T> {
    fn eq(&self, other: &TensorData<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: MapElem + PartialEq> PartialEq<Vec<T>> for TensorData<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: MapElem> TensorData<T> {
    /// Bytes this tensor holds on the heap (0 when mapped).
    pub fn heap_bytes(&self) -> usize {
        match self {
            TensorData::Owned(v) => v.len() * std::mem::size_of::<T>(),
            TensorData::Mapped(_) => 0,
        }
    }

    /// Bytes this tensor serves from a mapped file (0 when owned).
    pub fn mapped_bytes(&self) -> usize {
        match self {
            TensorData::Owned(_) => 0,
            TensorData::Mapped(s) => s.len() * std::mem::size_of::<T>(),
        }
    }

    pub fn is_mapped(&self) -> bool {
        matches!(self, TensorData::Mapped(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backing(bytes: &[u8]) -> Arc<MapFile> {
        Arc::new(MapFile::from_bytes(bytes.to_vec()))
    }

    #[test]
    fn from_bytes_is_payload_aligned_and_faithful() {
        let data: Vec<u8> = (0..=255u8).collect();
        let map = backing(&data);
        assert_eq!(map.bytes(), &data[..]);
        assert_eq!(map.len(), 256);
        assert_eq!(map.bytes().as_ptr() as usize % PAYLOAD_ALIGN, 0);
        assert!(!map.is_mmap());
        let empty = backing(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.bytes(), &[] as &[u8]);
    }

    #[test]
    fn map_slice_reads_typed_views() {
        let mut bytes = Vec::new();
        for v in [1u16, 2, 0x8000, 0xffff] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [0.5f32, -2.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let map = backing(&bytes);
        let h = MapSlice::<u16>::new(&map, 0, 4, "h").unwrap();
        assert_eq!(h.as_slice(), &[1, 2, 0x8000, 0xffff]);
        let f = MapSlice::<f32>::new(&map, 8, 2, "f").unwrap();
        assert_eq!(f.as_slice(), &[0.5, -2.0]);
        let q = MapSlice::<i8>::new(&map, 0, 16, "q").unwrap();
        assert_eq!(q.as_slice()[0], 1);
        assert_eq!(q.as_slice()[5], -1i8);
    }

    #[test]
    fn map_slice_rejects_out_of_bounds_and_misalignment() {
        let map = backing(&[0u8; 64]);
        // Past the end.
        assert!(MapSlice::<u16>::new(&map, 0, 33, "t").is_err());
        assert!(MapSlice::<f32>::new(&map, 64, 1, "t").is_err());
        // Offset overflow.
        assert!(MapSlice::<i8>::new(&map, usize::MAX, 2, "t").is_err());
        // Misaligned multi-byte views (base is 64-aligned, so odd
        // in-file offsets are odd addresses).
        assert!(MapSlice::<u16>::new(&map, 1, 4, "t").is_err());
        assert!(MapSlice::<f32>::new(&map, 2, 4, "t").is_err());
        // i8 has no alignment to violate.
        assert!(MapSlice::<i8>::new(&map, 1, 4, "t").is_ok());
        // Zero-length views are fine anywhere in bounds.
        assert!(MapSlice::<u16>::new(&map, 64, 0, "t").is_ok());
    }

    #[test]
    fn tensor_data_derefs_and_accounts_storage() {
        let owned: TensorData<f32> = vec![1.0f32, 2.0].into();
        assert_eq!(&owned[..], &[1.0, 2.0]);
        assert_eq!(owned.heap_bytes(), 8);
        assert_eq!(owned.mapped_bytes(), 0);
        assert!(!owned.is_mapped());

        let mut bytes = Vec::new();
        for v in [1.0f32, 2.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let map = backing(&bytes);
        let mapped: TensorData<f32> =
            MapSlice::new(&map, 0, 2, "w").unwrap().into();
        assert_eq!(&mapped[..], &[1.0, 2.0]);
        assert_eq!(mapped.heap_bytes(), 0);
        assert_eq!(mapped.mapped_bytes(), 8);
        assert!(mapped.is_mapped());
        // Storage kinds compare by contents.
        assert_eq!(owned, mapped);
        let collected: TensorData<f32> = [1.0f32, 2.0].into_iter().collect();
        assert_eq!(collected, mapped);
    }

    #[test]
    fn mapped_views_keep_the_backing_alive() {
        let mut bytes = Vec::new();
        for v in [7u16, 8, 9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let map = backing(&bytes);
        let view = MapSlice::<u16>::new(&map, 0, 3, "v").unwrap();
        drop(map); // the view's Arc keeps the bytes valid
        assert_eq!(view.as_slice(), &[7, 8, 9]);
    }

    #[cfg(not(miri))]
    #[test]
    fn open_maps_a_real_file_with_aligned_base() {
        let path = std::env::temp_dir().join(format!(
            "approxrbf_mapfile_test_{}.bin",
            std::process::id()
        ));
        let data: Vec<u8> = (0..200u8).collect();
        std::fs::write(&path, &data).unwrap();
        let map = MapFile::open(&path).unwrap();
        assert_eq!(map.bytes(), &data[..]);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(map.is_mmap());
        assert_eq!(map.bytes().as_ptr() as usize % PAYLOAD_ALIGN, 0);
        // Empty files take the heap arm (zero-length maps are invalid).
        std::fs::write(&path, b"").unwrap();
        let empty = MapFile::open(&path).unwrap();
        assert!(empty.is_empty() && !empty.is_mmap());
        let _ = std::fs::remove_file(&path);
    }

    #[cfg(not(miri))]
    #[test]
    fn open_rejects_missing_and_oversized() {
        let missing = std::env::temp_dir().join("approxrbf_mapfile_nope");
        assert!(MapFile::open(&missing).is_err());
        assert!(check_map_len(MAX_MAP_LEN).is_ok());
        assert!(check_map_len(MAX_MAP_LEN + 1).is_err());
    }
}
